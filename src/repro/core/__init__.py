"""Core 3DGS library — the paper's contribution as composable JAX modules."""

from repro.core.binning import (
    TileBins,
    bin_gaussians,
    compact_tile_features,
    lane_occupancy_stats,
    rasterize_binned,
)
from repro.core.camera import Camera, look_at_camera, orbit_cameras
from repro.core.config import COMPRESS_MODES, DEFAULT_CONFIG, RenderConfig
from repro.core.features import (
    GaussianFeatures,
    compute_features_fused,
    compute_features_naive,
    compute_features_staged,
)
from repro.core.gaussians import (
    GaussianParams,
    clustered_gaussians,
    random_gaussians,
)
from repro.core.multicam import (
    CameraBatch,
    render_batch,
    render_batch_jit,
    render_batch_masked,
    render_batch_masked_jit,
    stack_cameras,
    unstack_cameras,
)
from repro.core.quant import (
    QuantizedGaussianParams,
    dequantize_gaussians,
    quantize_dequantize,
    quantize_gaussians,
)
from repro.core.render import render, render_jit
from repro.core.scene import (
    ChunkVisibility,
    SceneTree,
    apply_sh_lod,
    build_scene_tree,
    cull_chunks,
    gather_visible,
    resolve_scene,
    resolve_scene_f32,
    select_visible_chunks,
    visibility_stats,
)

__all__ = [
    "COMPRESS_MODES",
    "Camera",
    "CameraBatch",
    "ChunkVisibility",
    "DEFAULT_CONFIG",
    "GaussianFeatures",
    "GaussianParams",
    "QuantizedGaussianParams",
    "RenderConfig",
    "SceneTree",
    "TileBins",
    "apply_sh_lod",
    "build_scene_tree",
    "cull_chunks",
    "dequantize_gaussians",
    "gather_visible",
    "quantize_dequantize",
    "quantize_gaussians",
    "resolve_scene",
    "resolve_scene_f32",
    "select_visible_chunks",
    "visibility_stats",
    "bin_gaussians",
    "clustered_gaussians",
    "compact_tile_features",
    "compute_features_fused",
    "compute_features_naive",
    "compute_features_staged",
    "lane_occupancy_stats",
    "look_at_camera",
    "orbit_cameras",
    "random_gaussians",
    "rasterize_binned",
    "render",
    "render_batch",
    "render_batch_jit",
    "render_batch_masked",
    "render_batch_masked_jit",
    "render_jit",
    "stack_cameras",
    "unstack_cameras",
]
