"""Differentiable rasterization (depth sort + front-to-back alpha blending).

Two execution paths share one blending contract:

* **dense** (this module) — every pixel visits every Gaussian, O(P*G). This
  is the correctness oracle: simple, chunked over pixels, used by tests to
  anchor the binned path and the Pallas kernel.
* **binned** (``repro.core.binning``) — per-tile Gaussian index lists from
  screen-AABB culling, O(P * G_visible_per_tile). The production path.

Blending model (standard 3DGS):
    d      = pix - uv_n                       (2,)
    power  = -0.5 (A d_x^2 + C d_y^2) - B d_x d_y
    alpha  = min(0.99, opacity_n * exp(power)),
             dropped if alpha < 1/255 OR pix outside the 3-sigma box
             |d| <= radius_n (the box is what tile culling keys on, so both
             paths share one support definition and agree exactly)
    C_pix  = sum_n color_n * alpha_n * T_n,   T_n = prod_{m<n} (1 - alpha_m)
    out    = C_pix + T_final * background
Gaussians are iterated in increasing camera depth.

``rasterize_features`` dispatches on :class:`repro.core.config.RenderConfig`.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.config import RenderConfig
from repro.core.constants import ALPHA_EPS, ALPHA_MAX  # noqa: F401 (re-export)
from repro.core.features import GaussianFeatures


def pixel_grid(height: int, width: int, dtype=jnp.float32) -> jax.Array:
    """(H*W, 2) pixel-center coordinates (x, y)."""
    ys, xs = jnp.meshgrid(
        jnp.arange(height, dtype=dtype) + 0.5,
        jnp.arange(width, dtype=dtype) + 0.5,
        indexing="ij",
    )
    return jnp.stack([xs.reshape(-1), ys.reshape(-1)], axis=-1)


def sort_by_depth(feats: GaussianFeatures) -> GaussianFeatures:
    """Sort Gaussians front-to-back; culled ones (mask=0) sink to the back.

    The sort key is stop-gradiented: the permutation is discrete, and
    gradients flow through the subsequent gather (standard 3DGS practice —
    also works around this jaxlib build's missing batched-gather JVP).
    """
    key = jnp.where(feats.mask > 0.5, feats.depth, jnp.inf)
    order = jnp.argsort(jax.lax.stop_gradient(key))
    return jax.tree.map(lambda x: x[order], feats)


def _pixel_alphas(
    pix: jax.Array, feats: GaussianFeatures
) -> jax.Array:
    """Alpha of every Gaussian at every pixel. pix: (P, 2) -> (P, G)."""
    d = pix[:, None, :] - feats.uv[None, :, :]  # (P, G, 2)
    a = feats.conic[None, :, 0]
    b = feats.conic[None, :, 1]
    c = feats.conic[None, :, 2]
    dx, dy = d[..., 0], d[..., 1]
    power = -0.5 * (a * dx * dx + c * dy * dy) - b * dx * dy
    power = jnp.minimum(power, 0.0)
    alpha = feats.opacity[None, :] * jnp.exp(power) * feats.mask[None, :]
    alpha = jnp.minimum(alpha, ALPHA_MAX)
    # Support cutoff: alpha floor + the 3-sigma screen box. The box is the
    # same AABB tile binning culls on — keeping it here makes dense and
    # binned blending mathematically identical (not just close).
    r = feats.radius[None, :]
    inside = (jnp.abs(dx) <= r) & (jnp.abs(dy) <= r)
    return jnp.where(inside & (alpha >= ALPHA_EPS), alpha, 0.0)


def rasterize_pixels(
    pix: jax.Array,
    feats_sorted: GaussianFeatures,
    background: jax.Array,
) -> jax.Array:
    """Blend all Gaussians (already depth-sorted) at the given pixels.

    Args:
      pix: (P, 2) pixel centers.
      feats_sorted: depth-sorted features (G Gaussians).
      background: (3,) background color.

    Returns:
      (P, 3) RGB.
    """
    alpha = _pixel_alphas(pix, feats_sorted)  # (P, G)
    # Exclusive front-to-back transmittance.
    trans = jnp.cumprod(1.0 - alpha, axis=-1)
    t_prev = jnp.concatenate(
        [jnp.ones_like(trans[:, :1]), trans[:, :-1]], axis=-1
    )
    weights = alpha * t_prev  # (P, G)
    rgb = weights @ feats_sorted.color  # (P, 3)
    t_final = trans[:, -1:]
    return rgb + t_final * background[None, :]


def rasterize(
    feats: GaussianFeatures,
    height: int,
    width: int,
    *,
    background: Sequence[float] | jax.Array = (0.0, 0.0, 0.0),
    pixel_chunk: int | None = 4096,
) -> jax.Array:
    """Full-image dense rasterization — the O(P*G) oracle.

    Memory is O(pixel_chunk * G); chunking over pixels keeps the peak bounded
    (and is the oracle-side analogue of the Pallas kernel's pixel-tile grid).
    """
    bg = jnp.asarray(background, dtype=feats.color.dtype)
    feats = sort_by_depth(feats)
    pix = pixel_grid(height, width, dtype=feats.uv.dtype)
    num_pix = pix.shape[0]
    if pixel_chunk is None or pixel_chunk >= num_pix:
        out = rasterize_pixels(pix, feats, bg)
        return out.reshape(height, width, 3)

    # lax.map over fixed-size pixel chunks (pad the tail).
    chunk = pixel_chunk
    pad = (-num_pix) % chunk
    pix_padded = jnp.pad(pix, ((0, pad), (0, 0)))
    chunks = pix_padded.reshape(-1, chunk, 2)
    out = jax.lax.map(lambda p: rasterize_pixels(p, feats, bg), chunks)
    out = out.reshape(-1, 3)[:num_pix]
    return out.reshape(height, width, 3)


def rasterize_features(
    feats: GaussianFeatures,
    height: int,
    width: int,
    config: RenderConfig,
) -> jax.Array:
    """Rasterize features along ``config.raster_path``. Returns (H, W, 3).

    ``dense`` runs the oracle above; ``binned`` builds per-tile index lists
    and blends each tile against its list only; ``pallas`` packs the features
    and runs the block-list Pallas TPU kernel (forward-only);
    ``pallas_binned`` runs the gather-to-compact Pallas kernel — every lane
    holds a live Gaussian, and a custom VJP makes it trainable.
    (``pallas_fused`` never reaches this function: it starts from raw
    params, not features — ``render`` dispatches it earlier.)
    """
    if config.raster_path == "dense":
        return rasterize(
            feats,
            height,
            width,
            background=config.background,
            pixel_chunk=config.pixel_chunk,
        )

    if config.raster_path == "binned":
        from repro.core import binning  # late: binning imports features only

        bg = jnp.asarray(config.background, dtype=feats.color.dtype)
        feats = sort_by_depth(feats)
        bins = binning.bin_gaussians(
            feats,
            height,
            width,
            tile_size=config.tile_size,
            capacity=config.tile_capacity,
            tile_chunk=config.tile_chunk,
        )
        return binning.rasterize_binned(
            feats,
            bins,
            height,
            width,
            bg,
            tile_chunk=config.tile_chunk,
            early_exit=config.early_exit,
        )

    if config.raster_path == "pallas_binned":
        from repro.kernels.gaussian_features.ref import pack_features
        from repro.kernels.tile_rasterize.ops import tile_rasterize_compact

        bg = jnp.asarray(config.background, dtype=feats.color.dtype)
        feats = sort_by_depth(feats)
        return tile_rasterize_compact(
            pack_features(feats),
            height,
            width,
            bg,
            tile_size=config.tile_size,
            capacity=config.tile_capacity,
            block_g=config.block_g,
            tile_chunk=config.tile_chunk,
        )

    if config.raster_path == "pallas":
        from repro.kernels.gaussian_features.ref import pack_features
        from repro.kernels.tile_rasterize.ops import tile_rasterize_binned

        bg = jnp.asarray(config.background, dtype=feats.color.dtype)
        feats = sort_by_depth(feats)
        return tile_rasterize_binned(
            pack_features(feats),
            height,
            width,
            bg,
            tile_size=config.tile_size,
            block_g=config.block_g,
            max_blocks=config.max_blocks_per_tile,
        )

    if config.raster_path == "pallas_fused":
        raise ValueError(
            "raster_path='pallas_fused' consumes raw GaussianParams, not "
            "precomputed features — call render()/render_jit() (or "
            "repro.kernels.fused_raster.fused_render) instead of "
            "rasterize_features"
        )

    raise ValueError(f"unknown raster_path {config.raster_path!r}")


def accumulated_alpha(
    feats: GaussianFeatures, height: int, width: int, pixel_chunk: int | None = 4096
) -> jax.Array:
    """1 - final transmittance per pixel (coverage map, used in tests)."""
    feats = sort_by_depth(feats)
    pix = pixel_grid(height, width, dtype=feats.uv.dtype)

    def chunk_fn(p):
        alpha = _pixel_alphas(p, feats)
        return 1.0 - jnp.prod(1.0 - alpha, axis=-1)

    num_pix = pix.shape[0]
    if pixel_chunk is None or pixel_chunk >= num_pix:
        return chunk_fn(pix).reshape(height, width)
    pad = (-num_pix) % pixel_chunk
    chunks = jnp.pad(pix, ((0, pad), (0, 0))).reshape(-1, pixel_chunk, 2)
    out = jax.lax.map(chunk_fn, chunks).reshape(-1)[:num_pix]
    return out.reshape(height, width)
