"""Gaussian feature computation — the paper's workload (Section IV).

Three execution paths, mirroring the paper's method ladder:

* ``naive``   — paper's "Naive": each of the (post-partitioning) seven tasks is
  its own jitted call; the math inside is written per-Gaussian with explicit
  3x3 index loops (``vmap`` of scalar code), i.e. no SoA vectorization. Each
  stage's intermediates round-trip through HBM — the analogue of un-optimized
  tile kernels chained over the array.
* ``staged``  — paper's "In-tile optimized" (Stream/Window): the same seven
  stages, still materializing stage boundaries (tile-to-tile streaming
  analogue), but each stage is SoA-vectorized over the Gaussian axis and uses
  the symmetric-Σ upper-triangular trick and the K = J·R_cw precompute.
* ``fused``   — beyond-paper: all seven stages in one pass with no stage
  materialization. Exposed both as a single jitted jnp function (this module)
  and as a Pallas TPU kernel (``repro.kernels.gaussian_features``).

All paths are numerically identical (fp32) and differentiable.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import constants
from repro.core import sh as sh_lib
from repro.core.camera import Camera
from repro.core.gaussians import GaussianParams

# Screen-space blur added to the 2D covariance diagonal (reference value).
COV2D_BLUR = 0.3
# Minimum camera-space depth for a Gaussian to be considered in-frustum.
NEAR_PLANE = 0.2
# Blending alpha floor (re-exported from core.constants, the single home of
# the alpha-floor contract): a Gaussian whose post-sigmoid opacity is below
# it can never pass the rasterizer's alpha cutoff (alpha <= opacity), so the
# validity mask culls it outright. That keeps sentinel/padding records
# (opacity ~1e-13) out of tile lists, where they would otherwise crowd the
# fixed capacity without contributing.
ALPHA_EPS = constants.ALPHA_EPS
# Guard band on the projection-plane coordinates before the Jacobian (the
# reference clamps x/z, y/z to 1.3 * tan(fov) to keep J finite off-screen).
FOV_GUARD = 1.3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GaussianFeatures:
    """Per-Gaussian screen-space features (paper's output record).

    uv:      (N, 2) pixel-space projected centers.
    conic:   (N, 3) inverse 2D covariance upper triangle (A, B, C).
    color:   (N, 3) view-dependent RGB.
    depth:   (N,)   camera-space z (sort key for the rasterizer).
    radius:  (N,)   3-sigma screen radius in pixels.
    opacity: (N,)   post-sigmoid opacity.
    mask:    (N,)   in-frustum validity (float 0/1 to stay differentiable-friendly).
    """

    uv: jax.Array
    conic: jax.Array
    color: jax.Array
    depth: jax.Array
    radius: jax.Array
    opacity: jax.Array
    mask: jax.Array


# ---------------------------------------------------------------------------
# Shared small math
# ---------------------------------------------------------------------------


def quat_to_rotmat(q: jax.Array) -> jax.Array:
    """(..., 4) wxyz quaternion -> (..., 3, 3) rotation matrix (normalizing)."""
    q = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    r00 = 1.0 - 2.0 * (y * y + z * z)
    r01 = 2.0 * (x * y - w * z)
    r02 = 2.0 * (x * z + w * y)
    r10 = 2.0 * (x * y + w * z)
    r11 = 1.0 - 2.0 * (x * x + z * z)
    r12 = 2.0 * (y * z - w * x)
    r20 = 2.0 * (x * z - w * y)
    r21 = 2.0 * (y * z + w * x)
    r22 = 1.0 - 2.0 * (x * x + y * y)
    rows = jnp.stack(
        [
            jnp.stack([r00, r01, r02], axis=-1),
            jnp.stack([r10, r11, r12], axis=-1),
            jnp.stack([r20, r21, r22], axis=-1),
        ],
        axis=-2,
    )
    return rows


# ---------------------------------------------------------------------------
# Staged (vectorized) stage implementations — the paper's 7 kernels
# ---------------------------------------------------------------------------


def stage_cov3d(quats: jax.Array, scales: jax.Array) -> jax.Array:
    """Paper kernel ``cov3D``: Sigma = R diag(s^2) R^T, upper triangle only.

    Vectorized form of the paper's Listing 2: each output entry is a dot of a
    row of R with an elementwise-scaled row of R. Returns (N, 6) as
    (xx, xy, xz, yy, yz, zz).
    """
    r = quat_to_rotmat(quats)  # (N, 3, 3)
    s2 = scales * scales  # (N, 3)
    rs = r * s2[..., None, :]  # (N, 3, 3): row_i * s^2 (elementwise, aie::mul)
    # sigma[i, j] = dot(rs[i], r[j]); symmetric -> 6 entries.
    xx = jnp.sum(rs[..., 0, :] * r[..., 0, :], axis=-1)
    xy = jnp.sum(rs[..., 0, :] * r[..., 1, :], axis=-1)
    xz = jnp.sum(rs[..., 0, :] * r[..., 2, :], axis=-1)
    yy = jnp.sum(rs[..., 1, :] * r[..., 1, :], axis=-1)
    yz = jnp.sum(rs[..., 1, :] * r[..., 2, :], axis=-1)
    zz = jnp.sum(rs[..., 2, :] * r[..., 2, :], axis=-1)
    return jnp.stack([xx, xy, xz, yy, yz, zz], axis=-1)


def stage_projection(
    positions: jax.Array, cam: Camera
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Paper kernel ``projection``: world -> camera -> pixel coordinates.

    Returns (p_cam (N,3), uv (N,2), depth (N,)).
    """
    p_cam = positions @ cam.r_cw.T + cam.t_cw
    z = p_cam[..., 2]
    safe_z = jnp.where(jnp.abs(z) < 1e-6, 1e-6, z)
    u = cam.fx * p_cam[..., 0] / safe_z + cam.cx
    v = cam.fy * p_cam[..., 1] / safe_z + cam.cy
    return p_cam, jnp.stack([u, v], axis=-1), z


def stage_jacobian(p_cam: jax.Array, cam: Camera) -> jax.Array:
    """Paper kernel ``Jacobian``: J of the pinhole projection at p_cam.

    Returns (N, 2, 3). Off-screen x/z, y/z are clamped to the FOV guard band
    as in the reference implementation.
    """
    tanx, tany = cam.tan_fov()
    x, y, z = p_cam[..., 0], p_cam[..., 1], p_cam[..., 2]
    safe_z = jnp.where(jnp.abs(z) < 1e-6, 1e-6, z)
    tx = jnp.clip(x / safe_z, -FOV_GUARD * tanx, FOV_GUARD * tanx) * safe_z
    ty = jnp.clip(y / safe_z, -FOV_GUARD * tany, FOV_GUARD * tany) * safe_z
    inv_z = 1.0 / safe_z
    inv_z2 = inv_z * inv_z
    zeros = jnp.zeros_like(z)
    row0 = jnp.stack([cam.fx * inv_z, zeros, -cam.fx * tx * inv_z2], axis=-1)
    row1 = jnp.stack([zeros, cam.fy * inv_z, -cam.fy * ty * inv_z2], axis=-1)
    return jnp.stack([row0, row1], axis=-2)


def stage_cov2d(
    cov3d: jax.Array, jac: jax.Array, cam: Camera
) -> jax.Array:
    """Paper kernel ``cov2D``: Sigma' = K Sigma K^T with K = J R_cw (Eq. 4).

    Returns (N, 3) as (A, B, C) = (Sigma'_00 + blur, Sigma'_01, Sigma'_11 + blur).
    """
    k = jnp.einsum("nij,jk->nik", jac, cam.r_cw)  # (N, 2, 3) — Eq. 4
    # Expand upper triangle to full symmetric Sigma rows.
    xx, xy, xz, yy, yz, zz = (cov3d[..., i] for i in range(6))
    sigma = jnp.stack(
        [
            jnp.stack([xx, xy, xz], axis=-1),
            jnp.stack([xy, yy, yz], axis=-1),
            jnp.stack([xz, yz, zz], axis=-1),
        ],
        axis=-2,
    )  # (N, 3, 3)
    ks = jnp.einsum("nij,njk->nik", k, sigma)  # (N, 2, 3)
    cov2d = jnp.einsum("nij,nkj->nik", ks, k)  # (N, 2, 2); symmetric
    a = cov2d[..., 0, 0] + COV2D_BLUR
    b = cov2d[..., 0, 1]
    c = cov2d[..., 1, 1] + COV2D_BLUR
    return jnp.stack([a, b, c], axis=-1)


def stage_cov2d_inv(cov2d: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Paper kernel ``cov2D_inv``: conic (inverse 2x2) + 3-sigma screen radius."""
    a, b, c = cov2d[..., 0], cov2d[..., 1], cov2d[..., 2]
    det = a * c - b * b
    safe_det = jnp.where(det <= 0.0, 1.0, det)
    inv_det = 1.0 / safe_det
    conic = jnp.stack([c * inv_det, -b * inv_det, a * inv_det], axis=-1)
    mid = 0.5 * (a + c)
    disc = jnp.sqrt(jnp.maximum(mid * mid - det, 0.1))
    lam1 = mid + disc
    radius = jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam1, 0.0)))
    radius = jnp.where(det <= 0.0, 0.0, radius)
    return conic, radius


def stage_ray_dir(positions: jax.Array, cam: Camera) -> jax.Array:
    """Paper kernel ``ray_dir`` (split from color for pipeline balance)."""
    d = positions - cam.cam_pos
    return d / (jnp.linalg.norm(d, axis=-1, keepdims=True) + 1e-12)


def stage_color(sh: jax.Array, ray_dir: jax.Array, degree: int = 3) -> jax.Array:
    """Paper kernel ``color``: Eq. 3 via 16 SH basis functions."""
    return sh_lib.eval_sh_color(sh, ray_dir, degree=degree)


# ---------------------------------------------------------------------------
# Naive path — per-Gaussian scalar code (paper Listing 1 semantics)
# ---------------------------------------------------------------------------


def _naive_cov3d_single(quat: jax.Array, scale: jax.Array) -> jax.Array:
    """Triple-loop Sigma = (R S) (R S)^T for one Gaussian (paper Listing 1)."""
    r = quat_to_rotmat(quat)
    s2 = scale * scale
    temp = [[jnp.float32(0.0)] * 3 for _ in range(3)]
    for i in range(3):
        for j in range(3):
            acc = jnp.float32(0.0)
            for k in range(3):
                acc = acc + r[i, k] * (s2[k] * (1.0 if k == j else 0.0))
            temp[i][j] = acc
    cov = [[jnp.float32(0.0)] * 3 for _ in range(3)]
    for i in range(3):
        for j in range(3):
            acc = jnp.float32(0.0)
            for k in range(3):
                acc = acc + temp[i][k] * r[j, k]
            cov[i][j] = acc
    return jnp.stack(
        [cov[0][0], cov[0][1], cov[0][2], cov[1][1], cov[1][2], cov[2][2]]
    )


def _naive_cov2d_single(cov3d: jax.Array, jac: jax.Array, r_cw: jax.Array) -> jax.Array:
    """Five explicit small matmuls: J R Sigma R^T J^T (no K precompute)."""
    xx, xy, xz, yy, yz, zz = (cov3d[i] for i in range(6))
    sigma = jnp.array([[xx, xy, xz], [xy, yy, yz], [xz, yz, zz]])
    m1 = jac @ r_cw  # in the naive path this is *re*-computed per Gaussian
    m2 = m1 @ sigma
    m3 = m2 @ r_cw.T
    m4 = m3 @ jac.T
    return jnp.stack(
        [m4[0, 0] + COV2D_BLUR, m4[0, 1], m4[1, 1] + COV2D_BLUR]
    )


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _finalize(
    uv: jax.Array,
    conic: jax.Array,
    color: jax.Array,
    depth: jax.Array,
    radius: jax.Array,
    opacity: jax.Array,
    cam: Camera,
) -> GaussianFeatures:
    onscreen = (
        (uv[..., 0] > -radius)
        & (uv[..., 0] < cam.width + radius)
        & (uv[..., 1] > -radius)
        & (uv[..., 1] < cam.height + radius)
    )
    mask = (
        (depth > NEAR_PLANE)
        & (radius > 0.0)
        & onscreen
        & (opacity >= ALPHA_EPS)
    )
    return GaussianFeatures(
        uv=uv,
        conic=conic,
        color=color,
        depth=depth,
        radius=radius,
        opacity=opacity,
        mask=mask.astype(uv.dtype),
    )


def compute_features_staged(
    g: GaussianParams, cam: Camera, *, sh_degree: int = 3
) -> GaussianFeatures:
    """Paper's in-tile-optimized pipeline: 7 vectorized stages."""
    cov3d = stage_cov3d(g.quats, g.scales())
    p_cam, uv, depth = stage_projection(g.positions, cam)
    jac = stage_jacobian(p_cam, cam)
    cov2d = stage_cov2d(cov3d, jac, cam)
    conic, radius = stage_cov2d_inv(cov2d)
    rdir = stage_ray_dir(g.positions, cam)
    color = stage_color(g.sh, rdir, degree=sh_degree)
    return _finalize(uv, conic, color, depth, radius, g.opacities(), cam)


# ``fused`` shares the exact same math; the difference is materialization:
# the staged benchmark path jits each stage separately (HBM round trips),
# while the fused path jits the whole pipeline (XLA fuses elementwise chains)
# and the Pallas kernel goes further (explicit VMEM blocking).
compute_features_fused = compute_features_staged


def compute_features_naive(
    g: GaussianParams, cam: Camera, *, sh_degree: int = 3
) -> GaussianFeatures:
    """Paper's naive path: per-Gaussian scalar loops, no K precompute."""
    cov3d = jax.vmap(_naive_cov3d_single)(g.quats, g.scales())
    p_cam, uv, depth = stage_projection(g.positions, cam)
    jac = stage_jacobian(p_cam, cam)
    cov2d = jax.vmap(_naive_cov2d_single, in_axes=(0, 0, None))(
        cov3d, jac, cam.r_cw
    )
    conic, radius = stage_cov2d_inv(cov2d)
    rdir = stage_ray_dir(g.positions, cam)
    # Naive color: explicit per-basis accumulation for one Gaussian at a time.
    def one_color(sh_n, d_n):
        basis = sh_lib.sh_basis(d_n)
        acc = jnp.zeros((3,), dtype=sh_n.dtype)
        for k in range((sh_degree + 1) ** 2):
            acc = acc + sh_n[k] * basis[k]
        return jnp.maximum(acc + 0.5, 0.0)

    color = jax.vmap(one_color)(g.sh, rdir)
    return _finalize(uv, conic, color, depth, radius, g.opacities(), cam)


def staged_stage_fns(cam: Camera, sh_degree: int = 3) -> dict[str, Callable]:
    """The 7 post-partitioning stages as separately-jittable callables.

    Used by the Table-I benchmark to time each paper kernel in isolation.
    """
    return {
        "cov3D": lambda g: stage_cov3d(g.quats, g.scales()),
        "projection": lambda g: stage_projection(g.positions, cam),
        "Jacobian": lambda g: stage_jacobian(
            stage_projection(g.positions, cam)[0], cam
        ),
        "cov2D": lambda g: stage_cov2d(
            stage_cov3d(g.quats, g.scales()),
            stage_jacobian(stage_projection(g.positions, cam)[0], cam),
            cam,
        ),
        "cov2D_inv": lambda g: stage_cov2d_inv(
            stage_cov2d(
                stage_cov3d(g.quats, g.scales()),
                stage_jacobian(stage_projection(g.positions, cam)[0], cam),
                cam,
            )
        ),
        "dir_vec": lambda g: stage_ray_dir(g.positions, cam),
        "color": lambda g: stage_color(
            g.sh, stage_ray_dir(g.positions, cam), degree=sh_degree
        ),
    }
