"""End-to-end 3DGS rendering: feature computation -> sort -> bin -> rasterize.

All knobs travel in a single :class:`repro.core.config.RenderConfig`; the old
loose kwargs (``feature_path=...``, ``sh_degree=...``, ...) are accepted
through a deprecation shim that folds them into a config.
"""

from __future__ import annotations

import functools
import warnings

import jax

from repro.core import features as feat_lib
from repro.core import rasterize as rast_lib
from repro.core.camera import Camera
from repro.core.config import UNSET, RenderConfig, as_config
from repro.core.gaussians import GaussianParams
from repro.core.quant import QuantizedGaussianParams
from repro.core.scene import SceneTree, resolve_scene_banded, resolve_scene_f32

FEATURE_PATHS = {
    "naive": feat_lib.compute_features_naive,
    "staged": feat_lib.compute_features_staged,
    "fused": feat_lib.compute_features_fused,
}

def _shim_config(config: RenderConfig | None, legacy: dict) -> RenderConfig:
    """Fold deprecated loose kwargs into a RenderConfig (with a warning)."""
    used = {k: v for k, v in legacy.items() if v is not UNSET}
    if used:
        warnings.warn(
            f"render(..., {', '.join(sorted(used))}=...) is deprecated; pass "
            "config=RenderConfig(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
    return as_config(config, **legacy)


def compute_features(
    g: GaussianParams, cam: Camera, config: RenderConfig
) -> feat_lib.GaussianFeatures:
    """Per-Gaussian screen-space features along ``config.feature_path``."""
    if config.feature_path == "pallas":
        # Imported lazily to keep core importable without the kernels package.
        from repro.kernels.gaussian_features import ops as gf_ops

        return gf_ops.gaussian_features(g, cam, sh_degree=config.sh_degree)
    return FEATURE_PATHS[config.feature_path](
        g, cam, sh_degree=config.sh_degree
    )


def render(
    g: "GaussianParams | SceneTree",
    cam: Camera,
    config: RenderConfig | None = None,
    *,
    sh_degree=UNSET,
    background=UNSET,
    feature_path=UNSET,
    pixel_chunk=UNSET,
) -> jax.Array:
    """Render one view. Returns (H, W, 3) in [0, ~1].

    Args:
      g: Gaussian cloud, or a :class:`repro.core.scene.SceneTree` — with
        ``config.cull`` the tree is frustum-culled against ``cam`` and only
        the visible chunks are featured/binned/blended (see
        ``scene.resolve_scene``).
      cam: camera (height/width are static ints on the camera).
      config: full render configuration; defaults to
        ``repro.core.config.DEFAULT_CONFIG`` (fused features, binned raster).
      sh_degree, background, feature_path, pixel_chunk: DEPRECATED loose
        kwargs, folded into ``config`` for backward compatibility.
    """
    cfg = _shim_config(
        config,
        dict(
            sh_degree=sh_degree,
            background=background,
            feature_path=feature_path,
            pixel_chunk=pixel_chunk,
        ),
    )
    if cfg.raster_path == "pallas_fused":
        # The fused path consumes raw params (+ the per-Gaussian SH LOD
        # band, which its kernel turns into skipped basis FLOPs) — feature
        # computation happens inside the blend kernel, so compute_features
        # and cfg.feature_path are bypassed entirely.
        from repro.kernels.fused_raster import ops as fused_ops

        g, band = resolve_scene_banded(g, cam, cfg)
        # A quantized resolve (compressed resident SceneTree) streams the
        # compact int8/fp16 records straight into the decode-in-kernel
        # raster; f32 resolves (incl. the compress="int8" straight-through
        # estimator) take the raw-record kernel. Both produce the same
        # image bitwise for the same scene.
        entry = (
            fused_ops.fused_render_q
            if isinstance(g, QuantizedGaussianParams)
            else fused_ops.fused_render
        )
        return entry(
            g,
            cam,
            jax.numpy.asarray(cfg.background, jax.numpy.float32),
            band=band,
            tile_size=cfg.tile_size,
            capacity=cfg.tile_capacity,
            block_g=cfg.block_g,
            tile_chunk=cfg.tile_chunk,
            sh_degree=cfg.sh_degree,
            early_exit=cfg.early_exit,
        )
    g = resolve_scene_f32(g, cam, cfg)
    feats = compute_features(g, cam, cfg)
    return rast_lib.rasterize_features(feats, cam.height, cam.width, cfg)


@functools.partial(jax.jit, static_argnames=("config",))
def render_jit(
    g: "GaussianParams | SceneTree",
    cam: Camera,
    config: RenderConfig | None = None,
) -> jax.Array:
    """Jitted :func:`render`. ``config`` is static (hashable dataclass)."""
    return render(g, cam, config)


def render_with_stats(
    g: "GaussianParams | SceneTree",
    cam: Camera,
    config: RenderConfig | None = None,
) -> tuple[jax.Array, dict | None]:
    """Render one view and (opt-in) collect pipeline diagnostics.

    With ``config.collect_stats=False`` this is exactly ``(render(g, cam,
    config), None)``. With it on, the returned stats dict depends on the
    raster path:

    * ``pallas_fused``: the in-kernel per-tile diagnostics plane
      (``chunks_processed`` / ``lanes_blended`` / ``max_sh_band`` measured
      inside the streaming loop, plus the assigned ``chunks_assigned``
      upper bound) — the image is bitwise-identical to the uninstrumented
      render (same operand prep, same in-kernel op sequence).
    * other paths: host-side ``core.binning.lane_occupancy_stats`` of the
      same resolved/sorted features the raster consumed (compact/block
      lane occupancy, chunk counts) — the image comes from the normal
      ``render`` and is trivially unchanged.

    Either way a ``visibility`` sub-dict (cull visible fraction) is added
    when ``g`` is a culled SceneTree. Stats values are device arrays /
    floats; ``repro.obs.pipeline`` folds them into a metrics registry.
    """
    cfg = as_config(config)
    if not cfg.collect_stats:
        return render(g, cam, cfg), None

    from repro.core.scene import visibility_stats

    extra: dict = {}
    if isinstance(g, SceneTree) and cfg.cull:
        vis = visibility_stats(g, cam, cfg)
        extra["visibility"] = {
            k: (v.item() if hasattr(v, "item") else v) for k, v in vis.items()
        }

    if cfg.raster_path == "pallas_fused":
        from repro.kernels.fused_raster import ops as fused_ops

        gr, band = resolve_scene_banded(g, cam, cfg)
        entry = (
            fused_ops.fused_render_q_stats
            if isinstance(gr, QuantizedGaussianParams)
            else fused_ops.fused_render_stats
        )
        img, stats = entry(
            gr,
            cam,
            jax.numpy.asarray(cfg.background, jax.numpy.float32),
            band=band,
            tile_size=cfg.tile_size,
            capacity=cfg.tile_capacity,
            block_g=cfg.block_g,
            tile_chunk=cfg.tile_chunk,
            sh_degree=cfg.sh_degree,
            early_exit=cfg.early_exit,
        )
        return img, {"kernel": stats, "block_g": cfg.block_g, **extra}

    from repro.core.binning import lane_occupancy_stats
    from repro.core.rasterize import sort_by_depth

    img = render(g, cam, cfg)
    gr = resolve_scene_f32(g, cam, cfg)
    feats = sort_by_depth(compute_features(gr, cam, cfg))
    occ = lane_occupancy_stats(
        feats,
        cam.height,
        cam.width,
        tile_size=cfg.tile_size,
        capacity=cfg.tile_capacity,
        block_g=cfg.block_g,
    )
    return img, {"occupancy": occ, "block_g": cfg.block_g, **extra}
