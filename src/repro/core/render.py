"""End-to-end 3DGS rendering: feature computation -> sort -> rasterize."""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import features as feat_lib
from repro.core import rasterize as rast_lib
from repro.core.camera import Camera
from repro.core.gaussians import GaussianParams

FEATURE_PATHS = {
    "naive": feat_lib.compute_features_naive,
    "staged": feat_lib.compute_features_staged,
    "fused": feat_lib.compute_features_fused,
}


def render(
    g: GaussianParams,
    cam: Camera,
    *,
    sh_degree: int = 3,
    background: Sequence[float] = (0.0, 0.0, 0.0),
    feature_path: str = "fused",
    pixel_chunk: int | None = 4096,
) -> jax.Array:
    """Render one view. Returns (H, W, 3) in [0, ~1]."""
    if feature_path == "pallas":
        # Imported lazily to keep core importable without the kernels package.
        from repro.kernels.gaussian_features import ops as gf_ops

        feats = gf_ops.gaussian_features(g, cam, sh_degree=sh_degree)
    else:
        feats = FEATURE_PATHS[feature_path](g, cam, sh_degree=sh_degree)
    return rast_lib.rasterize(
        feats,
        cam.height,
        cam.width,
        background=background,
        pixel_chunk=pixel_chunk,
    )


@functools.partial(jax.jit, static_argnames=("sh_degree", "feature_path", "pixel_chunk"))
def render_jit(
    g: GaussianParams,
    cam: Camera,
    sh_degree: int = 3,
    feature_path: str = "fused",
    pixel_chunk: int | None = 4096,
) -> jax.Array:
    return render(
        g, cam, sh_degree=sh_degree, feature_path=feature_path, pixel_chunk=pixel_chunk
    )
