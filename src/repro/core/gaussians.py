"""Gaussian parameter containers (SoA layout).

The paper processes Gaussians as a flat stream of records:
    position p_w (3), rotation quaternion q (4), scale s (3),
    spherical-harmonic coefficients sh (48 = 16 basis x 3 channels),
    opacity alpha (1)                                -> 59 floats / Gaussian.

We keep a struct-of-arrays (SoA) layout throughout: on the Versal AIE the
paper streams records and vectorizes *within* a record; on TPU we put one
Gaussian per VPU lane, so every field must be a contiguous array over the
Gaussian axis (see DESIGN.md section 2, adaptation note 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

# Bytes per Gaussian in the paper's record format (59 f32 values).
GAUSSIAN_RECORD_FLOATS = 3 + 4 + 3 + 48 + 1
GAUSSIAN_RECORD_BYTES = GAUSSIAN_RECORD_FLOATS * 4

# Feature-output record (paper: u, cov2D upper-tri/conic, color, depth, radius,
# opacity): 2 + 3 + 3 + 1 + 1 + 1 = 11 f32 values.
FEATURE_RECORD_FLOATS = 11
FEATURE_RECORD_BYTES = FEATURE_RECORD_FLOATS * 4

NUM_SH_BASES = 16  # degree <= 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GaussianParams:
    """SoA Gaussian cloud.

    Attributes:
      positions: (N, 3) world-space means.
      quats:     (N, 4) rotation quaternions (w, x, y, z); need not be
                 pre-normalized, all consumers normalize.
      log_scales:(N, 3) log of per-axis standard deviations (log-space keeps
                 the training parameterization positive).
      sh:        (N, 16, 3) real spherical-harmonic coefficients, degree <= 3.
      opacity_logit: (N,) pre-sigmoid opacity.
    """

    positions: jax.Array
    quats: jax.Array
    log_scales: jax.Array
    sh: jax.Array
    opacity_logit: jax.Array

    @property
    def num_gaussians(self) -> int:
        return self.positions.shape[0]

    def scales(self) -> jax.Array:
        return jnp.exp(self.log_scales)

    def opacities(self) -> jax.Array:
        return jax.nn.sigmoid(self.opacity_logit)

    def astype(self, dtype: Any) -> "GaussianParams":
        return jax.tree.map(lambda x: x.astype(dtype), self)


def random_gaussians(
    key: jax.Array,
    num: int,
    *,
    extent: float = 2.0,
    base_scale: float = 0.03,
    dtype: Any = jnp.float32,
) -> GaussianParams:
    """Random cloud matching the paper's synthetic 100-sample evaluation setup."""
    kp, kq, ks, kh, ko = jax.random.split(key, 5)
    positions = jax.random.uniform(kp, (num, 3), minval=-extent, maxval=extent)
    quats = jax.random.normal(kq, (num, 4))
    quats = quats / (jnp.linalg.norm(quats, axis=-1, keepdims=True) + 1e-8)
    log_scales = jnp.log(base_scale) + 0.3 * jax.random.normal(ks, (num, 3))
    sh = 0.3 * jax.random.normal(kh, (num, NUM_SH_BASES, 3))
    # Bias the DC term so colors land in a visible range after the +0.5 shift.
    sh = sh.at[:, 0, :].add(0.8)
    opacity_logit = jax.random.normal(ko, (num,)) + 1.5
    return GaussianParams(
        positions=positions.astype(dtype),
        quats=quats.astype(dtype),
        log_scales=log_scales.astype(dtype),
        sh=sh.astype(dtype),
        opacity_logit=opacity_logit.astype(dtype),
    )


def clustered_gaussians(
    key: jax.Array,
    num: int,
    *,
    num_clusters: int = 6,
    cluster_std: float = 0.12,
    extent: float = 2.0,
    base_scale: float = 0.03,
    dtype: Any = jnp.float32,
) -> GaussianParams:
    """Non-uniform cloud: Gaussians bunched around a few cluster centers.

    The worst case for block-granular raster sparsity (most screen tiles are
    empty, a few are crowded) and therefore the scene where gather-to-compact
    per-tile lists pay off most — used by the occupancy benchmarks/tests.
    Everything except positions matches :func:`random_gaussians`.
    """
    kc, ka, kp, krest = jax.random.split(key, 4)
    centers = jax.random.uniform(
        kc, (num_clusters, 3), minval=-extent, maxval=extent
    )
    assign = jax.random.randint(ka, (num,), 0, num_clusters)
    offsets = cluster_std * jax.random.normal(kp, (num, 3))
    g = random_gaussians(
        krest, num, extent=extent, base_scale=base_scale, dtype=dtype
    )
    positions = (centers[assign] + offsets).astype(dtype)
    return dataclasses.replace(g, positions=positions)


def pack_records(g: GaussianParams) -> jax.Array:
    """Pack to the paper's flat (N, 59) record stream (for IO-oriented benches)."""
    n = g.num_gaussians
    return jnp.concatenate(
        [
            g.positions,
            g.quats,
            g.log_scales,
            g.sh.reshape(n, NUM_SH_BASES * 3),
            g.opacity_logit[:, None],
        ],
        axis=-1,
    )


def unpack_records(records: jax.Array) -> GaussianParams:
    """Inverse of :func:`pack_records`."""
    n = records.shape[0]
    return GaussianParams(
        positions=records[:, 0:3],
        quats=records[:, 3:7],
        log_scales=records[:, 7:10],
        sh=records[:, 10:58].reshape(n, NUM_SH_BASES, 3),
        opacity_logit=records[:, 58],
    )


def pad_to_multiple(g: GaussianParams, multiple: int) -> tuple[GaussianParams, int]:
    """Pad the cloud so N % multiple == 0 (padded entries have opacity -> 0).

    Returns the padded params and the original count. Padding Gaussians are
    placed behind the camera guard plane (z<=0 after view transform is culled
    by the feature pipeline anyway) and given -30 opacity logit so they are
    numerically invisible to the rasterizer.
    """
    n = g.num_gaussians
    pad = (-n) % multiple
    if pad == 0:
        return g, n

    def _pad(x, fill):
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    padded = GaussianParams(
        positions=_pad(g.positions, 0.0),
        quats=_pad(g.quats, 1.0),
        log_scales=_pad(g.log_scales, -10.0),
        sh=_pad(g.sh, 0.0),
        opacity_logit=_pad(g.opacity_logit, -30.0),
    )
    return padded, n
