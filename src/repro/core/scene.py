"""Hierarchical scene subsystem — Morton-chunked AABB tree, frustum culling,
and distance-based spherical-harmonic LOD for million-Gaussian scenes.

Every render path so far touches all N Gaussians per camera: features are
computed for the whole cloud and the binner scans every Gaussian against
every tile. That caps scene size long before the serving stack saturates.
This module makes *scene size* the scaling axis:

* :func:`build_scene_tree` — a **static** spatial hierarchy built once per
  scene (at server startup / training checkpoints). Gaussians are sorted
  along a Morton (Z-order) curve so that each run of ``leaf_size``
  consecutive Gaussians is spatially coherent, and each such run becomes a
  *chunk* with a conservative world-space AABB (member positions padded by
  their 3-sigma support radius). A flat array of chunk AABBs over a
  locality-preserving permutation is the octree collapsed to its leaf
  level — exactly the part per-camera culling consumes, with none of the
  pointer chasing.
* :func:`cull_chunks` — per-camera frustum test of every chunk AABB (near
  plane + the four side planes, expanded by a screen-space margin so the
  test is conservative w.r.t. the rasterizer's 3-sigma/alpha-floor support
  contract), plus a per-chunk camera distance that drives LOD.
* :func:`select_visible_chunks` / :func:`gather_visible` — the
  gather-to-compact pattern from ``binning.compact_tile_features`` lifted
  to whole chunks: a **static-capacity** list of visible chunk indices
  (nearest-first on overflow, sentinel-padded) gathers a compact
  ``GaussianParams`` of ``capacity * leaf_size`` records. Static shapes ->
  one compiled executable per capacity; the traced camera only changes
  *which* chunks are gathered. Sentinel slots gather an invisible record
  (opacity below the alpha floor, mask-culled by the feature pipeline) and
  contribute exactly zero color/alpha in every blend path.
* :func:`apply_sh_lod` — distance-banded SH degree (3 near / 1 mid / 0 far
  by ``RenderConfig.lod_thresholds``): coefficients above each Gaussian's
  band are zeroed, which makes the degree-3 evaluator produce *exactly* the
  lower-degree color (the SH basis is orthogonal per coefficient). Under
  one executable the saving is bandwidth/accuracy-shaped; the static
  ``RenderConfig.sh_degree`` knob cuts basis FLOPs for the whole scene.

Everything below :func:`build_scene_tree` is jit/vmap/shard_map-friendly:
the tree is a pytree (``leaf_size`` static), culling + gather are pure
static-shape jnp, and gradients flow through the chunk gather back to the
resident cloud (scatter-add), so a culled render remains trainable.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import Camera
from repro.core.config import RenderConfig
from repro.core.features import NEAR_PLANE
from repro.core.gaussians import GaussianParams, pad_to_multiple
from repro.core.quant import (
    COMPRESS_MODES,
    QuantizedGaussianParams,
    dequantize_geometry,
    dequantize_gaussians,
    f32_memory_stats,
    quantize_dequantize,
    quantize_gaussians,
    quantized_memory_stats,
)

# World-space support radius of a Gaussian = AABB_SIGMA * max axis scale.
# 3 sigma matches the rasterizer's screen-space support box; the frustum
# margin below absorbs the blur/rounding slop on top.
AABB_SIGMA = 3.0

# Screen-space slack (pixels) added to the frustum side planes: the
# rasterizer's support radius includes the COV2D_BLUR screen blur
# (3 * sqrt(0.3) ~ 1.65 px), a ceil() on the radius (< 1 px) and the
# half-pixel center offset. 4 px over-covers all three.
FRUSTUM_MARGIN_PX = 4.0

# Morton quantization: 10 bits per axis -> 30-bit codes.
_MORTON_BITS = 10


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SceneTree:
    """Static chunked scene hierarchy (the octree's leaf level, flattened).

    Attributes:
      gaussians: (N_pad, ...) Morton-permuted cloud, padded to a whole
        number of chunks with invisible records (``pad_to_multiple``).
        Either plain f32 ``GaussianParams`` or a compressed
        ``QuantizedGaussianParams`` (``build_scene_tree(compress="int8")``)
        whose quantization chunks coincide with the tree's leaves.
      chunk_lo, chunk_hi: (M, 3) conservative world AABB of each chunk
        (member positions padded by their 3-sigma support radius).
      leaf_size: Gaussians per chunk (static; N_pad == M * leaf_size).
      num_real: original Gaussian count before padding (static).
    """

    gaussians: GaussianParams | QuantizedGaussianParams
    chunk_lo: jax.Array
    chunk_hi: jax.Array
    leaf_size: int = dataclasses.field(metadata=dict(static=True))
    num_real: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_chunks(self) -> int:
        return self.chunk_lo.shape[0]

    @property
    def num_gaussians(self) -> int:
        """Padded resident count (= num_chunks * leaf_size)."""
        return self.gaussians.positions.shape[0]

    @property
    def compressed(self) -> bool:
        return isinstance(self.gaussians, QuantizedGaussianParams)

    def memory_stats(self) -> dict:
        """Resident-byte accounting (fields, SH bands, ratio vs f32)."""
        if self.compressed:
            stats = quantized_memory_stats(self.gaussians)
        else:
            stats = f32_memory_stats(self.gaussians)
        stats["aabb_bytes"] = int(self.chunk_lo.nbytes + self.chunk_hi.nbytes)
        stats["num_chunks"] = self.num_chunks
        return stats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ChunkVisibility:
    """Per-camera, per-chunk culling verdict.

    Attributes:
      visible: (M,) bool — chunk AABB intersects the (margin-expanded)
        view frustum.
      distance: (M,) float — conservative camera distance (to the nearest
        point of the chunk's bounding sphere, clamped at 0).
      sh_degree: (M,) int32 — LOD band from ``lod_thresholds`` (3 under
        the near threshold, 1 under the far one, 0 beyond).
    """

    visible: jax.Array
    distance: jax.Array
    sh_degree: jax.Array


# ---------------------------------------------------------------------------
# Tree construction (host-side, once per scene)
# ---------------------------------------------------------------------------


def _part1by2(v: np.ndarray) -> np.ndarray:
    """Spread the low 10 bits of v so they occupy every third bit."""
    v = v.astype(np.uint64) & 0x3FF
    v = (v | (v << 16)) & 0x030000FF
    v = (v | (v << 8)) & 0x0300F00F
    v = (v | (v << 4)) & 0x030C30C3
    v = (v | (v << 2)) & 0x09249249
    return v


def morton_codes(positions: np.ndarray) -> np.ndarray:
    """(N, 3) positions -> (N,) 30-bit Morton (Z-order) codes.

    Quantized on the positions' own AABB; degenerate axes collapse to 0.
    """
    # Deliberate f64: quantizing the AABB in f64 keeps the 10-bit-per-axis
    # bin edges stable for clouds whose extent dwarfs f32 resolution; only
    # integer codes leave this function.
    pos = np.asarray(positions, dtype=np.float64)  # reprolint: disable=dtype-discipline
    lo = pos.min(axis=0)
    span = pos.max(axis=0) - lo
    span = np.where(span > 0, span, 1.0)
    q = ((pos - lo) / span * ((1 << _MORTON_BITS) - 1)).astype(np.uint64)
    return (
        _part1by2(q[:, 0])
        | (_part1by2(q[:, 1]) << 1)
        | (_part1by2(q[:, 2]) << 2)
    )


def build_scene_tree(
    g: GaussianParams, leaf_size: int = 256, *, compress: str = "none"
) -> SceneTree:
    """Build the static chunk hierarchy for a Gaussian cloud.

    Host-side (called once per scene, e.g. at server startup): Morton codes
    and the sort permutation are computed in numpy; the permutation itself
    is applied as a jnp gather, so the resident ``tree.gaussians`` stays
    differentiable w.r.t. ``g`` (the permutation is a constant).

    The cloud is padded to a whole number of chunks with invisible records
    (below the alpha floor — see ``gaussians.pad_to_multiple``); only the
    final chunk can contain padding, and its AABB ignores the padded rows.

    ``compress="int8"`` stores the resident cloud quantized
    (``core.quant``), one quantization chunk per tree leaf — Morton
    chunks are spatially coherent, so the per-chunk scales track local
    statistics, and the culled gather moves whole chunks so the scales
    travel with them. Chunk AABBs then use the *dequantized* support radii:
    conservative w.r.t. what the decode-in-kernel raster actually renders.
    """
    if leaf_size <= 0:
        raise ValueError(f"leaf_size must be positive, got {leaf_size}")
    if compress not in COMPRESS_MODES:
        raise ValueError(
            f"compress must be one of {COMPRESS_MODES}, got {compress!r}"
        )
    n = g.num_gaussians
    if n == 0:
        raise ValueError("cannot build a scene tree over an empty cloud")

    codes = morton_codes(np.asarray(jax.device_get(g.positions)))
    perm = np.argsort(codes, kind="stable").astype(np.int32)

    permuted = jax.tree.map(lambda x: x[jnp.asarray(perm)], g)
    padded, _ = pad_to_multiple(permuted, leaf_size)
    n_pad = padded.num_gaussians
    m = n_pad // leaf_size

    gaussians: GaussianParams | QuantizedGaussianParams = padded
    log_scales = padded.log_scales
    if compress == "int8":
        gaussians = quantize_gaussians(padded, leaf_size)
        log_scales, _ = dequantize_geometry(gaussians)

    # Conservative per-Gaussian support radius; padded rows are excluded
    # from the chunk AABBs (their -10 log-scale would not hurt, but their
    # zero position would).
    pos = padded.positions.reshape(m, leaf_size, 3)
    radius = (AABB_SIGMA * jnp.exp(log_scales).max(axis=-1)).reshape(
        m, leaf_size, 1
    )
    valid = (jnp.arange(n_pad, dtype=jnp.int32) < n).reshape(m, leaf_size, 1)
    big = jnp.asarray(jnp.finfo(pos.dtype).max, pos.dtype)
    lo = jnp.min(jnp.where(valid, pos - radius, big), axis=1)
    hi = jnp.max(jnp.where(valid, pos + radius, -big), axis=1)

    return SceneTree(
        gaussians=gaussians,
        chunk_lo=lo,
        chunk_hi=hi,
        leaf_size=leaf_size,
        num_real=n,
    )


# ---------------------------------------------------------------------------
# Per-camera culling + LOD (jit/vmap-friendly)
# ---------------------------------------------------------------------------


def cull_chunks(
    tree: SceneTree,
    cam: Camera,
    *,
    lod_thresholds: tuple[float, float] | None = None,
    margin_px: float = FRUSTUM_MARGIN_PX,
) -> ChunkVisibility:
    """Frustum-test every chunk AABB against one camera.

    The AABB is transformed to camera space in center/half-extent form
    (``e_cam = |R| e`` — conservative under rotation) and tested against
    the five frustum planes: near (``z > NEAR_PLANE``) and the four side
    planes, whose tangents are widened by ``margin_px / focal`` so a
    Gaussian whose screen support pokes in from off-frustum is never
    culled (the AABB already carries the 3-sigma world pad; the margin
    covers the screen-space blur + rounding).

    Distance (to the chunk's bounding sphere) drives the LOD band:
    ``lod_thresholds = (near, far)`` selects SH degree 3 below ``near``,
    1 below ``far``, 0 beyond; ``None`` pins every chunk to degree 3.
    """
    center = 0.5 * (tree.chunk_lo + tree.chunk_hi)
    half = 0.5 * (tree.chunk_hi - tree.chunk_lo)

    c_cam = center @ cam.r_cw.T + cam.t_cw  # (M, 3)
    e_cam = half @ jnp.abs(cam.r_cw).T  # (M, 3) conservative extents

    tanx, tany = cam.tan_fov()
    # tan_fov is the symmetric half-angle; an off-center principal point
    # (real COLMAP captures) widens one side of the frustum beyond it, so
    # widen both sides by the offset to stay conservative.
    tx = tanx + jnp.abs(cam.cx - 0.5 * cam.width) / cam.fx + margin_px / cam.fx
    ty = tany + jnp.abs(cam.cy - 0.5 * cam.height) / cam.fy + margin_px / cam.fy

    cx, cy, cz = c_cam[:, 0], c_cam[:, 1], c_cam[:, 2]
    ex, ey, ez = e_cam[:, 0], e_cam[:, 1], e_cam[:, 2]

    near_ok = cz + ez > NEAR_PLANE
    # Side planes through the camera center with inward normals
    # (±1, 0, tan) / (0, ±1, tan): the AABB is inside-or-crossing iff the
    # farthest-inside corner (n·c + Σ|n_i| e_i) is non-negative.
    slack_x = ex + tx * ez
    slack_y = ey + ty * ez
    left_ok = cx + tx * cz + slack_x >= 0
    right_ok = -cx + tx * cz + slack_x >= 0
    top_ok = cy + ty * cz + slack_y >= 0
    bot_ok = -cy + ty * cz + slack_y >= 0
    visible = near_ok & left_ok & right_ok & top_ok & bot_ok

    sphere_r = jnp.linalg.norm(half, axis=-1)
    dist = jnp.maximum(
        jnp.linalg.norm(center - cam.cam_pos, axis=-1) - sphere_r, 0.0
    )

    if lod_thresholds is None:
        degree = jnp.full(dist.shape, 3, dtype=jnp.int32)
    else:
        near_t, far_t = lod_thresholds
        degree = jnp.where(
            dist < near_t,
            jnp.int32(3),
            jnp.where(dist < far_t, jnp.int32(1), jnp.int32(0)),
        )
    return ChunkVisibility(visible=visible, distance=dist, sh_degree=degree)


def select_visible_chunks(
    vis: ChunkVisibility, capacity: int
) -> tuple[jax.Array, jax.Array]:
    """Compact the visible set into a static-capacity chunk index list.

    The chunk-level twin of ``binning.bin_gaussians``' front-most-K
    selection: sort the (stop-gradiented) ``visible -> distance`` key and
    take the prefix, so on overflow the *nearest* visible chunks win;
    padding slots carry the sentinel ``M`` (one past the last chunk).

    Distance decides only **which** chunks survive — the survivors are
    re-sorted by chunk index, so the gathered compact set preserves the
    resident (Morton) order. That keeps the downstream depth sort's
    tie-breaking identical to an uncull render of the same tree: f32
    depth ties are real at 1e5+ Gaussians, and equal-depth Gaussians
    blended in a different order would break the culled == uncull
    equality contract.

    Returns ``(chunk_idx (capacity,) int32, num_visible () int32)``.
    ``num_visible`` is the pre-clamp count — callers can detect overflow
    (``num_visible > capacity`` means far chunks were dropped and the
    render is no longer conservative).
    """
    m = vis.visible.shape[0]
    cap = min(capacity, m)
    key = jnp.where(
        vis.visible, jax.lax.stop_gradient(vis.distance), jnp.inf
    )
    order = jnp.argsort(key).astype(jnp.int32)
    sel = order[:cap]
    chunk_idx = jnp.where(vis.visible[sel], sel, jnp.int32(m))
    return jnp.sort(chunk_idx), jnp.sum(vis.visible).astype(jnp.int32)


def _append_invisible(g: GaussianParams) -> GaussianParams:
    """Append one sentinel record that no blend path can see.

    Mirrors ``pad_to_multiple``'s padding: opacity sigmoid(-30) is ~1e-13,
    far below the rasterizer's 1/255 alpha floor, and the feature
    pipeline's mask additionally culls sub-floor opacities outright — so
    a sentinel contributes exactly zero color/alpha everywhere (pinned by
    tests/test_scene.py).
    """

    def pad1(x, fill):
        widths = [(0, 1)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    return GaussianParams(
        positions=pad1(g.positions, 0.0),
        quats=pad1(g.quats, 1.0),
        log_scales=pad1(g.log_scales, -10.0),
        sh=pad1(g.sh, 0.0),
        opacity_logit=pad1(g.opacity_logit, -30.0),
    )


# Decode-scale row a sentinel chunk gathers: codes -127 for log scales and
# opacity then decode to ~(-10, -30) — the invisible record — and the SH
# band scales are the guarded fallback (codes are 0 -> exact zero color).
_SENTINEL_SCALE_ROW = (10.0 / 127.0, 30.0 / 127.0, 1.0, 1.0, 1.0)


def _append_invisible_q(qg: QuantizedGaussianParams) -> QuantizedGaussianParams:
    """Quantized twin of :func:`_append_invisible` (per-Gaussian fields only;
    the sentinel *scale row* is gathered chunk-granularly in
    :func:`gather_visible`)."""

    def pad1(x, fill):
        widths = [(0, 1)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    return dataclasses.replace(
        qg,
        positions=pad1(qg.positions, 0.0),
        quats=pad1(qg.quats, 1.0),
        log_scales_q=pad1(qg.log_scales_q, -127),
        opacity_q=pad1(qg.opacity_q, -127),
        sh_dc=pad1(qg.sh_dc, 0.0),
        sh_rest_q=pad1(qg.sh_rest_q, 0),
    )


def gather_visible(
    tree: SceneTree, chunk_idx: jax.Array
) -> tuple[GaussianParams | QuantizedGaussianParams, jax.Array]:
    """Gather the selected chunks into one compact cloud.

    ``chunk_idx`` is the static-capacity sentinel-padded list from
    :func:`select_visible_chunks`; every sentinel slot's ``leaf_size``
    rows gather the appended invisible record. Differentiable w.r.t. the
    resident cloud (the gather's VJP scatter-adds per-chunk gradients
    back), the indices are discrete.

    A quantized tree gathers quantized chunks: per-Gaussian planes row-wise
    like the f32 fields, the (M, 5) scale table chunk-granularly (one row
    per selected slot; sentinels get :data:`_SENTINEL_SCALE_ROW`). The
    gather moves whole chunks, so every lane stays next to its own decode
    scales — ``dequantize(gather(qg)) == gather(dequantize(qg))`` on all
    visible lanes.

    Returns ``(params (capacity * leaf_size, ...), valid (capacity,)
    bool)`` — ``valid`` marks real (non-sentinel) chunk slots.
    """
    leaf = tree.leaf_size
    m = tree.num_chunks
    n_pad = tree.num_gaussians
    valid = chunk_idx < m
    rows = chunk_idx[:, None] * leaf + jnp.arange(leaf, dtype=jnp.int32)
    # Sentinel chunks (index M) land exactly at n_pad .. n_pad + leaf - 1;
    # clamp them onto the single appended invisible record.
    rows = jnp.minimum(rows, jnp.int32(n_pad)).reshape(-1)
    if tree.compressed:
        qg_pad = _append_invisible_q(tree.gaussians)
        scales_pad = jnp.concatenate(
            [
                tree.gaussians.scales,
                jnp.asarray(_SENTINEL_SCALE_ROW, jnp.float32)[None, :],
            ],
            axis=0,
        )
        gathered = QuantizedGaussianParams(
            positions=qg_pad.positions[rows],
            quats=qg_pad.quats[rows],
            log_scales_q=qg_pad.log_scales_q[rows],
            opacity_q=qg_pad.opacity_q[rows],
            sh_dc=qg_pad.sh_dc[rows],
            sh_rest_q=qg_pad.sh_rest_q[rows],
            scales=scales_pad[jnp.minimum(chunk_idx, jnp.int32(m))],
            chunk_size=leaf,
            num_real=chunk_idx.shape[0] * leaf,
        )
        return gathered, valid
    g_pad = _append_invisible(tree.gaussians)
    return jax.tree.map(lambda x: x[rows], g_pad), valid


def apply_sh_lod(sh: jax.Array, degree: jax.Array) -> jax.Array:
    """Zero SH coefficients above each Gaussian's LOD degree.

    ``sh`` is (..., 16, 3), ``degree`` broadcasts over the leading axes.
    Zeroing bands k >= (degree+1)^2 makes the full degree-3 evaluator
    return exactly the degree-``d`` color (each basis function multiplies
    its own coefficient), so LOD composes with every feature path without
    a second executable.
    """
    nb = (degree + 1) ** 2
    keep = jnp.arange(sh.shape[-2], dtype=nb.dtype) < nb[..., None]
    return sh * keep[..., None].astype(sh.dtype)


# ---------------------------------------------------------------------------
# Render-stack entry point
# ---------------------------------------------------------------------------


def resolve_scene_banded(
    scene: "SceneTree | GaussianParams",
    cam: Camera | None,
    config: RenderConfig,
) -> tuple[GaussianParams | QuantizedGaussianParams, jnp.ndarray | None]:
    """The render stack's scene adapter: tree + camera -> compact params.

    * plain ``GaussianParams`` pass through untouched;
    * a :class:`SceneTree` with ``config.cull`` disabled renders its full
      resident (Morton-permuted) cloud — same image as the raw cloud, the
      permutation only reorders depth-sort ties;
    * with ``config.cull`` the tree is frustum-culled against ``cam``,
      the visible chunks (nearest-first under ``config.visible_capacity``)
      are gathered to a compact static-shape cloud, and — when
      ``config.lod_thresholds`` is set — each chunk's SH coefficients are
      banded down by camera distance.

    ``config.compress="int8"`` interacts two ways:

    * a tree whose resident cloud is already quantized passes the
      :class:`QuantizedGaussianParams` through (full or culled-gathered) —
      no ``apply_sh_lod``: the fused path gates the *decode* per band, and
      f32 consumers go through :func:`resolve_scene_f32`;
    * an f32 scene gets the straight-through estimator
      (``quant.quantize_dequantize``) — the rendered cloud is exactly the
      dequantized quantization, gradients land on the f32 masters. Applied
      *before* LOD banding and on whole gathered chunks, so the STE render
      is bitwise the image a quantized-resident tree would produce.

    Returns ``(params, band)``: ``band`` is the per-Gaussian int32 SH LOD
    degree when distance LOD applied, else None. The fused raster path
    feeds ``band`` to its kernel, which then *skips* the above-band basis
    FLOPs that the zeroed coefficients would have multiplied; every other
    path can ignore it (``params.sh`` is already banded by
    ``apply_sh_lod``, so rendering is unchanged either way).

    Pure static-shape jnp after tree construction, so it traces inside
    ``jit``/``vmap``/``shard_map``: per-camera culling lives *inside* the
    existing executables (one compile per capacity, any camera).
    """
    ste = config.compress != "none"
    if not isinstance(scene, SceneTree):
        if ste:
            return quantize_dequantize(scene, config.leaf_size), None
        return scene, None
    ste = ste and not scene.compressed
    if not config.cull:
        g = scene.gaussians
        if ste:
            g = quantize_dequantize(g, scene.leaf_size)
        return g, None
    if cam is None:
        raise ValueError("config.cull needs a camera to cull against")
    vis = cull_chunks(scene, cam, lod_thresholds=config.lod_thresholds)
    capacity = config.visible_capacity or scene.num_chunks
    chunk_idx, _ = select_visible_chunks(vis, capacity)
    g, _ = gather_visible(scene, chunk_idx)
    if ste:
        # Gathered slots are whole leaves, so re-quantizing here sees each
        # chunk's exact resident statistics (sentinel chunks quantize to
        # the sentinel scale row) — same codes, same scales, same decode.
        g = quantize_dequantize(g, scene.leaf_size)
    if config.lod_thresholds is None:
        return g, None
    # Per-Gaussian degree: the owning chunk's band (sentinels -> 0),
    # clamped by the global static degree knob.
    deg_pad = jnp.concatenate(
        [vis.sh_degree, jnp.zeros((1,), jnp.int32)]
    )
    deg = jnp.minimum(deg_pad[chunk_idx], jnp.int32(config.sh_degree))
    deg = jnp.repeat(
        deg,
        scene.leaf_size,
        total_repeat_length=deg.shape[0] * scene.leaf_size,
    )
    if not isinstance(g, QuantizedGaussianParams):
        g = dataclasses.replace(g, sh=apply_sh_lod(g.sh, deg))
    return g, deg


def resolve_scene(
    scene: "SceneTree | GaussianParams",
    cam: Camera | None,
    config: RenderConfig,
) -> GaussianParams | QuantizedGaussianParams:
    """:func:`resolve_scene_banded` for callers that only need the params."""
    return resolve_scene_banded(scene, cam, config)[0]


def resolve_scene_f32(
    scene: "SceneTree | GaussianParams",
    cam: Camera | None,
    config: RenderConfig,
) -> GaussianParams:
    """:func:`resolve_scene` guaranteed to yield f32 ``GaussianParams``.

    The adapter for the non-fused feature paths (staged/Pallas feature
    kernels, binned batch renderer), which consume f32 records: a quantized
    resolve is dequantized in jnp — the same ``q * scale`` decode the fused
    kernel performs — and distance LOD is applied via ``apply_sh_lod``
    (the quantized resolve defers it, since quantized storage is not
    pre-zeroed above band).
    """
    g, band = resolve_scene_banded(scene, cam, config)
    if isinstance(g, QuantizedGaussianParams):
        g = dequantize_gaussians(g)
        if band is not None:
            g = dataclasses.replace(g, sh=apply_sh_lod(g.sh, band))
    return g


def visibility_stats(
    tree: SceneTree, cam: Camera, config: RenderConfig
) -> dict:
    """Host-side culling summary for one camera (benchmarks/examples)."""
    vis = cull_chunks(tree, cam, lod_thresholds=config.lod_thresholds)
    visible = np.asarray(jax.device_get(vis.visible))
    degree = np.asarray(jax.device_get(vis.sh_degree))
    capacity = config.visible_capacity or tree.num_chunks
    num_visible = int(visible.sum())
    return {
        "num_chunks": int(visible.size),
        "num_visible": num_visible,
        "visible_fraction": num_visible / max(1, visible.size),
        "capacity": int(capacity),
        "overflowed": num_visible > capacity,
        "degree_counts": {
            str(d): int(((degree == d) & visible).sum()) for d in (0, 1, 3)
        },
    }
