"""RenderConfig — the single configuration record for the render stack.

Every knob that used to travel as a loose kwarg (``feature_path=...``,
``sh_degree=...``, ``pixel_chunk=...``) lives here. The dataclass is frozen
(hashable), so it can be passed as a *static* argument to ``jax.jit`` — one
compiled executable per distinct configuration, exactly like the old
``static_argnames`` strings but typo-proof and threadable through every layer
(render -> pipeline -> training -> serving -> benchmarks).

Paths:

* ``feature_path``: how per-Gaussian screen-space features are computed
  (the paper's method ladder) — ``naive`` | ``staged`` | ``fused`` |
  ``pallas``.
* ``raster_path``: how features become pixels — ``dense`` (the O(P*G)
  oracle blend), ``binned`` (tile-binned lists, O(P * G_visible_per_tile)),
  ``pallas`` (block-list Pallas TPU kernel, forward-only),
  ``pallas_binned`` (gather-to-compact per-tile Gaussian lists + custom
  VJP — the fast *and* trainable Pallas path), or ``pallas_fused``
  (feature computation folded *into* the blend kernel: per-tile raw
  Gaussian records stream through projection/covariance/SH directly into
  alpha blending with in-kernel early exit and banded SH — subsumes
  ``feature_path``, which only the geometry pre-pass ignores).
"""

from __future__ import annotations

import dataclasses

FEATURE_PATHS = ("naive", "staged", "fused", "pallas")
RASTER_PATHS = ("dense", "binned", "pallas", "pallas_binned", "pallas_fused")
COMPRESS_MODES = ("none", "int8")


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    """Configuration for the full render stack (hashable -> jit-static).

    Attributes:
      feature_path: feature-computation ladder rung (see module docstring).
      raster_path: rasterization strategy (see module docstring).
      tile_size: screen-tile edge in pixels for the binned/pallas paths.
      tile_capacity: max Gaussians kept per tile list (front-most win on
        overflow). Clamped to the scene size at trace time.
      sh_degree: spherical-harmonics degree for view-dependent color.
      background: RGB background color (tuple, so the config stays hashable).
      pixel_chunk: dense-path pixel chunking (peak-memory bound); None = one
        shot over all pixels.
      tile_chunk: binned-path tile chunking (peak-memory bound); None = all
        tiles in one vmapped pass.
      block_g: Gaussian block width for the pallas raster paths (lane dim;
        also the compacted-chunk width of the pallas_binned path).
      max_blocks_per_tile: static cap on the pallas path's per-tile block
        list (front-most blocks win on overflow, like tile_capacity). None =
        no cap: exact, but every tile's grid then spans all blocks and the
        kernel saves DMA traffic only, not trip count.
      early_exit: binned-path early termination — a tile chunk's scan over
        its list stops once every pixel's transmittance saturates below
        1/255 or the remaining list entries are all sentinels. The sentinel
        skip is exact; the saturation skip can only drop contributions a
        u8 pixel cannot represent (error < 1/255). The pallas_fused path
        implements the saturation skip *in-kernel*: its chunk loop
        terminates and the remaining chunks are never executed.
      cull: enable per-camera frustum culling when the render entry points
        are handed a ``repro.core.scene.SceneTree`` instead of raw
        ``GaussianParams`` — only the visible chunks' Gaussians are
        gathered, featured, and binned. Ignored for raw clouds.
      visible_capacity: static capacity (in *chunks*) of the culled
        compact set. None = the tree's full chunk count (conservative:
        nothing is ever dropped, the gather only reorders). Smaller values
        bound the per-camera compute; on overflow the nearest visible
        chunks win.
      lod_thresholds: ``(near, far)`` camera-distance cutoffs for the
        distance-based SH level of detail: chunks nearer than ``near``
        keep SH degree 3, chunks nearer than ``far`` drop to degree 1,
        everything beyond renders degree 0 (DC color only). None disables
        LOD (every chunk uses ``sh_degree``).
      leaf_size: Gaussians per scene-tree chunk when a component (e.g.
        the render server) builds the tree itself from this config.
      compress: resident-scene compression mode — ``"none"`` (f32) or
        ``"int8"`` (per-chunk int8/fp16 storage, ``core.quant``). Scene
        trees built under this config store the cloud quantized and the
        fused raster path decodes it in-kernel; raw f32 clouds render
        through the straight-through estimator (the quantized image,
        gradients to the f32 masters).
      collect_stats: opt-in pipeline diagnostics (``repro.obs``). On the
        ``pallas_fused`` path, ``core.render.render_with_stats`` makes the
        kernel emit a per-tile diagnostics plane (chunks processed before
        early exit, lanes blended, max SH band decoded) alongside the
        image — which stays bitwise-identical (pure side output). Other
        paths report host-side binning/occupancy stats. ``render`` itself
        ignores the flag (the image never depends on it); it exists on the
        config so servers/benchmarks can thread one switch end to end.
    """

    feature_path: str = "fused"
    raster_path: str = "binned"
    tile_size: int = 16
    # 512 keeps typical scenes exact vs the dense oracle (overflow drops
    # back-most Gaussians); lower it to trade fidelity for speed.
    tile_capacity: int = 512
    sh_degree: int = 3
    background: tuple[float, float, float] = (0.0, 0.0, 0.0)
    pixel_chunk: int | None = 4096
    tile_chunk: int | None = 64
    block_g: int = 128
    max_blocks_per_tile: int | None = None
    early_exit: bool = True
    cull: bool = False
    visible_capacity: int | None = None
    lod_thresholds: tuple[float, float] | None = None
    leaf_size: int = 256
    compress: str = "none"
    collect_stats: bool = False

    def __post_init__(self) -> None:
        if self.feature_path not in FEATURE_PATHS:
            raise ValueError(
                f"feature_path={self.feature_path!r} not in {FEATURE_PATHS}"
            )
        if self.raster_path not in RASTER_PATHS:
            raise ValueError(
                f"raster_path={self.raster_path!r} not in {RASTER_PATHS}"
            )
        if self.tile_size <= 0:
            raise ValueError(f"tile_size must be positive, got {self.tile_size}")
        if self.tile_capacity <= 0:
            raise ValueError(
                f"tile_capacity must be positive, got {self.tile_capacity}"
            )
        if self.visible_capacity is not None and self.visible_capacity <= 0:
            raise ValueError(
                f"visible_capacity must be positive or None, got "
                f"{self.visible_capacity}"
            )
        if self.leaf_size <= 0:
            raise ValueError(
                f"leaf_size must be positive, got {self.leaf_size}"
            )
        if self.compress not in COMPRESS_MODES:
            raise ValueError(
                f"compress={self.compress!r} not in {COMPRESS_MODES}"
            )
        if self.lod_thresholds is not None:
            near, far = self.lod_thresholds
            if not (0.0 <= near <= far):
                raise ValueError(
                    "lod_thresholds must be (near, far) with "
                    f"0 <= near <= far, got {self.lod_thresholds}"
                )
            object.__setattr__(
                self, "lod_thresholds", (float(near), float(far))
            )
        # Normalize background to a plain float tuple so two configs built
        # from a list and a tuple hash identically.
        object.__setattr__(
            self, "background", tuple(float(c) for c in self.background)
        )

    def replace(self, **kw) -> "RenderConfig":
        return dataclasses.replace(self, **kw)


# The library-wide default configuration.
DEFAULT_CONFIG = RenderConfig()

# Sentinel distinguishing "kwarg not passed" from an explicit None (e.g.
# ``pixel_chunk=None`` legitimately means "no chunking").
UNSET = object()


def as_config(
    config: "RenderConfig | None",
    **overrides,
) -> RenderConfig:
    """Coerce ``config`` (or the default) with the given overrides applied.

    The deprecation shim for the old kwarg-style API: callers that still pass
    ``feature_path=...`` / ``sh_degree=...`` etc. get them folded into a
    RenderConfig here. Overrides equal to :data:`UNSET` are ignored.
    """
    base = config if config is not None else DEFAULT_CONFIG
    clean = {k: v for k, v in overrides.items() if v is not UNSET}
    # (background sequences are normalized to tuples by __post_init__.)
    return base.replace(**clean) if clean else base
