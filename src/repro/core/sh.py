"""Real spherical harmonics, degree <= 3 (16 basis functions).

Constants follow the INRIA 3DGS reference implementation, so the paper's
``color`` kernel (Eq. 3) is reproduced bit-for-bit in fp32:
    c(r) = clamp( 0.5 + sum_k sh[k] * Y_k(r), 0 )
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

SH_C0 = 0.28209479177387814
SH_C1 = 0.4886025119029199
SH_C2 = (
    1.0925484305920792,
    -1.0925484305920792,
    0.31539156525252005,
    -1.0925484305920792,
    0.5462742152960396,
)
SH_C3 = (
    -0.5900435899266435,
    2.890611442640554,
    -0.4570457994644658,
    0.3731763325901154,
    -0.4570457994644658,
    1.445305721320277,
    -0.5900435899266435,
)

NUM_BASES = 16


def sh_basis(dirs: jax.Array) -> jax.Array:
    """Evaluate the 16 real SH basis functions at unit directions.

    Args:
      dirs: (..., 3) unit vectors.

    Returns:
      (..., 16) basis values, ordered (l, m) = (0,0), (1,-1), (1,0), (1,1),
      (2,-2) ... (3,3) — matching the 3DGS coefficient layout.
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    xx, yy, zz = x * x, y * y, z * z
    xy, yz, xz = x * y, y * z, x * z

    b = [
        jnp.full_like(x, SH_C0),
        -SH_C1 * y,
        SH_C1 * z,
        -SH_C1 * x,
        SH_C2[0] * xy,
        SH_C2[1] * yz,
        SH_C2[2] * (2.0 * zz - xx - yy),
        SH_C2[3] * xz,
        SH_C2[4] * (xx - yy),
        SH_C3[0] * y * (3.0 * xx - yy),
        SH_C3[1] * xy * z,
        SH_C3[2] * y * (4.0 * zz - xx - yy),
        SH_C3[3] * z * (2.0 * zz - 3.0 * xx - 3.0 * yy),
        SH_C3[4] * x * (4.0 * zz - xx - yy),
        SH_C3[5] * z * (xx - yy),
        SH_C3[6] * x * (xx - 3.0 * yy),
    ]
    return jnp.stack(b, axis=-1)


def eval_sh_color(sh: jax.Array, dirs: jax.Array, degree: int = 3) -> jax.Array:
    """View-dependent color from SH coefficients (paper Eq. 3).

    Args:
      sh:   (..., 16, 3) coefficients.
      dirs: (..., 3) unit view directions (Gaussian center - camera center).
      degree: max SH degree actually used (0..3); higher coefficients ignored.

    Returns:
      (..., 3) colors, shifted by +0.5 and clamped at 0 (reference behavior).
    """
    nb = (degree + 1) ** 2
    basis = sh_basis(dirs)[..., :nb]
    rgb = jnp.einsum("...k,...kc->...c", basis, sh[..., :nb, :])
    return jnp.maximum(rgb + 0.5, 0.0)
