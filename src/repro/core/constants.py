"""Blending-contract constants — the single import site for the alpha floor.

Every blend path (the dense jnp oracle, the chunked binned scan, the Pallas
tile kernels, and the fused feature→blend kernel) must agree *exactly* on
which Gaussians contribute and how much, or the exactness contracts between
them break. The three numbers that define that agreement live here:

* :data:`ALPHA_EPS` — the alpha floor. A Gaussian whose blended alpha at a
  pixel is below one u8 quantization step is dropped; the feature pipeline
  additionally mask-culls any Gaussian whose *opacity* is below it (alpha
  <= opacity, so it could never pass the floor).
* :data:`ALPHA_MAX` — the alpha cap (the reference implementation's 0.99
  clamp, which keeps transmittance strictly positive so the front-to-back
  product never hard-zeros).
* :data:`EARLY_EXIT_EPS` — the transmittance-saturation cutoff: once every
  pixel of a tile has transmittance below one u8 step, whatever remains
  behind cannot move a u8 pixel, so chunked blenders stop early. Kept equal
  to ALPHA_EPS by construction but named separately: the floor is part of
  the *exact* blend definition, the saturation exit is an approximation
  whose error bound is this constant.

``features.ALPHA_EPS``, ``rasterize.ALPHA_MAX`` and
``binning.EARLY_EXIT_EPS`` re-export these for backward compatibility.
"""

from __future__ import annotations

# Blending alpha floor: one u8 quantization step.
ALPHA_EPS = 1.0 / 255.0

# Blending alpha cap (reference 3DGS clamps alpha at 0.99).
ALPHA_MAX = 0.99

# Transmittance-saturation early-exit threshold (see module docstring).
EARLY_EXIT_EPS = 1.0 / 255.0
