"""3DGS training substrate: loss (L1 + D-SSIM), densification, pruning.

The paper trains Gaussians with the standard 3DGS recipe ("custom training
code" on the INRIA tandt_db dataset); this module implements that recipe in
JAX with *fixed-capacity* functional densification so every step is jittable
(no shape polymorphism — required for the multi-device training path).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.config import RenderConfig
from repro.core.gaussians import GaussianParams

# ---------------------------------------------------------------------------
# SSIM + loss
# ---------------------------------------------------------------------------


def _gaussian_window(size: int = 11, sigma: float = 1.5) -> jax.Array:
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x * x) / (2.0 * sigma * sigma))
    g = g / g.sum()
    return jnp.outer(g, g)


def ssim(img0: jax.Array, img1: jax.Array, *, window_size: int = 11) -> jax.Array:
    """Mean SSIM between two (H, W, C) images (per-channel depthwise window)."""
    c1, c2 = 0.01**2, 0.03**2
    channels = img0.shape[-1]
    win = _gaussian_window(window_size)
    # Depthwise conv: NHWC, HWIO with feature_group_count=C.
    kernel = jnp.tile(win[:, :, None, None], (1, 1, 1, channels))

    def filt(x):
        return jax.lax.conv_general_dilated(
            x[None],
            kernel,
            window_strides=(1, 1),
            padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=channels,
        )[0]

    mu0, mu1 = filt(img0), filt(img1)
    mu00, mu11, mu01 = mu0 * mu0, mu1 * mu1, mu0 * mu1
    s00 = filt(img0 * img0) - mu00
    s11 = filt(img1 * img1) - mu11
    s01 = filt(img0 * img1) - mu01
    num = (2.0 * mu01 + c1) * (2.0 * s01 + c2)
    den = (mu00 + mu11 + c1) * (s00 + s11 + c2)
    return jnp.mean(num / den)


def gsplat_loss(
    rendered: jax.Array, target: jax.Array, *, lambda_dssim: float = 0.2
) -> jax.Array:
    """(1 - lambda) * L1 + lambda * D-SSIM — the 3DGS training loss."""
    l1 = jnp.mean(jnp.abs(rendered - target))
    dssim = (1.0 - ssim(rendered, target)) / 2.0
    return (1.0 - lambda_dssim) * l1 + lambda_dssim * dssim


def render_loss(
    params: GaussianParams,
    cam,
    target: jax.Array,
    config: RenderConfig | None = None,
    *,
    lambda_dssim: float = 0.2,
) -> jax.Array:
    """Render one view under ``config`` and score it against ``target``.

    The differentiable objective for a training step; the RenderConfig picks
    the feature and raster paths. Every raster path except the forward-only
    block-list ``"pallas"`` kernel trains: the binned path differentiates
    through the per-tile gathers, and ``"pallas_binned"`` through the
    compact kernel's custom VJP (gradients match the jnp binned path).
    """
    from repro.core.render import render  # late: render imports this module's peers

    img = render(params, cam, config)
    return gsplat_loss(img, target, lambda_dssim=lambda_dssim)


def render_loss_batch(
    params: GaussianParams,
    cams,
    targets: jax.Array,
    config: RenderConfig | None = None,
    *,
    lambda_dssim: float = 0.2,
) -> jax.Array:
    """Multi-view objective: mean :func:`gsplat_loss` over a camera batch.

    ``cams`` is a :class:`repro.core.multicam.CameraBatch` and ``targets``
    the matching (C, H, W, 3) ground-truth stack. One training step against
    C views through one compiled executable — gradients are identical (up
    to f32 reassociation) to averaging C per-camera :func:`render_loss`
    calls, but the render runs the batched pipeline (shared model
    residency, cross-camera load-balanced blending).
    """
    from repro.core.multicam import render_batch  # late: imports render

    imgs = render_batch(params, cams, config)
    losses = jax.vmap(
        lambda img, tgt: gsplat_loss(img, tgt, lambda_dssim=lambda_dssim)
    )(imgs, targets)
    return jnp.mean(losses)


# ---------------------------------------------------------------------------
# Densification / pruning state machine (fixed capacity, fully jittable)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DensifyConfig:
    grad_threshold: float = 2e-4  # avg screen-space grad norm to densify
    split_scale_threshold: float = 0.05  # world extent above which we split
    split_shrink: float = 1.6  # reference: new scales = old / 1.6
    min_opacity: float = 0.005  # prune below this
    opacity_reset_value: float = 0.01


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DensifyState:
    """Running statistics between densification events."""

    active: jax.Array  # (N,) bool — slot in use
    grad_accum: jax.Array  # (N,) accumulated ||d(uv)|| per Gaussian
    count: jax.Array  # (N,) number of frames the Gaussian was visible


def init_densify_state(capacity: int, num_initial: int) -> DensifyState:
    active = jnp.arange(capacity, dtype=jnp.int32) < num_initial
    return DensifyState(
        active=active,
        grad_accum=jnp.zeros((capacity,), jnp.float32),
        count=jnp.zeros((capacity,), jnp.float32),
    )


def accumulate_grad_stats(
    state: DensifyState, uv_grad: jax.Array, visible: jax.Array
) -> DensifyState:
    """Accumulate per-Gaussian screen-space gradient norms (3DGS heuristic)."""
    norm = jnp.linalg.norm(uv_grad, axis=-1)
    return DensifyState(
        active=state.active,
        grad_accum=state.grad_accum + norm * visible,
        count=state.count + visible,
    )


def _inverse_sigmoid(x: float) -> float:
    import math

    return math.log(x / (1.0 - x))


def densify_and_prune(
    params: GaussianParams,
    state: DensifyState,
    key: jax.Array,
    cfg: DensifyConfig | None = None,
) -> tuple[GaussianParams, DensifyState]:
    """One densification event: prune -> clone/split into free slots.

    Fixed capacity: new Gaussians are written into inactive slots, highest
    gradient first; if the pool is full, lowest-priority candidates are
    dropped (graceful saturation instead of reallocation).
    """
    cfg = cfg if cfg is not None else DensifyConfig()
    n = params.num_gaussians
    avg_grad = state.grad_accum / jnp.maximum(state.count, 1.0)

    # --- prune ---------------------------------------------------------
    active = state.active & (params.opacities() >= cfg.min_opacity)

    # --- candidate selection --------------------------------------------
    candidates = active & (avg_grad > cfg.grad_threshold)
    max_scale = jnp.max(params.scales(), axis=-1)
    is_split = candidates & (max_scale >= cfg.split_scale_threshold)
    priority = jnp.where(candidates, avg_grad, -jnp.inf)

    # Highest-priority candidates first; free slots in index order.
    cand_order = jnp.argsort(-priority)  # (N,) candidate indices, best first
    free_order = jnp.argsort(active, stable=True)  # inactive slots first
    num_free = jnp.sum(~active)
    num_cand = jnp.sum(candidates)
    k = jnp.minimum(num_free, num_cand)  # dynamic, used via masking

    slot_rank = jnp.arange(n, dtype=jnp.int32)
    write_valid = slot_rank < k  # rank r gets candidate cand_order[r]
    src = cand_order  # (N,) source gaussian per rank
    dst = free_order  # (N,) destination slot per rank

    # New parameters: clones copy; splits sample along the principal axis and
    # shrink. (Principal axis ~ largest-scale column of R.)
    from repro.core.features import quat_to_rotmat

    src_params = jax.tree.map(lambda x: x[src], params)
    rot = quat_to_rotmat(src_params.quats)  # (N, 3, 3)
    axis_idx = jnp.argmax(src_params.log_scales, axis=-1)  # (N,)
    principal = jnp.take_along_axis(
        rot, axis_idx[:, None, None], axis=2
    )[..., 0]  # column axis_idx of R -> (N, 3)
    sigma = jnp.max(src_params.scales(), axis=-1, keepdims=True)
    noise = jax.random.normal(key, (n, 1)) * sigma
    split_src = is_split[src]

    new_positions = jnp.where(
        split_src[:, None],
        src_params.positions + principal * noise,
        src_params.positions,
    )
    new_log_scales = jnp.where(
        split_src[:, None],
        src_params.log_scales - jnp.log(cfg.split_shrink),
        src_params.log_scales,
    )
    new_params = GaussianParams(
        positions=new_positions,
        quats=src_params.quats,
        log_scales=new_log_scales,
        sh=src_params.sh,
        opacity_logit=src_params.opacity_logit,
    )

    # Scatter the first-k ranked writes into their destination slots.
    def scatter(field_old, field_new):
        gathered_old = field_old[dst]
        merged = jnp.where(
            write_valid.reshape((n,) + (1,) * (field_old.ndim - 1)),
            field_new,
            gathered_old,
        )
        return field_old.at[dst].set(merged)

    out_params = GaussianParams(
        positions=scatter(params.positions, new_params.positions),
        quats=scatter(params.quats, new_params.quats),
        log_scales=scatter(params.log_scales, new_params.log_scales),
        sh=scatter(params.sh, new_params.sh),
        opacity_logit=scatter(params.opacity_logit, new_params.opacity_logit),
    )

    # The originals of split Gaussians also shrink (reference behavior).
    shrunk = jnp.where(
        is_split[:, None],
        out_params.log_scales - jnp.log(cfg.split_shrink),
        out_params.log_scales,
    )
    out_params = dataclasses.replace(out_params, log_scales=shrunk)

    new_active = active.at[dst].set(active[dst] | write_valid)
    new_state = DensifyState(
        active=new_active,
        grad_accum=jnp.zeros_like(state.grad_accum),
        count=jnp.zeros_like(state.count),
    )
    # Deactivated slots are made invisible.
    out_params = dataclasses.replace(
        out_params,
        opacity_logit=jnp.where(new_active, out_params.opacity_logit, -30.0),
    )
    return out_params, new_state


def reset_opacity(
    params: GaussianParams, state: DensifyState, cfg: DensifyConfig | None = None
) -> GaussianParams:
    """Clamp opacity down periodically (reference: fights floaters)."""
    cfg = cfg if cfg is not None else DensifyConfig()
    cap = _inverse_sigmoid(cfg.opacity_reset_value)
    new_logit = jnp.where(
        state.active,
        jnp.minimum(params.opacity_logit, cap),
        params.opacity_logit,
    )
    return dataclasses.replace(params, opacity_logit=new_logit)
