"""Batched multi-camera rendering — C cameras through one compiled executable.

The paper's deployment shape is a trained Gaussian model served against a
*stream* of camera requests, with throughput (not single-frame latency) as
the figure of merit. The per-camera path dispatches one executable per
request; this module renders a whole :class:`CameraBatch` in one jit so the
model is resident once and the batch amortizes dispatch, and — for the
default ``binned`` raster path — schedules work *across* cameras:

* **features + sort** are ``vmap``-ed over the camera axis (batched small
  matmuls instead of C tiny dispatches),
* **binning** uses a sort-based candidate selection (``jnp.sort`` of the
  index-or-sentinel matrix) instead of the per-tile ``top_k`` — the same
  ascending front-most-K lists, picked by a primitive that vectorizes far
  better over a batch,
* **blending** pools all C x T tiles, orders them by list occupancy, and
  feeds :func:`repro.core.binning.blend_tile_chunks` chunks of
  *similarly-loaded* tiles. The chunk scan's sentinel skip then ends each
  chunk at (approximately) its own occupancy instead of the per-camera
  maximum — cross-camera load balancing that a sequential per-camera
  render cannot do, because one camera's 64 tiles give the scheduler
  nothing to balance against.

Per-tile blending math is bitwise identical to the per-camera path (same
gather, same chunk width, same scan order within a tile), so
``render_batch`` matches per-camera ``render`` exactly whenever the skip
predicates are exact (``early_exit=False``; with the saturation skip the
difference is bounded by the usual <1/255 transmittance contract).

The non-binned raster paths (``dense`` oracle, the Pallas kernels —
including the ``pallas_fused`` streaming pipeline, which goes straight from
raw records to pixels inside ``render``) run camera-major through
``lax.map`` inside the same jit: still one compiled executable and one
model residency, without vmapping ``pallas_call``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import binning
from repro.core import rasterize as rast_lib
from repro.core.camera import Camera
from repro.core.config import RenderConfig, as_config
from repro.core.features import GaussianFeatures
from repro.core.gaussians import GaussianParams
from repro.core.scene import SceneTree, resolve_scene_f32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CameraBatch:
    """C pinhole cameras sharing one static image size.

    Field-for-field the stacked version of :class:`repro.core.camera.Camera`
    (array leaves gain a leading camera axis; ``width``/``height`` stay
    static python ints), so a ``vmap``/``lax.map``/``shard_map`` slice of a
    CameraBatch duck-types as a Camera everywhere the render stack consumes
    one. One static image size per batch is the micro-batching contract:
    every batch hits the same compiled executable.
    """

    r_cw: jax.Array  # (C, 3, 3)
    t_cw: jax.Array  # (C, 3)
    fx: jax.Array  # (C,)
    fy: jax.Array  # (C,)
    cx: jax.Array  # (C,)
    cy: jax.Array  # (C,)
    width: int = dataclasses.field(metadata=dict(static=True))
    height: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_cameras(self) -> int:
        return self.r_cw.shape[0]

    @property
    def cam_pos(self) -> jax.Array:
        """World-space camera centers: -R_cw^T t_cw (batched)."""
        return -jnp.einsum("...ji,...j->...i", self.r_cw, self.t_cw)

    def tan_fov(self) -> tuple[jax.Array, jax.Array]:
        return (0.5 * self.width / self.fx, 0.5 * self.height / self.fy)

    def camera(self, i: int) -> Camera:
        """Slice out camera ``i`` as a plain :class:`Camera`."""
        return Camera(
            r_cw=self.r_cw[i],
            t_cw=self.t_cw[i],
            fx=self.fx[i],
            fy=self.fy[i],
            cx=self.cx[i],
            cy=self.cy[i],
            width=self.width,
            height=self.height,
        )


def stack_cameras(cams: Sequence[Camera]) -> CameraBatch:
    """Stack same-sized cameras into a :class:`CameraBatch` (leading axis C)."""
    if not cams:
        raise ValueError("stack_cameras needs at least one camera")
    w, h = cams[0].width, cams[0].height
    for c in cams:
        if (c.width, c.height) != (w, h):
            raise ValueError(
                "all cameras in a batch must share one static image size; "
                f"got {(c.width, c.height)} vs {(w, h)}"
            )
    return CameraBatch(
        r_cw=jnp.stack([c.r_cw for c in cams]),
        t_cw=jnp.stack([c.t_cw for c in cams]),
        fx=jnp.stack([jnp.asarray(c.fx) for c in cams]),
        fy=jnp.stack([jnp.asarray(c.fy) for c in cams]),
        cx=jnp.stack([jnp.asarray(c.cx) for c in cams]),
        cy=jnp.stack([jnp.asarray(c.cy) for c in cams]),
        width=w,
        height=h,
    )


def unstack_cameras(cams: CameraBatch) -> list[Camera]:
    """Inverse of :func:`stack_cameras`."""
    return [cams.camera(i) for i in range(cams.num_cameras)]


# ---------------------------------------------------------------------------
# Batched binning — sort-based front-most-K selection
# ---------------------------------------------------------------------------


def bin_gaussians_batch(
    feats_sorted: GaussianFeatures,
    height: int,
    width: int,
    *,
    tile_size: int = 16,
    capacity: int = binning.DEFAULT_CAPACITY,
    tile_chunk: int | None = 64,
) -> tuple[jax.Array, jax.Array]:
    """Per-camera, per-tile index lists for a (C, G, ...) feature batch.

    A vmap of :func:`repro.core.binning.bin_gaussians` with the
    ``select="sort"`` primitive — identical list contract (ascending
    front-to-back indices, sentinel ``G``, front-most win on overflow), but
    the sorted-prefix selection lowers far better over a camera batch than
    the per-tile ``top_k`` does.

    Returns ``(indices (C, T, K) int32, count (C, T) int32)`` with count
    clamped to K.
    """
    bins = jax.vmap(
        lambda f: binning.bin_gaussians(
            f,
            height,
            width,
            tile_size=tile_size,
            capacity=capacity,
            tile_chunk=tile_chunk,
            select="sort",
        )
    )(feats_sorted)
    return bins.indices, bins.count


# ---------------------------------------------------------------------------
# Pooled, load-balanced batched blend
# ---------------------------------------------------------------------------


def _render_batch_binned(
    g: "GaussianParams | SceneTree",
    cams: CameraBatch,
    cfg: RenderConfig,
    active: jax.Array | None = None,
) -> jax.Array:
    """The batched ``binned`` raster path. Returns (C, H, W, 3).

    ``active`` (C,) bool masks out sentinel slots: an inactive camera's tile
    lists are forced to zero count / all-sentinel indices *before* the pooled
    count-sort, so the blender's sentinel skip ends its chunks after zero
    scan steps — a masked slot skips all blend work and renders the
    background color. (The vmapped features + binning still run at batch
    width; only the blend scales with occupancy.)

    A :class:`~repro.core.scene.SceneTree` with ``cfg.cull`` is culled *per
    camera inside the vmap*: each lane gathers its own compact visible set
    (one static ``visible_capacity``-shaped gather per camera), so the
    vmapped features/sort/binning run at the compact width instead of the
    resident scene size.
    """
    from repro.core.render import compute_features  # late: render imports us

    height, width = cams.height, cams.width
    c = cams.num_cameras

    feats = jax.vmap(
        lambda cam: rast_lib.sort_by_depth(
            compute_features(resolve_scene_f32(g, cam, cfg), cam, cfg)
        )
    )(cams)  # (C, G, ...)
    gn = feats.uv.shape[-2]

    indices, counts = bin_gaussians_batch(
        feats,
        height,
        width,
        tile_size=cfg.tile_size,
        capacity=cfg.tile_capacity,
        tile_chunk=cfg.tile_chunk,
    )  # (C, T, K), (C, T)

    if active is not None:
        act = active.astype(bool)
        counts = jnp.where(act[:, None], counts, 0)
        indices = jnp.where(act[:, None, None], indices, jnp.int32(gn))

    tiles_y, tiles_x = binning.tile_grid_shape(height, width, cfg.tile_size)
    num_tiles = tiles_y * tiles_x
    k = indices.shape[-1]
    tile = cfg.tile_size

    # Flatten the per-camera padded feature tensors into one gather source:
    # camera c's record i lives at row c*(G+1)+i, and every camera's sentinel
    # row c*(G+1)+G is the all-zero record.
    feats_pad = jax.vmap(binning._pad_features)(feats)  # (C, G+1, ...)
    flat_feats = jax.tree.map(
        lambda x: x.reshape((c * (gn + 1),) + x.shape[2:]), feats_pad
    )
    cam_base = (jnp.arange(c, dtype=jnp.int32) * (gn + 1))[:, None, None]
    flat_idx = (indices + cam_base).reshape(c * num_tiles, k)
    flat_counts = counts.reshape(c * num_tiles)

    # Tile origins repeat per camera (each camera blends its own screen).
    origin = binning.tile_origins(tiles_y, tiles_x, tile, dtype=feats.uv.dtype)
    flat_org = jnp.tile(origin, (c, 1))  # (C*T, 2)

    # Load balance: order the pooled tiles by occupancy (descending) so each
    # blend_tile_chunks chunk groups similarly-loaded tiles and its sentinel
    # skip ends the scan at the chunk's own occupancy, not the global max.
    # The permutation is discrete (counts carry no gradient); gradients flow
    # through the feature gather exactly as in the per-camera path.
    order = jnp.argsort(-flat_counts)
    inv_order = jnp.argsort(order)

    out_sorted = binning.blend_tile_chunks(
        flat_feats,
        flat_idx[order],
        flat_org[order],
        flat_counts[order],
        jnp.asarray(cfg.background, dtype=feats.uv.dtype),
        tile_size=tile,
        sentinel=gn,  # camera 0's zero record; only used for shape padding
        tile_chunk=cfg.tile_chunk,
        early_exit=cfg.early_exit,
    )  # (C*T, tile^2, 3)

    out = out_sorted[inv_order].reshape(c, num_tiles, tile * tile, 3)
    return binning.untile_image(out, tiles_y, tiles_x, tile, height, width)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def render_batch(
    g: "GaussianParams | SceneTree",
    cams: CameraBatch,
    config: RenderConfig | None = None,
) -> jax.Array:
    """Render C cameras in one executable. Returns (C, H, W, 3).

    ``raster_path="binned"`` (the default) runs the pooled load-balanced
    batch pipeline above; the other raster paths (``dense``, ``pallas``,
    ``pallas_binned``, ``pallas_fused``) reuse the per-camera
    implementation camera-major via ``lax.map`` inside the same jit — one
    compiled executable and one model residency either way, which is what
    the serving layer needs.

    ``g`` may be a :class:`~repro.core.scene.SceneTree`: with
    ``config.cull`` every camera (vmap lane or ``lax.map`` iteration) culls
    the resident hierarchy and renders only its own compact visible set.

    Differentiable along every path the per-camera render differentiates
    (everything but the forward-only block-list ``pallas`` kernel).
    """
    from repro.core.render import render  # late: render imports this module

    cfg = as_config(config)
    if cfg.raster_path == "binned" and cfg.feature_path != "pallas":
        return _render_batch_binned(g, cams, cfg)
    # Camera-major loop: the Pallas kernels (and the pallas feature path)
    # are traced once and iterated, not vmapped.
    return jax.lax.map(lambda cam: render(g, cam, cfg), cams)


@functools.partial(jax.jit, static_argnames=("config",))
def render_batch_jit(
    g: "GaussianParams | SceneTree",
    cams: CameraBatch,
    config: RenderConfig | None = None,
) -> jax.Array:
    """Jitted :func:`render_batch`; ``config`` is static (hashable)."""
    return render_batch(g, cams, config)


def render_batch_masked(
    g: "GaussianParams | SceneTree",
    cams: CameraBatch,
    active: jax.Array,
    config: RenderConfig | None = None,
) -> jax.Array:
    """Render only the ``active`` slots of a fixed-width camera batch.

    The continuous-batching serving primitive: the slot table is a
    fixed-width :class:`CameraBatch` (static shapes -> one executable per
    image size) in which ``active`` (C,) bool — a *traced* operand, so any
    occupancy pattern hits the same compile — marks the live slots. Inactive
    slots return ``config.background`` and cost ~0 blend work:

    * ``binned`` path: an inactive camera's tile lists are masked to zero
      count / all-sentinel before the pooled count-sort, so the shared
      blender's sentinel skip ends those chunks at zero scan steps;
    * ``lax.map`` paths (``dense``, ``pallas``, ``pallas_binned``,
      ``pallas_fused``): each camera's render sits under a ``lax.cond`` on
      its slot bit, skipped entirely for inactive slots.

    Active slots match :func:`render_batch` exactly (the masking only adds
    empty tiles to the pooled schedule; per-tile math is untouched).
    """
    from repro.core.render import render  # late: render imports this module

    cfg = as_config(config)
    active = jnp.asarray(active, dtype=bool)
    if cfg.raster_path == "binned" and cfg.feature_path != "pallas":
        return _render_batch_binned(g, cams, cfg, active=active)
    background = jnp.broadcast_to(
        jnp.asarray(cfg.background, dtype=jnp.float32),
        (cams.height, cams.width, 3),
    )
    return jax.lax.map(
        lambda args: jax.lax.cond(
            args[1], lambda cam: render(g, cam, cfg), lambda cam: background,
            args[0],
        ),
        (cams, active),
    )


@functools.partial(jax.jit, static_argnames=("config",))
def render_batch_masked_jit(
    g: "GaussianParams | SceneTree",
    cams: CameraBatch,
    active: jax.Array,
    config: RenderConfig | None = None,
) -> jax.Array:
    """Jitted :func:`render_batch_masked`; ``config`` is static (hashable)."""
    return render_batch_masked(g, cams, active, config)
