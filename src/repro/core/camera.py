"""Camera model + per-camera precomputation.

The paper's task-partitioning trick (Eq. 4) precomputes ``K = J @ R_cw`` so the
2D covariance costs two small matmuls instead of four. ``J`` depends on the
per-Gaussian camera-space position, so the *camera-only* part that can be
hoisted is ``R_cw`` itself plus the focal scalars that parameterize ``J``; the
fused kernel receives those as tiny scalar operands (the TPU analogue of the
AIE's local-memory constants) and forms ``K`` per Gaussian in registers.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Camera:
    """Pinhole camera.

    Attributes:
      r_cw: (3, 3) world->camera rotation.
      t_cw: (3,) world->camera translation (p_c = r_cw @ p_w + t_cw).
      fx, fy: focal lengths in pixels (scalars, stored as 0-d arrays).
      cx, cy: principal point in pixels.
      width, height: static python ints (image size).
    """

    r_cw: jax.Array
    t_cw: jax.Array
    fx: jax.Array
    fy: jax.Array
    cx: jax.Array
    cy: jax.Array
    width: int = dataclasses.field(metadata=dict(static=True))
    height: int = dataclasses.field(metadata=dict(static=True))

    @property
    def cam_pos(self) -> jax.Array:
        """World-space camera center: -R_cw^T t_cw."""
        return -self.r_cw.T @ self.t_cw

    def tan_fov(self) -> tuple[jax.Array, jax.Array]:
        return (
            0.5 * self.width / self.fx,
            0.5 * self.height / self.fy,
        )


def look_at_camera(
    eye: Any,
    target: Any,
    up: Any = (0.0, 1.0, 0.0),
    *,
    width: int = 128,
    height: int = 128,
    focal: float | None = None,
    dtype: Any = jnp.float32,
) -> Camera:
    """Build a camera looking from ``eye`` toward ``target`` (OpenCV convention:
    +z forward, +x right, +y down)."""
    # Deliberate f64: the look-at basis is orthonormalized host-side once
    # per camera, then cast to `dtype` below — extra precision here never
    # reaches the f32 render path.
    eye = np.asarray(eye, dtype=np.float64)  # reprolint: disable=dtype-discipline
    target = np.asarray(target, dtype=np.float64)  # reprolint: disable=dtype-discipline
    up = np.asarray(up, dtype=np.float64)  # reprolint: disable=dtype-discipline

    fwd = target - eye
    fwd = fwd / (np.linalg.norm(fwd) + 1e-12)
    right = np.cross(fwd, up)
    right = right / (np.linalg.norm(right) + 1e-12)
    down = np.cross(fwd, right)
    # Rows of R_cw are the camera axes expressed in world coordinates.
    r_cw = np.stack([right, down, fwd], axis=0)
    t_cw = -r_cw @ eye
    if focal is None:
        focal = 1.2 * max(width, height)
    return Camera(
        r_cw=jnp.asarray(r_cw, dtype=dtype),
        t_cw=jnp.asarray(t_cw, dtype=dtype),
        fx=jnp.asarray(focal, dtype=dtype),
        fy=jnp.asarray(focal, dtype=dtype),
        cx=jnp.asarray(width / 2.0, dtype=dtype),
        cy=jnp.asarray(height / 2.0, dtype=dtype),
        width=width,
        height=height,
    )


def orbit_cameras(
    num: int,
    *,
    radius: float = 6.0,
    height_offset: float = 1.5,
    width: int = 128,
    height: int = 128,
    stacked: bool = False,
):
    """A ring of cameras orbiting the origin — synthetic multi-view training set.

    Returns a python list of :class:`Camera` by default; with
    ``stacked=True`` returns the same ring as one
    :class:`repro.core.multicam.CameraBatch` (leading camera axis), ready
    for ``render_batch`` / the batched training step.
    """
    cams = []
    for i in range(num):
        theta = 2.0 * np.pi * i / num
        eye = (radius * np.cos(theta), height_offset, radius * np.sin(theta))
        cams.append(look_at_camera(eye, (0.0, 0.0, 0.0), width=width, height=height))
    if stacked:
        from repro.core.multicam import stack_cameras  # late: avoids cycle

        return stack_cameras(cams)
    return cams
