"""Multi-device 3DGS pipeline — the paper's spatial parallelism on a TPU mesh.

The paper replicates one 7-kernel feature-computation unit down each of the 50
AIE columns (data parallelism over the Gaussian stream). The TPU analogue:

  stage 1  feature computation — Gaussians sharded over every mesh axis
           (pure map, zero collectives; mirrors the per-column units),
  stage 2  redistribution      — an all-gather of the *small* feature records
           (11 floats vs the 59-float input — gathering features, not raw
           Gaussians, is the bandwidth-side win; this corresponds to the
           PL-side gather the paper identifies as the system bottleneck),
  stage 3  rasterization       — pixels sharded over the same axes.

All three stages live in one ``shard_map`` so XLA can overlap the gather with
the tail of feature computation.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import features as feat_lib
from repro.core import rasterize as rast_lib
from repro.core.camera import Camera
from repro.core.features import GaussianFeatures
from repro.core.gaussians import GaussianParams


def sharded_features(
    mesh: Mesh,
    axis_names: Sequence[str],
    *,
    sh_degree: int = 3,
    feature_path: str = "fused",
):
    """Build a pjit-style sharded feature-computation fn.

    Gaussians shard along their leading axis over ``axis_names``; the camera
    is replicated (it is ~30 scalars — the AIE analogue streams it once to
    every column). Returns features sharded the same way (no collectives).
    """
    fn = feat_lib.compute_features_staged
    if feature_path == "naive":
        fn = feat_lib.compute_features_naive

    gspec = P(tuple(axis_names))

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(gspec, P()),
        out_specs=gspec,
    )
    def _features(g: GaussianParams, cam: Camera) -> GaussianFeatures:
        return fn(g, cam, sh_degree=sh_degree)

    return _features


def sharded_render(
    mesh: Mesh,
    gaussian_axes: Sequence[str],
    pixel_axes: Sequence[str],
    *,
    sh_degree: int = 3,
):
    """Feature-compute (sharded over Gaussians) -> gather -> rasterize
    (sharded over pixel rows). The full production render step."""

    gspec = P(tuple(gaussian_axes))
    all_axes = tuple(gaussian_axes) + tuple(
        a for a in pixel_axes if a not in gaussian_axes
    )

    def _render(g: GaussianParams, cam: Camera, background: jax.Array) -> jax.Array:
        @functools.partial(
            jax.shard_map,
            mesh=mesh,
            in_specs=(gspec, P(), P()),
            out_specs=P(tuple(pixel_axes)),
        )
        def _impl(g_shard, cam_rep, bg):
            feats = feat_lib.compute_features_fused(
                g_shard, cam_rep, sh_degree=sh_degree
            )
            # Stage 2: gather the small feature records from all shards.
            gathered = jax.tree.map(
                lambda x: _multi_axis_all_gather(x, gaussian_axes), feats
            )
            gathered = rast_lib.sort_by_depth(gathered)
            # Stage 3: every device rasterizes its slice of pixel rows.
            my_rows = cam_rep.height // _axis_size(pixel_axes)
            row0 = _pixel_axis_index(pixel_axes) * my_rows
            pix = rast_lib.pixel_grid(cam_rep.height, cam_rep.width)
            pix = jax.lax.dynamic_slice_in_dim(
                pix.reshape(cam_rep.height, cam_rep.width, 2),
                row0,
                my_rows,
                axis=0,
            ).reshape(-1, 2)
            out = rast_lib.rasterize_pixels(pix, gathered, bg)
            return out.reshape(my_rows, cam_rep.width, 3)

        def _axis_size(names):
            s = 1
            for nm in names:
                s *= mesh.shape[nm]
            return s

        def _pixel_axis_index(names):
            idx = jax.lax.axis_index(names[0])
            for nm in names[1:]:
                idx = idx * mesh.shape[nm] + jax.lax.axis_index(nm)
            return idx

        def _multi_axis_all_gather(x, names):
            for nm in reversed(names):
                x = jax.lax.all_gather(x, nm, axis=0, tiled=True)
            return x

        return _impl(g, cam, background)

    return _render
