"""Multi-device 3DGS pipeline — the paper's spatial parallelism on a TPU mesh.

The paper replicates one 7-kernel feature-computation unit down each of the 50
AIE columns (data parallelism over the Gaussian stream). The TPU analogue:

  stage 1  feature computation — Gaussians sharded over every mesh axis
           (pure map, zero collectives; mirrors the per-column units),
  stage 2  redistribution      — an all-gather of the *small* feature records
           (11 floats vs the 59-float input — gathering features, not raw
           Gaussians, is the bandwidth-side win; this corresponds to the
           PL-side gather the paper identifies as the system bottleneck),
  stage 3  rasterization       — pixels sharded over the same axes; with the
           binned raster path each device tile-bins the gathered features
           against ONLY its own pixel rows (its slice of the tile grid), so
           the per-tile list build is sharded alongside the blending.

All three stages live in one ``shard_map`` so XLA can overlap the gather with
the tail of feature computation.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import binning as bin_lib
from repro.core import features as feat_lib
from repro.core import rasterize as rast_lib
from repro.core.camera import Camera
from repro.core.config import UNSET, RenderConfig, as_config
from repro.core.features import GaussianFeatures
from repro.core.gaussians import GaussianParams
from repro.core.gaussians import pack_records
from repro.core.quant import QuantizedGaussianParams, dequantize_geometry
from repro.core.render import FEATURE_PATHS
from repro.core.scene import resolve_scene_banded, resolve_scene_f32


def _pipeline_config(config: RenderConfig | None, **legacy) -> RenderConfig:
    """Deprecation shim mirroring ``render``'s: fold loose kwargs, warn."""
    used = sorted(k for k, v in legacy.items() if v is not UNSET)
    if used:
        warnings.warn(
            f"sharded pipeline kwargs {', '.join(used)} are deprecated; pass "
            "config=RenderConfig(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
    return as_config(config, **legacy)


def _sharded_feature_fn(cfg: RenderConfig):
    """Per-device feature fn for the sharded paths.

    The pallas feature kernel is per-device-callable too, but the sharded
    paths stay on the jnp implementations (Mosaic inside shard_map is
    exercised by the kernel tests, not the pipeline) — an explicit
    ``feature_path="pallas"`` falls back to the numerically identical fused
    path, with a warning so comparisons aren't silently mislabeled.
    """
    if cfg.feature_path not in FEATURE_PATHS:
        warnings.warn(
            f"feature_path={cfg.feature_path!r} is not shardable; the "
            "sharded pipeline uses the fused jnp path instead",
            stacklevel=3,
        )
        return feat_lib.compute_features_fused
    return FEATURE_PATHS[cfg.feature_path]


def sharded_features(
    mesh: Mesh,
    axis_names: Sequence[str],
    *,
    config: RenderConfig | None = None,
    sh_degree=UNSET,
    feature_path=UNSET,
):
    """Build a pjit-style sharded feature-computation fn.

    Gaussians shard along their leading axis over ``axis_names``; the camera
    is replicated (it is ~30 scalars — the AIE analogue streams it once to
    every column). Returns features sharded the same way (no collectives).

    ``sh_degree`` / ``feature_path`` kwargs are a deprecation shim; pass a
    :class:`RenderConfig` instead.
    """
    cfg = _pipeline_config(config, sh_degree=sh_degree, feature_path=feature_path)
    fn = _sharded_feature_fn(cfg)

    gspec = P(tuple(axis_names))

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(gspec, P()),
        out_specs=gspec,
    )
    def _features(g: GaussianParams, cam: Camera) -> GaussianFeatures:
        return fn(g, cam, sh_degree=cfg.sh_degree)

    return _features


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    s = 1
    for nm in names:
        s *= mesh.shape[nm]
    return s


def _axis_index(mesh: Mesh, names: Sequence[str]) -> jax.Array:
    """Linearized index of this device along the given mesh axes."""
    idx = jax.lax.axis_index(names[0])
    for nm in names[1:]:
        idx = idx * mesh.shape[nm] + jax.lax.axis_index(nm)
    return idx


def _multi_axis_all_gather(x, names: Sequence[str]):
    for nm in reversed(names):
        x = jax.lax.all_gather(x, nm, axis=0, tiled=True)
    return x


def _raster_device_rows(
    gathered: GaussianFeatures,
    cfg: RenderConfig,
    raster_path: str,
    my_rows: jax.Array | int,
    width: int,
    height: int,
    row0: jax.Array,
    bg: jax.Array,
) -> jax.Array:
    """Rasterize one device's slice of pixel rows from gathered, depth-sorted
    features. Shared by :func:`sharded_render` (one camera per call) and
    :func:`sharded_render_batch` (camera-major loop per device).

    For the binned paths the features are shifted so this device's rows
    start at y=0, then binned + blended as a ``my_rows x width`` sub-image
    (the per-tile list build shards alongside the blending);
    ``pallas_binned`` compacts the local lists and blends through the
    compact Pallas kernel (custom VJP -> the sharded path stays trainable).
    ``dense`` keeps the all-pairs oracle blend on the row slice.
    """
    if raster_path in ("binned", "pallas_binned"):
        shift = jnp.stack([jnp.zeros((), bg.dtype), row0.astype(bg.dtype)])
        local = dataclasses.replace(gathered, uv=gathered.uv - shift[None, :])
        if raster_path == "pallas_binned":
            from repro.kernels.gaussian_features.ref import pack_features
            from repro.kernels.tile_rasterize.ops import tile_rasterize_compact

            return tile_rasterize_compact(
                pack_features(local),
                my_rows,
                width,
                bg,
                tile_size=cfg.tile_size,
                capacity=cfg.tile_capacity,
                block_g=cfg.block_g,
                tile_chunk=cfg.tile_chunk,
            )
        bins = bin_lib.bin_gaussians(
            local,
            my_rows,
            width,
            tile_size=cfg.tile_size,
            capacity=cfg.tile_capacity,
            tile_chunk=cfg.tile_chunk,
        )
        return bin_lib.rasterize_binned(
            local,
            bins,
            my_rows,
            width,
            bg,
            tile_chunk=cfg.tile_chunk,
            early_exit=cfg.early_exit,
        )

    pix = rast_lib.pixel_grid(height, width)
    pix = jax.lax.dynamic_slice_in_dim(
        pix.reshape(height, width, 2), row0, my_rows, axis=0
    ).reshape(-1, 2)
    out = rast_lib.rasterize_pixels(pix, gathered, bg)
    return out.reshape(my_rows, width, 3)


def _fused_raster_device_rows(
    local: GaussianParams | QuantizedGaussianParams,
    band: jax.Array | None,
    cam: Camera,
    cfg: RenderConfig,
    gaussian_axes: Sequence[str],
    my_rows: int,
    row0: jax.Array,
    bg: jax.Array,
) -> jax.Array:
    """Fused-path stages for one device's pixel rows.

    The fused raster path computes features *inside* the blend kernel, so
    its stage 2 ships the raw 59-float records to the rasterizer (plus the
    small geometry-only pre-pass features for the replicated depth sort)
    instead of precomputed feature records — the gather is heavier, and in
    exchange the FLOP-dominant SH + covariance arithmetic shards with the
    pixel rows. Stage 3 tile-bins this device's rows only, compacts the raw
    chunks along its own lists, and streams them through the fused Pallas
    kernel with the *untouched* full-image camera and absolute pixel
    coordinates — in-kernel feature math and blending are bitwise-identical
    to the unsharded fused path wherever the tile lists agree.

    A quantized shard (compressed resident SceneTree under
    ``cfg.compress="int8"``) keeps stage 2 on the *compressed* planes: the
    all-gather moves ~83 bytes/Gaussian (int8/fp16 fields + per-chunk
    scales, chunk-aligned so every lane lands next to its own decode
    scales) instead of the 236-byte raw records — the sharded wire cost
    shrinks by the same ~2.8x as the resident bytes — and each device
    decodes in-kernel after its own compact gather.
    """
    from repro.kernels.fused_raster import ops as fused_ops
    from repro.kernels.gaussian_features.ops import pack_camera
    from repro.kernels.tile_rasterize.ops import (
        _default_interpret,
        _tile_order_pixels,
    )

    tile = cfg.tile_size
    quantized = isinstance(local, QuantizedGaussianParams)

    # Stage 1 (sharded): geometry-only pre-pass on this device's shard.
    # Quantized shards decode just the two compressed geometry fields
    # (strip-free, so shapes stay shard-local) — SH never enters degree-0
    # geometry, so the pre-pass is bitwise the f32-on-dequantized one.
    if quantized:
        log_scales, opacity = dequantize_geometry(local)
        g_geo = GaussianParams(
            positions=local.positions,
            quats=local.quats,
            log_scales=log_scales,
            sh=jnp.zeros((local.num_gaussians, 16, 3), jnp.float32),
            opacity_logit=opacity,
        )
    else:
        g_geo = local
    geo = jax.tree.map(
        jax.lax.stop_gradient,
        feat_lib.compute_features_staged(g_geo, cam, sh_degree=0),
    )

    # Stage 2: all-gather the record stream + pre-pass geometry. The
    # quantized gather is chunk-aligned: every leaf (including the (M, 5)
    # scale table) concatenates along axis 0 in the same shard order, so
    # chunk k's lanes still broadcast from scale row k after the gather.
    geo_g = jax.tree.map(
        lambda x: _multi_axis_all_gather(x, gaussian_axes), geo
    )
    band_g = (
        None if band is None else _multi_axis_all_gather(band, gaussian_axes)
    )

    # Replicated depth sort (discrete; same permutation on every device).
    key = jnp.where(geo_g.mask > 0.5, geo_g.depth, jnp.inf)
    order = jnp.argsort(key)
    geo_sorted = jax.tree.map(lambda x: x[order], geo_g)
    band_sorted = None if band_g is None else band_g[order]

    # Stage 3: bin this device's rows only (uv shifted so they start at
    # y=0 — the tile-list build shards with the pixels, like the binned
    # path), then blend through the fused kernel in absolute coordinates.
    shift = jnp.stack([jnp.zeros((), bg.dtype), row0.astype(bg.dtype)])
    local_geo = dataclasses.replace(
        geo_sorted, uv=geo_sorted.uv - shift[None, :]
    )
    bins = bin_lib.bin_gaussians(
        local_geo,
        my_rows,
        cam.width,
        tile_size=tile,
        capacity=cfg.tile_capacity,
        tile_chunk=cfg.tile_chunk,
    )
    h_pad, w_pad = bins.tiles_y * tile, bins.tiles_x * tile
    pix = _tile_order_pixels(h_pad, w_pad, tile) + shift[None, :]
    bg4 = jnp.concatenate([bg, jnp.zeros((1,), bg.dtype)])[None, :]
    blend_static = (
        bins.num_tiles,
        None,  # steps, filled per path below
        cfg.block_g,
        cfg.sh_degree,
        band is not None,
        cfg.early_exit,
        fused_ops.pick_tiles_per_step(bins.num_tiles),
        _default_interpret(),
    )
    if quantized:
        qg_g = jax.tree.map(
            lambda x: _multi_axis_all_gather(x, gaussian_axes), local
        )
        qf, qi, qdc = fused_ops.pack_quant_rows(qg_g)
        planes, nsteps, chunk_band, steps = fused_ops.compact_fused_operands_q(
            qf[:, order],
            qi[:, order],
            qdc[:, order],
            bins,
            band_sorted=band_sorted,
            block_g=cfg.block_g,
        )
        out = fused_ops._fused_blend_q(
            *planes, pack_camera(cam), pix, bg4, nsteps, chunk_band,
            *(blend_static[:1] + (steps,) + blend_static[2:]),
        )
    else:
        raw = pack_records(local)  # (n_shard, RAW_ROWS)
        raw_g = _multi_axis_all_gather(raw, gaussian_axes)
        raw_sorted = raw_g[order].T
        raw_compact, nsteps, chunk_band, steps = (
            fused_ops.compact_fused_operands(
                raw_sorted, bins, band_sorted=band_sorted, block_g=cfg.block_g
            )
        )
        out = fused_ops._fused_blend(
            raw_compact, pack_camera(cam), pix, bg4, nsteps, chunk_band,
            *(blend_static[:1] + (steps,) + blend_static[2:]),
        )
    img = out[:, 0:3].reshape(bins.tiles_y, bins.tiles_x, tile, tile, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(h_pad, w_pad, 3)
    return img[:my_rows, : cam.width]


def sharded_render(
    mesh: Mesh,
    gaussian_axes: Sequence[str],
    pixel_axes: Sequence[str],
    *,
    config: RenderConfig | None = None,
    sh_degree=UNSET,
):
    """Feature-compute (sharded over Gaussians) -> gather -> bin -> rasterize
    (sharded over pixel rows). The full production render step.

    With ``config.raster_path == "binned"`` (the default) every device builds
    tile lists for its own row slice of the image only — binning cost shards
    with the pixels. ``"pallas_binned"`` additionally compacts each device's
    tile lists and blends them through the compact Pallas kernel (custom
    VJP, so the sharded path stays trainable); compaction, like binning,
    runs per device on its own pixel rows. ``"pallas_fused"`` gathers the
    *raw* record stream instead of feature records and runs feature
    computation inside each device's blend kernel (see
    :func:`_fused_raster_device_rows`). ``"dense"`` keeps the all-pairs
    oracle blend.
    """
    cfg = _pipeline_config(config, sh_degree=sh_degree)
    feature_fn = _sharded_feature_fn(cfg)
    # The forward-only block-list pallas kernel is not differentiable; use
    # the jnp binned path on-device instead. The compact kernel
    # ("pallas_binned") IS per-device-callable and trainable: each device
    # runs its own gather-to-compact over its pixel-row slice.
    raster_path = "binned" if cfg.raster_path == "pallas" else cfg.raster_path

    gspec = P(tuple(gaussian_axes))

    # pallas_call has no shard_map replication rule, and the culled-gather
    # path's data-dependent chunk selection defeats static replication
    # inference; both are rank-preserving by construction (each device
    # writes only its own pixel rows), so disabling the check is safe.
    extra = (
        {"check_rep": False}
        if raster_path in ("pallas_binned", "pallas_fused") or cfg.cull
        else {}
    )

    def _render(g, cam: Camera, background: jax.Array) -> jax.Array:
        @functools.partial(
            shard_map,
            mesh=mesh,
            in_specs=(gspec, P(), P()),
            out_specs=P(tuple(pixel_axes)),
            **extra,
        )
        def _impl(g_shard, cam_rep, bg):
            # A SceneTree shards chunk-aligned (chunk table and Gaussians
            # split along the same axes), so each device culls its *own*
            # chunk slice and features only its local compact visible set;
            # ``visible_capacity`` is therefore per device here. Raw
            # clouds pass through untouched.
            if raster_path == "pallas_fused":
                local, band = resolve_scene_banded(g_shard, cam_rep, cfg)
                my_rows = cam_rep.height // _axis_size(mesh, pixel_axes)
                row0 = _axis_index(mesh, pixel_axes) * my_rows
                return _fused_raster_device_rows(
                    local, band, cam_rep, cfg, gaussian_axes,
                    my_rows, row0, bg,
                )
            local = resolve_scene_f32(g_shard, cam_rep, cfg)
            feats = feature_fn(local, cam_rep, sh_degree=cfg.sh_degree)
            # Stage 2: gather the small feature records from all shards.
            gathered = jax.tree.map(
                lambda x: _multi_axis_all_gather(x, gaussian_axes), feats
            )
            gathered = rast_lib.sort_by_depth(gathered)
            # Stage 3: every device rasterizes its slice of pixel rows.
            my_rows = cam_rep.height // _axis_size(mesh, pixel_axes)
            row0 = _axis_index(mesh, pixel_axes) * my_rows
            return _raster_device_rows(
                gathered,
                cfg,
                raster_path,
                my_rows,
                cam_rep.width,
                cam_rep.height,
                row0,
                bg,
            )

        return _impl(g, cam, background)

    return _render


def sharded_render_batch(
    mesh: Mesh,
    gaussian_axes: Sequence[str],
    camera_axes: Sequence[str],
    pixel_axes: Sequence[str],
    *,
    config: RenderConfig | None = None,
):
    """Batched multi-camera render sharded cameras x pixel-rows on the mesh.

    The serving-scale layout: the camera batch shards along ``camera_axes``
    (each device owns C / n_cam cameras), and within each camera every
    device rasterizes its slice of pixel rows along ``pixel_axes`` — the
    same row-sharding as :func:`sharded_render`, looped camera-major per
    device. Feature computation shards Gaussians along ``gaussian_axes``
    (disjoint from ``camera_axes``) and all-gathers the small feature
    records, exactly like the single-camera pipeline.

    Returns a callable ``(g, cams: CameraBatch, background) -> (C, H, W, 3)``
    whose output is sharded over cameras (axis 0) and pixel rows (axis 1).
    ``C`` must divide by the camera-axes size and ``H`` by the pixel-axes
    size. Differentiable along every path the per-camera pipeline
    differentiates (``pallas`` falls back to the jnp binned blend, as in
    :func:`sharded_render`).
    """
    cfg = _pipeline_config(config)
    feature_fn = _sharded_feature_fn(cfg)
    raster_path = "binned" if cfg.raster_path == "pallas" else cfg.raster_path

    if set(camera_axes) & set(gaussian_axes):
        raise ValueError(
            f"camera_axes {camera_axes} and gaussian_axes {gaussian_axes} "
            "must be disjoint (cameras and Gaussians shard independently)"
        )

    gspec = P(tuple(gaussian_axes))
    cspec = P(tuple(camera_axes))

    extra = (
        {"check_rep": False}
        if raster_path in ("pallas_binned", "pallas_fused") or cfg.cull
        else {}
    )

    def _render(g, cams, background: jax.Array) -> jax.Array:
        @functools.partial(
            shard_map,
            mesh=mesh,
            in_specs=(gspec, cspec, P()),
            out_specs=P(tuple(camera_axes), tuple(pixel_axes)),
            **extra,
        )
        def _impl(g_shard, local_cams, bg):
            my_rows = local_cams.height // _axis_size(mesh, pixel_axes)
            row0 = _axis_index(mesh, pixel_axes) * my_rows

            def per_camera(cam):
                # Per-camera, per-device culling (see sharded_render): a
                # SceneTree slice is compacted before features, so the
                # all-gather below moves the culled width, not the scene.
                if raster_path == "pallas_fused":
                    local, band = resolve_scene_banded(g_shard, cam, cfg)
                    return _fused_raster_device_rows(
                        local, band, cam, cfg, gaussian_axes,
                        my_rows, row0, bg,
                    )
                local = resolve_scene_f32(g_shard, cam, cfg)
                feats = feature_fn(local, cam, sh_degree=cfg.sh_degree)
                gathered = jax.tree.map(
                    lambda x: _multi_axis_all_gather(x, gaussian_axes), feats
                )
                gathered = rast_lib.sort_by_depth(gathered)
                return _raster_device_rows(
                    gathered,
                    cfg,
                    raster_path,
                    my_rows,
                    cam.width,
                    cam.height,
                    row0,
                    bg,
                )

            return jax.lax.map(per_camera, local_cams)

        return _impl(g, cams, background)

    return _render
