"""Quantized resident Gaussian scenes — per-chunk, per-band int8/fp16 storage.

A million Gaussians at f32 with degree-3 SH is ~236 MB resident per scene
(59 floats/record) — the binding constraint for multi-scene serving and the
dominant payload of the sharded pipeline's raw-record all-gather. This module
stores the *cold* fields compressed and leaves the numerically hot ones
alone:

  field            storage              bytes/G   notes
  positions        f32 (N, 3)           12        sub-pixel projection error
  quats            f32 (N, 4)           16        is not worth 7 bytes
  log_scales       int8 (N, 3)          3         per-chunk scale
  opacity_logit    int8 (N,)            1         per-chunk scale
  SH band 0 (DC)   fp16 (N, 3)          6         dominates color: kept fp16
  SH bands 1-3     int8 (N, 15, 3)      45        per-chunk, per-*band* scale
  chunk scales     f32 (M, 5)           20 / chunk_size

~83 bytes/Gaussian vs 236 (0.35x), and the 192-byte SH block shrinks to
~51 bytes (3.8x) — the per-band layout the 129FPS accelerator paper
motivates (PAPERS.md): each band's coefficient magnitudes decay with degree,
so one shared scale per (chunk, band) keeps the int8 grid matched to each
band instead of letting band-1 span waste band-3 resolution.

Quantization is *chunked* on the same ``leaf_size`` runs as the scene tree
(``core.scene``): Morton-sorted chunks are spatially coherent, so per-chunk
max-abs scales adapt to local statistics, the scales travel with the chunk
through the culled gather, and the fused kernel can decode a chunk from one
broadcast scale row. The scale math reuses
``distributed.compression.symmetric_scale`` (max-abs / 127 with the
zero-range / non-finite guard), extending the gradient compressor's blockwise
scheme to per-field, per-band blocks.

Decode is ``q.astype(f32) * scale`` — *bitwise identical* whether it runs in
plain jnp (:func:`dequantize_gaussians`), inside the fused Pallas kernel
(``kernels.fused_raster.kernel.decode_lanes``), or per device after the
sharded all-gather. That identity is the testing lever: the fused quantized
render must equal the fused f32 render of the dequantized cloud exactly.

Training runs against **f32 master weights**: :func:`quantize_dequantize` is
a straight-through estimator (identity VJP), so ``render(quantize_dequantize
(g))`` produces the quantized image while gradients land on the f32 masters
unchanged — the render stack applies it when ``RenderConfig.compress`` is
set and the scene is still a raw f32 cloud.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.config import COMPRESS_MODES  # noqa: F401  (re-export)
from repro.core.gaussians import GaussianParams, pad_to_multiple
from repro.distributed.compression import symmetric_scale

# SH basis-index ranges of bands 1..3 ((deg+1)^2 boundaries).
SH_BAND_SLICES = ((1, 4), (4, 9), (9, 16))

# Columns of the per-chunk scale table (M, 5).
SCALE_COLS = ("log_scales", "opacity", "sh_band1", "sh_band2", "sh_band3")

# Bytes per Gaussian at f32 (59 floats) and quantized (see module docstring).
F32_RECORD_BYTES = 59 * 4
QUANT_RECORD_BYTES = 12 + 16 + 3 + 1 + 6 + 45


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class QuantizedGaussianParams:
    """Compressed SoA Gaussian cloud (see module docstring for the layout).

    ``N`` is padded to a whole number of ``chunk_size`` runs (padding rows
    carry the standard invisible record and decode below the alpha floor);
    ``scales`` holds one f32 decode scale per (chunk, field-or-band) in
    :data:`SCALE_COLS` order. ``num_real`` is the pre-padding count —
    :func:`dequantize_gaussians` strips back to it.
    """

    positions: jax.Array  # (N, 3) f32
    quats: jax.Array  # (N, 4) f32
    log_scales_q: jax.Array  # (N, 3) int8
    opacity_q: jax.Array  # (N,) int8
    sh_dc: jax.Array  # (N, 3) fp16
    sh_rest_q: jax.Array  # (N, 15, 3) int8
    scales: jax.Array  # (M, 5) f32
    chunk_size: int = dataclasses.field(metadata=dict(static=True))
    num_real: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_gaussians(self) -> int:
        """Padded resident count (= num_chunks * chunk_size)."""
        return self.positions.shape[0]

    @property
    def num_chunks(self) -> int:
        return self.scales.shape[0]


def _chunk_maxabs(x: jax.Array, m: int) -> jax.Array:
    """(N, ...) -> (M, 1) max |x| over each chunk's flattened members."""
    return jnp.max(jnp.abs(x.reshape(m, -1)), axis=-1, keepdims=True)


def _encode(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 encode with a per-chunk broadcastable decode scale."""
    x = jnp.where(jnp.isfinite(x), x, 0.0)
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def _lane_scales(scales: jax.Array, chunk_size: int, n: int) -> jax.Array:
    """(M, 5) chunk scales -> (N, 5) per-Gaussian broadcast."""
    return jnp.repeat(scales, chunk_size, axis=0, total_repeat_length=n)


def quantize_gaussians(
    g: GaussianParams, chunk_size: int
) -> QuantizedGaussianParams:
    """Compress an f32 cloud to per-chunk int8/fp16 storage.

    The cloud is padded to a whole number of chunks first (standard
    invisible records — ``pad_to_multiple``), and the padding participates
    in the chunk max-abs: the pad's -10 log-scale / -30 opacity logit then
    pin those codes to exactly representable grid points (q = -127), so
    padding decodes invisible. Only the final chunk pays the coarser grid.

    Zero-range blocks (e.g. COLMAP point-seeded clouds whose SH bands 1-3
    are all zero) get the guarded fallback scale and decode to exact zeros.
    """
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    padded, n_real = pad_to_multiple(g, chunk_size)
    n = padded.num_gaussians
    m = n // chunk_size

    b1, b2, b3 = (padded.sh[:, lo:hi, :] for lo, hi in SH_BAND_SLICES)
    scales = symmetric_scale(
        jnp.concatenate(
            [
                _chunk_maxabs(padded.log_scales, m),
                _chunk_maxabs(padded.opacity_logit, m),
                _chunk_maxabs(b1, m),
                _chunk_maxabs(b2, m),
                _chunk_maxabs(b3, m),
            ],
            axis=-1,
        )
    )  # (M, 5)
    lane = _lane_scales(scales, chunk_size, n)  # (N, 5)

    return QuantizedGaussianParams(
        positions=padded.positions,
        quats=padded.quats,
        log_scales_q=_encode(padded.log_scales, lane[:, 0:1]),
        opacity_q=_encode(padded.opacity_logit, lane[:, 1]),
        sh_dc=padded.sh[:, 0, :].astype(jnp.float16),
        sh_rest_q=_encode(padded.sh[:, 1:, :], _band_lane_scales(lane)),
        scales=scales,
        chunk_size=chunk_size,
        num_real=n_real,
    )


def _band_lane_scales(lane: jax.Array) -> jax.Array:
    """(N, 5) lane scales -> (N, 15, 1) per-rest-basis SH decode scales."""
    reps = jnp.asarray([3, 5, 7])  # basis counts of bands 1..3
    band_of_basis = jnp.repeat(
        jnp.arange(3, dtype=jnp.int32), reps, total_repeat_length=15
    )
    return lane[:, 2 + band_of_basis][:, :, None]  # (N, 15, 1)


def dequantize_geometry(
    qg: QuantizedGaussianParams,
) -> tuple[jax.Array, jax.Array]:
    """Decode (log_scales (N, 3), opacity_logit (N,)) — no stripping.

    The fused path's geometry pre-pass needs only these two compressed
    fields (positions/quats are already f32); keeping the decode strip-free
    makes it shard_map-safe (shapes stay shard-local).
    """
    n = qg.num_gaussians
    lane = _lane_scales(qg.scales, qg.chunk_size, n)
    log_scales = qg.log_scales_q.astype(jnp.float32) * lane[:, 0:1]
    opacity = qg.opacity_q.astype(jnp.float32) * lane[:, 1]
    return log_scales, opacity


def dequantize_gaussians(qg: QuantizedGaussianParams) -> GaussianParams:
    """Full f32 reconstruction, stripped back to the pre-padding count.

    Bitwise-identical to the fused kernel's in-kernel decode
    (``q.astype(f32) * scale`` per field/band), which is what makes
    ``fused_render(dequantize_gaussians(qg)) == fused_render_q(qg)`` an
    exact (bitwise) contract rather than a tolerance.
    """
    n = qg.num_gaussians
    lane = _lane_scales(qg.scales, qg.chunk_size, n)
    log_scales, opacity = dequantize_geometry(qg)
    sh_rest = qg.sh_rest_q.astype(jnp.float32) * _band_lane_scales(lane)
    sh = jnp.concatenate(
        [qg.sh_dc.astype(jnp.float32)[:, None, :], sh_rest], axis=1
    )
    g = GaussianParams(
        positions=qg.positions,
        quats=qg.quats,
        log_scales=log_scales,
        sh=sh,
        opacity_logit=opacity,
    )
    if qg.num_real == n:
        return g
    return jax.tree.map(lambda x: x[: qg.num_real], g)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_dequantize(g: GaussianParams, chunk_size: int) -> GaussianParams:
    """Straight-through estimator: quantization in the forward pass only.

    Forward returns ``dequantize(quantize(g))`` — exactly the cloud a
    quantized resident scene renders — while the VJP passes cotangents
    through unchanged, so optimizers keep training the f32 master weights
    (the standard quantization-aware-training trick). ``grad(f(ste(g)))``
    therefore equals ``grad(f)`` evaluated at the dequantized point.
    """
    return dequantize_gaussians(quantize_gaussians(g, chunk_size))


def _qd_fwd(g, chunk_size):
    return quantize_dequantize(g, chunk_size), None


def _qd_bwd(chunk_size, _, ct):
    return (ct,)


quantize_dequantize.defvjp(_qd_fwd, _qd_bwd)


def quantized_memory_stats(qg: QuantizedGaussianParams) -> dict:
    """Resident-byte accounting per field and SH band (see memory_stats)."""
    n = qg.num_gaussians
    fields = {
        "positions": int(qg.positions.nbytes),
        "quats": int(qg.quats.nbytes),
        "log_scales": int(qg.log_scales_q.nbytes),
        "opacity": int(qg.opacity_q.nbytes),
        "sh_dc": int(qg.sh_dc.nbytes),
        "sh_rest": int(qg.sh_rest_q.nbytes),
        "chunk_scales": int(qg.scales.nbytes),
    }
    sh_bands = {
        "dc": int(qg.sh_dc.nbytes),
        "band1": 3 * 3 * n,  # int8: 3 bases x 3 channels
        "band2": 5 * 3 * n,
        "band3": 7 * 3 * n,
        "band_scales": 3 * 4 * qg.num_chunks,
    }
    return _memory_summary(n, fields, sh_bands, compressed=True)


def f32_memory_stats(g: GaussianParams) -> dict:
    """f32 resident-byte accounting with the same schema."""
    n = g.num_gaussians
    fields = {
        "positions": int(g.positions.nbytes),
        "quats": int(g.quats.nbytes),
        "log_scales": int(g.log_scales.nbytes),
        "opacity": int(g.opacity_logit.nbytes),
        "sh": int(g.sh.nbytes),
    }
    sh_bands = {
        "dc": 3 * 4 * n,
        "band1": 3 * 3 * 4 * n,
        "band2": 5 * 3 * 4 * n,
        "band3": 7 * 3 * 4 * n,
        "band_scales": 0,
    }
    return _memory_summary(n, fields, sh_bands, compressed=False)


def _memory_summary(n: int, fields: dict, sh_bands: dict, compressed: bool) -> dict:
    total = sum(fields.values())
    f32_equiv = n * F32_RECORD_BYTES
    return {
        "compressed": compressed,
        "num_gaussians": n,
        "fields": fields,
        "sh_bands": sh_bands,
        "sh_bytes": sum(sh_bands.values()),
        "total_bytes": total,
        "f32_bytes": f32_equiv,
        "ratio_vs_f32": total / max(1, f32_equiv),
    }
