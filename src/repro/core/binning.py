"""Tile binning — per-tile Gaussian index lists for sparse rasterization.

The paper stops at feature computation and identifies the downstream
gather/rasterize stage as the system bottleneck; the dense rasterizer in
``repro.core.rasterize`` blends every Gaussian at every pixel (O(P*G)). This
module adds the standard 3DGS tile-culling stage: each Gaussian's screen AABB
(``uv`` +- ``radius``, the 3-sigma box) is mapped to the ``tile_size`` x
``tile_size`` screen tiles it overlaps, and each tile gets a fixed-capacity,
depth-sorted list of the Gaussian indices that can touch it. Blending a tile
then visits only its list — O(P * G_visible_per_tile).

Everything is static-shape and jittable:

* lists have a fixed ``capacity``; empty slots carry the sentinel index ``G``
  (one past the last Gaussian) and gather a padded all-zero feature record,
* on overflow the *front-most* (nearest) Gaussians are kept — because the
  features are globally depth-sorted first, "front-most" is simply "smallest
  index", so per-tile selection is a smallest-K over indices (a sorted
  prefix by default, ``lax.top_k`` behind ``select="topk"``) — no per-tile
  depth sort,
* the index selection is discrete (under ``stop_gradient``); gradients flow
  through the subsequent feature *gather*, the same idiom as
  ``rasterize.sort_by_depth``.

Exactness contract: the dense path cuts every Gaussian at its 3-sigma box
(see ``rasterize._pixel_alphas``), and a tile list contains every Gaussian
whose box overlaps the tile, so binned blending reproduces the dense oracle
exactly (skipped Gaussians contribute an exact 1.0 transmittance factor) —
up to list-capacity overflow, which drops back-most Gaussians only.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import constants
from repro.core.features import GaussianFeatures

# Default list capacity; RenderConfig.tile_capacity overrides per call site.
DEFAULT_CAPACITY = 512


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TileBins:
    """Fixed-capacity per-tile Gaussian index lists.

    Attributes:
      indices: (T, K) int32 indices into the depth-sorted Gaussian axis,
        ascending (= front-to-back). Empty slots hold the sentinel ``G``.
      count: (T,) int32 number of valid entries per tile (pre-clamp overlap
        count capped at K).
      overflowed: (T,) bool — tile had more than K overlapping Gaussians.
      tiles_y, tiles_x: tile-grid shape (static).
      tile_size: tile edge in pixels (static).
    """

    indices: jax.Array
    count: jax.Array
    overflowed: jax.Array
    tiles_y: int = dataclasses.field(metadata=dict(static=True))
    tiles_x: int = dataclasses.field(metadata=dict(static=True))
    tile_size: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_tiles(self) -> int:
        return self.tiles_y * self.tiles_x

    @property
    def capacity(self) -> int:
        return self.indices.shape[-1]


def tile_grid_shape(height: int, width: int, tile_size: int) -> tuple[int, int]:
    """(tiles_y, tiles_x) covering an H x W image (last row/col may be partial)."""
    return -(-height // tile_size), -(-width // tile_size)


def gaussian_tile_bounds(
    feats: GaussianFeatures, height: int, width: int, tile_size: int
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-Gaussian inclusive tile-index AABB [x0, x1] x [y0, y1] + validity.

    The AABB is the 3-sigma screen box ``uv +- radius`` in tile units, clamped
    to the tile grid. Gaussians that are culled (mask 0) or whose box misses
    the screen entirely get an empty range via ``valid`` = False.
    """
    tiles_y, tiles_x = tile_grid_shape(height, width, tile_size)
    uv = jax.lax.stop_gradient(feats.uv)
    radius = jax.lax.stop_gradient(feats.radius)
    ts = jnp.float32(tile_size)
    x0 = jnp.floor((uv[:, 0] - radius) / ts).astype(jnp.int32)
    x1 = jnp.floor((uv[:, 0] + radius) / ts).astype(jnp.int32)
    y0 = jnp.floor((uv[:, 1] - radius) / ts).astype(jnp.int32)
    y1 = jnp.floor((uv[:, 1] + radius) / ts).astype(jnp.int32)
    onscreen = (x1 >= 0) & (x0 < tiles_x) & (y1 >= 0) & (y0 < tiles_y)
    valid = (feats.mask > 0.5) & onscreen
    x0 = jnp.clip(x0, 0, tiles_x - 1)
    x1 = jnp.clip(x1, 0, tiles_x - 1)
    y0 = jnp.clip(y0, 0, tiles_y - 1)
    y1 = jnp.clip(y1, 0, tiles_y - 1)
    return x0, x1, y0, y1, valid


def bin_gaussians(
    feats_sorted: GaussianFeatures,
    height: int,
    width: int,
    *,
    tile_size: int = 16,
    capacity: int = DEFAULT_CAPACITY,
    tile_chunk: int | None = 64,
    select: str = "sort",
) -> TileBins:
    """Build per-tile index lists from *depth-sorted* features.

    Args:
      feats_sorted: output of ``rasterize.sort_by_depth`` (front-to-back; the
        ascending-index = ascending-depth invariant is what makes per-tile
        lists sorted for free).
      height, width: image size in pixels.
      tile_size: tile edge in pixels.
      capacity: fixed list length K (clamped to G).
      tile_chunk: tiles processed per ``lax.map`` step — bounds the (chunk, G)
        overlap matrix; None = all tiles at once.
      select: selection primitive for the front-most-K candidates — both
        produce identical lists (pinned by test). ``"sort"`` (the default)
        sorts the candidate matrix and takes the prefix, which lowers much
        better on CPU and under ``vmap`` (~3.5x faster single-camera
        binning measured on the CPU backend at 2k G / 64^2; the batched
        multi-camera path always used it). ``"topk"`` (the original) runs
        ``lax.top_k`` on the negated candidates — kept for the equality
        pin and comparison benches.

    Returns a :class:`TileBins`.
    """
    if select not in ("topk", "sort"):
        raise ValueError(f"select={select!r} not in ('topk', 'sort')")
    g = feats_sorted.uv.shape[0]
    tiles_y, tiles_x = tile_grid_shape(height, width, tile_size)
    num_tiles = tiles_y * tiles_x
    k = min(capacity, g)

    x0, x1, y0, y1, valid = gaussian_tile_bounds(
        feats_sorted, height, width, tile_size
    )
    iota_g = jnp.arange(g, dtype=jnp.int32)
    sentinel = jnp.int32(g)

    tile_ids = jnp.arange(num_tiles, dtype=jnp.int32)
    tx_all = tile_ids % tiles_x
    ty_all = tile_ids // tiles_x

    def bins_for_tiles(tx: jax.Array, ty: jax.Array):
        """(C,) tile coords -> ((C, K) indices, (C,) count)."""
        overlap = (
            valid[None, :]
            & (tx[:, None] >= x0[None, :])
            & (tx[:, None] <= x1[None, :])
            & (ty[:, None] >= y0[None, :])
            & (ty[:, None] <= y1[None, :])
        )  # (C, G)
        count = jnp.sum(overlap, axis=-1).astype(jnp.int32)
        # Front-most K: the smallest overlapping indices, ascending.
        cand = jnp.where(overlap, iota_g[None, :], sentinel)
        if select == "sort":
            return jnp.sort(cand, axis=-1)[..., :k], count
        # top_k on the negated candidates returns them descending ->
        # negate back = ascending.
        neg_topk, _ = jax.lax.top_k(-cand, k)
        return -neg_topk, count

    if tile_chunk is None or tile_chunk >= num_tiles:
        indices, count = bins_for_tiles(tx_all, ty_all)
    else:
        pad = (-num_tiles) % tile_chunk
        # Padding tiles point off-grid (match nothing via x0/x1 clamped range
        # is impossible, so use coordinate -1 which is < every x0 >= 0).
        tx_p = jnp.pad(tx_all, (0, pad), constant_values=-1)
        ty_p = jnp.pad(ty_all, (0, pad), constant_values=-1)
        txc = tx_p.reshape(-1, tile_chunk)
        tyc = ty_p.reshape(-1, tile_chunk)
        indices, count = jax.lax.map(
            lambda args: bins_for_tiles(*args), (txc, tyc)
        )
        indices = indices.reshape(-1, k)[:num_tiles]
        count = count.reshape(-1)[:num_tiles]

    return TileBins(
        indices=indices,
        count=jnp.minimum(count, jnp.int32(k)),
        overflowed=count > k,
        tiles_y=tiles_y,
        tiles_x=tiles_x,
        tile_size=tile_size,
    )


# ---------------------------------------------------------------------------
# Binned blending
# ---------------------------------------------------------------------------


def _pad_features(feats: GaussianFeatures) -> GaussianFeatures:
    """Append one all-zero record at index G — the sentinel gather target."""
    def pad1(x):
        widths = [(0, 1)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths)

    return jax.tree.map(pad1, feats)


def tile_origins(
    tiles_y: int, tiles_x: int, tile_size: int, dtype=jnp.float32
) -> jax.Array:
    """(T, 2) pixel-space origin (x, y) of each tile, row-major tile order."""
    tile_ids = jnp.arange(tiles_y * tiles_x, dtype=jnp.int32)
    return jnp.stack(
        [(tile_ids % tiles_x) * tile_size, (tile_ids // tiles_x) * tile_size],
        axis=-1,
    ).astype(dtype)


def untile_image(
    out: jax.Array, tiles_y: int, tiles_x: int, tile_size: int,
    height: int, width: int,
) -> jax.Array:
    """(..., T, tile^2, 3) row-major blended tiles -> (..., H, W, 3) crop."""
    lead = out.shape[:-3]
    img = out.reshape(lead + (tiles_y, tiles_x, tile_size, tile_size, 3))
    n = len(lead)
    perm = tuple(range(n)) + (n, n + 2, n + 1, n + 3, n + 4)
    img = img.transpose(perm).reshape(
        lead + (tiles_y * tile_size, tiles_x * tile_size, 3)
    )
    return img[..., :height, :width, :]


def _tile_pixel_offsets(tile_size: int, dtype=jnp.float32) -> jax.Array:
    """(tile_size^2, 2) pixel-center offsets within one tile (x, y)."""
    ys, xs = jnp.meshgrid(
        jnp.arange(tile_size, dtype=dtype) + 0.5,
        jnp.arange(tile_size, dtype=dtype) + 0.5,
        indexing="ij",
    )
    return jnp.stack([xs.reshape(-1), ys.reshape(-1)], axis=-1)


# A chunk scan stops once every pixel's transmittance is below this: any
# remaining contribution is smaller than one u8 quantization step. (Alias of
# core.constants.EARLY_EXIT_EPS — the in-kernel early exit of the fused
# Pallas path uses the same cutoff, so both early exits share one bound.)
EARLY_EXIT_EPS = constants.EARLY_EXIT_EPS

# Scan-chunk width of the binned blender's per-tile list traversal (the
# early-exit granularity). Implementation detail, not a config knob: results
# are chunk-size invariant up to f32 reassociation.
SCAN_CHUNK = 64


def blend_tile_chunks(
    feats_pad: GaussianFeatures,
    indices: jax.Array,
    origins: jax.Array,
    counts: jax.Array,
    background: jax.Array,
    *,
    tile_size: int,
    sentinel: int,
    tile_chunk: int | None = 64,
    early_exit: bool = True,
) -> jax.Array:
    """Chunked-scan blender over explicit per-tile work lists.

    The shared blending engine behind :func:`rasterize_binned` (one camera,
    tiles in row-major order) and the batched multi-camera path
    (``repro.core.multicam``, tiles pooled across cameras and count-sorted
    for load balance). The caller owns the tile *schedule*; this function
    owns the math.

    Args:
      feats_pad: gather source; every field has leading axis M, and row
        ``sentinel`` (and any other index used as list padding) must be an
        all-zero record so sentinel lanes blend as alpha 0.
      indices: (Tn, K) int32 rows into ``feats_pad``, ascending depth order
        per tile, padded with ``sentinel``.
      origins: (Tn, 2) pixel-space origin (x, y) of each tile.
      counts: (Tn,) int32 live entries per tile (drives the sentinel skip).
      background: (3,) background color.
      tile_size: tile edge in pixels.
      sentinel: the padding index (used for internal tile/list padding too).
      tile_chunk: tiles blended per ``lax.map`` step; None = all at once.
      early_exit: also stop a chunk's scan once every pixel's transmittance
        saturates below :data:`EARLY_EXIT_EPS`.

    Returns (Tn, tile_size^2, 3) blended tiles (background already applied).

    The per-tile list is traversed in :data:`SCAN_CHUNK`-wide chunks
    (front-to-back); a chunk of the scan is skipped entirely once

    * the remaining entries of every tile in the chunk are sentinels (exact:
      sentinels gather all-zero records and blend as alpha 0), or
    * with ``early_exit``, every pixel's transmittance has saturated below
      :data:`EARLY_EXIT_EPS` — front-most-first ordering means whatever is
      left cannot move a u8 pixel by a quantization step.

    The skip is a ``lax.cond`` on a scalar predicate (aggregated over the
    ``tile_chunk`` tiles blended together), so it is a real compute saving
    under ``jit`` and remains reverse-mode differentiable.
    """
    from repro.core import rasterize as rast_lib  # late: avoid import cycle

    tile = tile_size
    num_tiles = indices.shape[0]
    dtype = feats_pad.uv.dtype
    offsets = _tile_pixel_offsets(tile, dtype=dtype)
    sentinel = jnp.int32(sentinel)

    k = indices.shape[-1]
    sc = min(SCAN_CHUNK, k)
    pad_k = (-k) % sc
    idx_all = jnp.pad(indices, ((0, 0), (0, pad_k)), constant_values=sentinel)
    num_scan = (k + pad_k) // sc

    def blend_tiles(idx: jax.Array, org: jax.Array, count: jax.Array) -> jax.Array:
        """((C, S*sc) indices, (C, 2) origins, (C,) counts) -> (C, tile^2, 3)."""
        c_tiles = idx.shape[0]
        pix = org[:, None, :] + offsets[None, :, :]  # (C, tp, 2)
        idx_chunks = idx.reshape(c_tiles, num_scan, sc).transpose(1, 0, 2)

        def step(carry, xs):
            t_run, acc = carry  # (C, tp, 1), (C, tp, 3)
            s, idx_c = xs  # scalar step, (C, sc) indices
            live = jnp.any(count > s * sc)
            if early_exit:
                live = live & (jnp.max(t_run) >= EARLY_EXIT_EPS)

            def blend(c):
                t_run, acc = c
                tile_feats = jax.tree.map(lambda x: x[idx_c], feats_pad)
                # The dense oracle's alpha model, vmapped over tiles: the
                # binned path inherits _pixel_alphas' support contract
                # (alpha floor + 3-sigma box) verbatim.
                alpha = jax.vmap(rast_lib._pixel_alphas)(pix, tile_feats)
                cum = jnp.cumprod(1.0 - alpha, axis=-1)  # (C, tp, sc)
                t_prev = jnp.concatenate(
                    [jnp.ones_like(cum[..., :1]), cum[..., :-1]], axis=-1
                )
                w = alpha * t_prev * t_run  # (C, tp, sc)
                rgb = jnp.einsum("cps,csk->cpk", w, tile_feats.color)
                return t_run * cum[..., -1:], acc + rgb

            return jax.lax.cond(live, blend, lambda c: c, (t_run, acc)), None

        init = (
            jnp.ones((c_tiles, tile * tile, 1), dtype),
            jnp.zeros((c_tiles, tile * tile, 3), dtype),
        )
        (t_fin, acc), _ = jax.lax.scan(
            step, init, (jnp.arange(num_scan, dtype=jnp.int32), idx_chunks)
        )
        return acc + t_fin * background[None, None, :]

    if tile_chunk is None or tile_chunk >= num_tiles:
        return blend_tiles(idx_all, origins, counts)  # (Tn, tp, 3)

    pad = (-num_tiles) % tile_chunk
    idx_p = jnp.pad(idx_all, ((0, pad), (0, 0)), constant_values=sentinel)
    org_p = jnp.pad(origins, ((0, pad), (0, 0)))
    cnt_p = jnp.pad(counts, (0, pad))
    out = jax.lax.map(
        lambda args: blend_tiles(*args),
        (
            idx_p.reshape(-1, tile_chunk, k + pad_k),
            org_p.reshape(-1, tile_chunk, 2),
            cnt_p.reshape(-1, tile_chunk),
        ),
    )
    return out.reshape(-1, tile * tile, 3)[:num_tiles]


def rasterize_binned(
    feats_sorted: GaussianFeatures,
    bins: TileBins,
    height: int,
    width: int,
    background: jax.Array,
    *,
    tile_chunk: int | None = 64,
    early_exit: bool = True,
) -> jax.Array:
    """Blend each tile against its index list only. Returns (H, W, 3).

    ``feats_sorted`` must be the same depth-sorted features the bins were
    built from. Gradients flow through the per-tile feature gather; the
    indices themselves are discrete. The traversal/skip semantics live in
    :func:`blend_tile_chunks` (shared with the batched multi-camera path).
    """
    tile = bins.tile_size
    tiles_y, tiles_x = bins.tiles_y, bins.tiles_x
    feats_pad = _pad_features(feats_sorted)
    origin = tile_origins(tiles_y, tiles_x, tile, dtype=feats_sorted.uv.dtype)

    out = blend_tile_chunks(
        feats_pad,
        bins.indices,
        origin,
        bins.count,
        background,
        tile_size=tile,
        sentinel=feats_sorted.uv.shape[0],
        tile_chunk=tile_chunk,
        early_exit=early_exit,
    )
    return untile_image(out, tiles_y, tiles_x, tile, height, width)


# ---------------------------------------------------------------------------
# Gather-to-compact — dense per-tile feature tensors (the Pallas kernel diet)
# ---------------------------------------------------------------------------

# Compact feature record: uv(2) conic(3) color(3) radius opacity mask.
# Depth is deliberately absent — the lists are depth-ordered by construction.
COMPACT_FEAT_DIM = 11


def compact_tile_features(
    feats_sorted: GaussianFeatures, bins: TileBins
) -> jax.Array:
    """Gather each tile's index list into a dense (T, K, 11) feature tensor.

    Row ``[t, r]`` holds the features of the ``r``-th front-most Gaussian
    overlapping tile ``t`` for ``r < count[t]``, and all-zero sentinel
    records past the count (zero mask -> zero alpha, so consumers blend the
    tensor verbatim). This is the gather-to-compact stage: a kernel that
    streams rows of this tensor holds a *live* Gaussian in every lane,
    instead of blending masked-out lanes at 128-wide block granularity.

    Differentiable w.r.t. the features (the gather's VJP scatter-adds
    per-tile gradients back to per-Gaussian records, accumulating across
    tiles); the indices are discrete.
    """
    feats_pad = _pad_features(feats_sorted)
    g = jax.tree.map(lambda x: x[bins.indices], feats_pad)  # (T, K, ...)
    return jnp.concatenate(
        [
            g.uv,
            g.conic,
            g.color,
            g.radius[..., None],
            g.opacity[..., None],
            g.mask[..., None],
        ],
        axis=-1,
    )


def lane_occupancy_stats(
    feats_sorted: GaussianFeatures,
    height: int,
    width: int,
    *,
    tile_size: int = 16,
    capacity: int = DEFAULT_CAPACITY,
    block_g: int = 128,
) -> dict:
    """Live-lane fraction of the two Pallas work-list formats.

    A lane is *live* when it holds a Gaussian whose AABB overlaps the tile
    being blended. The block-list kernel streams whole 128-wide blocks of
    depth-consecutive Gaussians (a block is fetched if any member overlaps),
    so on non-uniform scenes most lanes are masked; the compacted lists
    waste lanes only in the final partial chunk of each tile.

    Each format's numerator matches what *it* actually blends: the compact
    lists are capped at ``capacity`` (front-most win on overflow), the block
    lists are not — so under overflow the block kernel blends *more* live
    lanes than the compact one, and the comparison stays fair.

    Beyond the per-tile-list aggregate, the ``chunk_*`` keys report
    *per-chunk* occupancy — the block_g-wide chunk is the streaming unit of
    the compacted kernels (one fetch, one blend step, and the granularity
    at which the fused kernel's early exit can stop), so chunk-level
    occupancy is what governs how much a skipped chunk actually saves.
    Compaction makes every chunk except each tile's tail fully live:
    ``chunk_full_fraction`` is the fraction of chunks with all ``block_g``
    lanes live, ``chunk_tail_occupancy`` the mean live fraction of the
    partial tail chunks, and ``chunks_per_tile_mean``/``_max`` the
    early-exit headroom (how many steps a saturated tile can skip).
    """
    import numpy as np

    g = feats_sorted.uv.shape[0]
    bins = bin_gaussians(
        feats_sorted, height, width, tile_size=tile_size, capacity=capacity
    )
    count = np.asarray(bins.count)
    live = int(count.sum())

    nsteps = -(-count // block_g)  # per-tile compacted chunk count
    compact_lanes = int(nsteps.sum()) * block_g

    # Per-chunk view of the same lists: every chunk is full except each
    # tile's tail (count % block_g live lanes, when nonzero).
    chunk_count = int(nsteps.sum())
    full_chunks = int((count // block_g).sum())
    tail = count % block_g
    tail = tail[tail > 0]
    chunk_tail_occupancy = (
        float((tail / block_g).mean()) if tail.size else 1.0
    )

    block_ids, num_blocks, _ = tile_block_lists(
        feats_sorted, height, width, tile_size=tile_size, block_g=block_g
    )
    block_lanes = int((np.asarray(block_ids) < num_blocks).sum()) * block_g
    # Uncapped overlap total — the block kernel has no capacity cap.
    full = bin_gaussians(
        feats_sorted, height, width, tile_size=tile_size, capacity=g
    )
    live_uncapped = int(np.asarray(full.count).sum())

    return {
        "live_lanes": live,
        "live_lanes_uncapped": live_uncapped,
        "compact_lanes": compact_lanes,
        "compact_occupancy": live / max(compact_lanes, 1),
        "block_lanes": block_lanes,
        "block_occupancy": live_uncapped / max(block_lanes, 1),
        "overflow_rate": float(np.asarray(bins.overflowed).mean()),
        "chunk_count": chunk_count,
        "chunk_full_fraction": full_chunks / max(chunk_count, 1),
        "chunk_tail_occupancy": chunk_tail_occupancy,
        "chunks_per_tile_mean": float(nsteps.mean()),
        "chunks_per_tile_max": int(nsteps.max()),
    }


# ---------------------------------------------------------------------------
# Per-tile *block* lists — the Pallas kernel's consumption format
# ---------------------------------------------------------------------------


def tile_block_lists(
    feats_sorted: GaussianFeatures,
    height: int,
    width: int,
    *,
    tile_size: int = 16,
    block_g: int = 128,
    max_blocks: int | None = None,
) -> tuple[jax.Array, int, int]:
    """Per-tile lists of depth-consecutive Gaussian *blocks* (width block_g).

    The Pallas kernel streams whole (FEAT_ROWS, block_g) feature blocks
    through VMEM; its unit of sparsity is therefore the block, not the
    Gaussian. A block is live for a tile if any of its Gaussians' AABBs
    overlap the tile. Lists are ascending (= front-to-back, features sorted),
    padded with the sentinel ``num_blocks`` — which indexes one extra
    all-zero block the ops wrapper appends.

    Returns (block_ids (T, max_blocks) int32, num_blocks, max_blocks).
    """
    g = feats_sorted.uv.shape[0]
    num_blocks = -(-g // block_g)
    if max_blocks is None:
        max_blocks = num_blocks
    max_blocks = min(max_blocks, num_blocks)
    tiles_y, tiles_x = tile_grid_shape(height, width, tile_size)
    num_tiles = tiles_y * tiles_x

    x0, x1, y0, y1, valid = gaussian_tile_bounds(
        feats_sorted, height, width, tile_size
    )
    pad = num_blocks * block_g - g

    def pad_b(v, fill):
        return jnp.pad(v, (0, pad), constant_values=fill).reshape(
            num_blocks, block_g
        )

    # Per-block AABB over its member Gaussians (invalid members excluded).
    big = jnp.int32(1 << 29)
    bx0 = jnp.min(pad_b(jnp.where(valid, x0, big), big), axis=1)
    by0 = jnp.min(pad_b(jnp.where(valid, y0, big), big), axis=1)
    bx1 = jnp.max(pad_b(jnp.where(valid, x1, -big), -big), axis=1)
    by1 = jnp.max(pad_b(jnp.where(valid, y1, -big), -big), axis=1)
    bvalid = jnp.max(pad_b(valid, False), axis=1)

    # NOTE: block AABB is a conservative union — a block whose Gaussians
    # surround but miss a tile is still listed (correct, just not minimal).
    tile_ids = jnp.arange(num_tiles, dtype=jnp.int32)
    tx = (tile_ids % tiles_x)[:, None]
    ty = (tile_ids // tiles_x)[:, None]
    live = (
        bvalid[None, :]
        & (tx >= bx0[None, :])
        & (tx <= bx1[None, :])
        & (ty >= by0[None, :])
        & (ty <= by1[None, :])
    )  # (T, num_blocks)

    iota_b = jnp.arange(num_blocks, dtype=jnp.int32)
    cand = jnp.where(live, iota_b[None, :], jnp.int32(num_blocks))
    neg_topk, _ = jax.lax.top_k(-cand, max_blocks)
    return -neg_topk, num_blocks, max_blocks
