from repro.serve.render_server import RenderResult, RenderServer, replay_schedule
from repro.serve.server import BatchedServer, GenerationResult

__all__ = [
    "BatchedServer",
    "GenerationResult",
    "RenderResult",
    "RenderServer",
    "replay_schedule",
]
