from repro.serve.render_server import RenderResult, RenderServer
from repro.serve.server import BatchedServer, GenerationResult

__all__ = ["BatchedServer", "GenerationResult", "RenderResult", "RenderServer"]
