from repro.serve.server import BatchedServer, GenerationResult

__all__ = ["BatchedServer", "GenerationResult"]
