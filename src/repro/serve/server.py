"""Batched serving: prefill + jitted greedy/temperature decode loop.

The decode step is exactly what the ``decode_32k`` / ``long_500k`` dry-run
cells lower: one token per sequence against a (seq-sharded) KV/SSM state.
Requests are padded into fixed batch slots (static shapes); a production
deployment would add continuous batching on top of the same two jitted
functions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as P
from repro.models.api import ModelConfig, family_module


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, steps)
    logprobs: np.ndarray  # (B, steps)


class BatchedServer:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        max_seq: int = 512,
        temperature: float = 0.0,
    ):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self.mod = family_module(cfg)
        self._prefill = jax.jit(
            lambda p, b: self.mod.prefill(cfg, p, b, self.max_seq)
        )
        self._decode = jax.jit(lambda p, s, t: self.mod.decode_step(cfg, p, s, t))

    def _sample(self, logits: jax.Array, key: jax.Array) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature).astype(
            jnp.int32
        )

    def generate(
        self,
        batch: dict,
        steps: int,
        *,
        seed: int = 0,
    ) -> GenerationResult:
        """batch: family-specific prompt inputs (tokens [+frames/patches])."""
        state, logits = self._prefill(self.params, batch)
        # Every sample folds its step index into the base key BEFORE use —
        # the pre-loop sample is step 0, the loop samples are 1..steps. The
        # raw PRNGKey(seed) is never consumed directly, so no two samples
        # (and no other consumer of the seed) share a key.
        key = jax.random.PRNGKey(seed)
        toks, lps = [], []
        tok = self._sample(logits, jax.random.fold_in(key, 0))
        for i in range(steps):
            lp = jax.nn.log_softmax(logits, axis=-1)
            lps.append(jnp.take_along_axis(lp, tok[:, None], axis=-1)[:, 0])
            toks.append(tok)
            state, logits = self._decode(self.params, state, tok)
            tok = self._sample(logits, jax.random.fold_in(key, i + 1))
        return GenerationResult(
            tokens=np.stack([np.asarray(t) for t in toks], axis=1),
            logprobs=np.stack([np.asarray(l) for l in lps], axis=1),
        )
