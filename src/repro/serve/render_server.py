"""Async render serving — continuous batching over a persistent slot table.

The deployment shape the paper targets: one trained Gaussian model, a stream
of camera requests, throughput as the figure of merit. PR 3's server grouped
requests into micro-batch windows and **drained** each window before admitting
new work, so one slow window capped req/s. This server schedules the way
Orca-style iteration-level batching and vLLM's slot reuse do (PAPERS.md):

* a **persistent slot table** of ``max_batch`` lanes backs one fixed-width
  ``render_batch_masked`` executable per image-size bucket. A slot holds at
  most one request; free slots render as masked sentinel cameras whose
  blend work is skipped entirely (features/binning still pay the batch
  width — see ``core.multicam.render_batch_masked``), unlike the
  micro-batching baseline's copied-camera padding, which blends at full
  price;
* the scheduler **admits continuously — no batching window**. An idle
  server dispatches the moment a request arrives (partial steps are fine:
  masked slots cost ~0); while a step renders, arrivals accumulate into
  the next full-width step, and the instant the step's compute finishes
  (``is_ready``) its slots are freed and the next step is dispatched
  *before* the finished step's host-side harvest runs — XLA renders the
  new step while device transfer, stats, and future fan-out happen, so a
  request waits only for compute it genuinely contends with, never for a
  window and never for bookkeeping;
* every render finishes in exactly one step, so **harvesting a step frees
  its slots** and the queue refills them without waiting for any other
  in-flight work. **Per-slot generation counters** stamp each assignment;
  a harvested lane only routes its image to the future whose generation it
  carries, so a reused slot can never deliver a stale frame;
* **mixed image sizes** are admitted via a small set of bucketed
  executables (``sizes=[(128, 128), (256, 256)]``): each step serves one
  bucket (chosen oldest-waiting-first — FIFO across buckets, starvation
  free), requests for a size outside the bucket set are rejected at submit.
  The static-shape contract survives: one compiled executable per bucket,
  any occupancy pattern hits it via the traced ``active`` mask.

``mode="microbatch"`` keeps PR 3's window-then-drain scheduler as the
measured baseline (``benchmarks/bench_serving.py`` sweeps the two against
identical arrival schedules).

The server is raster-path agnostic: its :class:`RenderConfig` travels into
``render_batch_masked`` unchanged, so ``raster_path="pallas_fused"`` serves
through the fused streaming kernel (requests render camera-major under the
slot mask, and a free slot skips the fused chunk loops entirely).

Cancellation: a request's future is *claimed* with
``set_running_or_notify_cancel()`` at admission — a future cancelled while
queued silently gives its slot to the next request, and a claimed future can
no longer be cancelled, so result fan-out never races a cancel into
``InvalidStateError`` (which previously poisoned every other request in the
group).
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camera import Camera, look_at_camera
from repro.core.config import RenderConfig, as_config
from repro.core.gaussians import GaussianParams
from repro.core.multicam import (
    CameraBatch,
    render_batch_jit,
    render_batch_masked_jit,
    stack_cameras,
)
from repro.core.scene import SceneTree, build_scene_tree
from repro.obs.metrics import DEFAULT_LATENCY_BUCKETS_MS, Registry
from repro.obs.slo import SLOMonitor, SLOTargets
from repro.obs.tracing import Tracer, span

MODES = ("continuous", "microbatch")

# Bucket bounds for the per-step real-request count (slot-table width is
# small, so fine-grained powers of two resolve occupancy exactly).
BATCH_SIZE_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0)


@dataclasses.dataclass
class RenderResult:
    """One served frame plus its request-level timing."""

    image: np.ndarray  # (H, W, 3)
    latency_ms: float  # enqueue -> result available
    batch_size: int  # real requests in the step/batch that served this one


def replay_schedule(submit, cams, gaps):
    """Replay an open-loop arrival schedule against ``submit``.

    ``gaps`` holds inter-arrival seconds (all zeros = one burst at t0).
    ``submit`` may return a Future (async server) or a final value
    (synchronous baseline); futures are resolved after the stream ends.
    Returns ``(results, wall_seconds)`` with wall measured from t0 to the
    last result. Shared by ``examples/serve_render.py`` and
    ``benchmarks/bench_serving.py`` so example and benchmark replay
    byte-identical offered load.
    """
    t_start = time.perf_counter()
    out = []
    target = t_start
    for gap, cam in zip(gaps, cams):
        target += gap
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        out.append(submit(cam))
    results = [f.result() if hasattr(f, "result") else f for f in out]
    return results, time.perf_counter() - t_start


@dataclasses.dataclass
class _Request:
    camera: Camera
    future: Future
    t_enqueue: float


@dataclasses.dataclass
class _Lane:
    """One slot assignment inside an in-flight step."""

    slot: int
    gen: int  # the slot's generation counter at assignment time
    req: _Request


@dataclasses.dataclass
class _Step:
    """One dispatched (asynchronous) masked-batch render."""

    bucket: tuple[int, int]
    lanes: list[_Lane]
    images: jax.Array  # (max_batch, H, W, 3); a device future until ready
    t_dispatch: float = 0.0  # perf_counter at async dispatch
    t_ready: float = 0.0  # perf_counter when is_ready() was observed


class RenderServer:
    """Continuous-batching render server over a resident Gaussian model.

    Args:
      model: the Gaussian cloud to serve (resident for the server lifetime).
        With ``config.cull`` a raw cloud is promoted to a
        :class:`~repro.core.scene.SceneTree` **once at startup**
        (``config.leaf_size`` chunks), so every request renders against
        the resident hierarchy: each step's executables frustum-cull per
        camera and touch only the visible chunks. A prebuilt tree is also
        accepted (e.g. shared across servers).
      config: render configuration (static -> one executable per bucket).
      width, height: the (single) image-size bucket when ``sizes`` is not
        given — the PR 3 signature, still the common case.
      sizes: optional sequence of ``(width, height)`` buckets the server
        admits. Requests are routed to their exact bucket; any other size is
        rejected at submit (the static-shape contract: one compiled
        executable per bucket, never a fresh compile from traffic).
      max_batch: slot-table width (the padded render width of every bucket).
      max_wait_ms: micro-batching window (``mode="microbatch"`` only) — how
        long the batcher waits for the batch to fill after the first
        request arrives. The continuous scheduler never waits.
      mode: ``"continuous"`` (slot table, refill-at-completion, dispatch
        pipelined ahead of harvest — the default) or ``"microbatch"``
        (PR 3's window-then-drain baseline; single bucket only).
      registry: metrics registry (``repro.obs``) the server reports into
        (latency/batch-size histograms, request counters, compile gauges,
        resident-model footprint). Defaults to a fresh private
        :class:`~repro.obs.metrics.Registry`; pass one to share a
        ``/metrics`` endpoint across components. All instruments are
        bounded (ring-buffer percentiles), so a long-lived server's stats
        cost O(ring) memory, never O(requests).
      tracer: optional :class:`~repro.obs.tracing.Tracer`. When set, every
        served request emits ``queue`` / ``render`` / ``harvest`` spans on
        a logical per-slot trace row, stamped with the slot's generation
        counter at assignment — load the saved trace in Perfetto to see
        admission waits, step packing, and the dispatch-ahead-of-harvest
        overlap. ``None`` (default) is a zero-cost no-op.
      slo: optional live SLO monitoring (``repro.obs.slo``). Pass
        :class:`~repro.obs.slo.SLOTargets` to have the server build an
        :class:`~repro.obs.slo.SLOMonitor` on its own registry, or a
        prebuilt monitor to share one across surfaces (e.g. with
        ``serve_metrics(..., slo=monitor)`` for ``/healthz`` + ``/slo``).
        The server feeds it admission/completion/rejection events and
        per-request latencies; the rolling-window health state appears
        under ``stats()["slo"]`` and as ``slo_*`` gauges. ``None``
        (default) is a zero-cost no-op.
    """

    def __init__(
        self,
        model: GaussianParams,
        config: RenderConfig | None = None,
        *,
        width: int = 128,
        height: int = 128,
        sizes: Sequence[tuple[int, int]] | None = None,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
        mode: str = "continuous",
        registry: Registry | None = None,
        tracer: Tracer | None = None,
        slo: SLOTargets | SLOMonitor | None = None,
    ):
        if mode not in MODES:
            raise ValueError(f"mode={mode!r} not in {MODES}")
        self.config = as_config(config)
        promote = self.config.cull or self.config.compress != "none"
        if promote and not isinstance(model, SceneTree):
            model = build_scene_tree(
                model,
                leaf_size=self.config.leaf_size,
                compress=self.config.compress,
            )
        self.model: GaussianParams | SceneTree = model
        if sizes is None:
            sizes = [(int(width), int(height))]
        self.buckets: tuple[tuple[int, int], ...] = tuple(
            dict.fromkeys((int(w), int(h)) for w, h in sizes)
        )
        if not self.buckets:
            raise ValueError("server needs at least one image-size bucket")
        if mode == "microbatch" and len(self.buckets) > 1:
            raise ValueError(
                "microbatch mode is the single-size PR 3 baseline; "
                "mixed-size buckets need mode='continuous'"
            )
        # Back-compat attributes: the primary bucket.
        self.width, self.height = self.buckets[0]
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.mode = mode

        self._queue: queue.Queue[_Request | None] = queue.Queue()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._stopping = False
        self.compile_ms: float | None = None  # summed across buckets
        self.compile_ms_by_bucket: dict[tuple[int, int], float] = {}
        # Sentinel camera per bucket: fills free slots (masked -> ~0 work).
        self._sentinels = {
            (w, h): look_at_camera(
                (0.0, 1.0, -5.0), (0.0, 0.0, 0.0), width=w, height=h
            )
            for (w, h) in self.buckets
        }
        # Slot table (scheduler-thread-private after start).
        self._slot_req: list[_Request | None] = [None] * self.max_batch
        self._slot_gen: list[int] = [0] * self.max_batch
        # Stats live in a metrics registry (repro.obs): bounded ring-buffer
        # histograms replace the unbounded per-request lists the server
        # used to append to — memory is O(ring_size) for the lifetime.
        self.registry = registry if registry is not None else Registry()
        self.tracer = tracer
        # SLOTargets -> build a monitor on this server's registry (gauges
        # ride the same /metrics exposition); a prebuilt SLOMonitor is
        # adopted as-is so one monitor can back serve_metrics' /healthz.
        if isinstance(slo, SLOTargets):
            slo = SLOMonitor(slo, registry=self.registry, mode=self.mode)
        self.slo: SLOMonitor | None = slo
        self._lat = self.registry.histogram(
            "render_server_latency_ms",
            "Request latency, enqueue to result available (ms)",
            buckets=DEFAULT_LATENCY_BUCKETS_MS,
        ).labels(mode=self.mode)
        self._batch = self.registry.histogram(
            "render_server_batch_size",
            "Real (unmasked) requests per dispatched step/batch",
            buckets=BATCH_SIZE_BUCKETS,
        ).labels(mode=self.mode)
        self._requests_total = self.registry.counter(
            "render_server_requests_total", "Requests admitted by submit()"
        ).labels(mode=self.mode)
        self._rejected_total = self.registry.counter(
            "render_server_rejected_total",
            "Requests rejected at submit (size outside the bucket set)",
        ).labels(mode=self.mode)
        self._compile_gauge = self.registry.gauge(
            "render_server_compile_ms",
            "Warmup compile time per image-size bucket (ms)",
        )
        mem = self.memory_stats()
        if mem is not None:
            from repro.obs.pipeline import fold_memory

            fold_memory(self.registry, mem)

    # -- lifecycle ---------------------------------------------------------

    def warmup(self, camera: Camera | None = None) -> float:
        """Compile every bucket's fixed-shape executable; returns summed ms.

        Serving latencies must not fold compile time into request 0 — call
        this before accepting traffic (``start`` does it for you).
        """
        total = 0.0
        for bucket in self.buckets:
            cam = self._sentinels[bucket]
            if camera is not None and (camera.width, camera.height) == bucket:
                cam = camera
            batch = stack_cameras([cam] * self.max_batch)
            t0 = time.perf_counter()
            bucket_name = f"{bucket[0]}x{bucket[1]}"
            with span(
                "warmup_compile", tracer=self.tracer,
                bucket=bucket_name, mode=self.mode,
            ):
                if self.mode == "continuous":
                    active = jnp.ones((self.max_batch,), dtype=bool)
                    render_batch_masked_jit(
                        self.model, batch, active, self.config
                    ).block_until_ready()
                else:
                    render_batch_jit(
                        self.model, batch, self.config
                    ).block_until_ready()
            ms = (time.perf_counter() - t0) * 1e3
            self.compile_ms_by_bucket[bucket] = ms
            self._compile_gauge.set(ms, bucket=bucket_name, mode=self.mode)
            total += ms
        self.compile_ms = total
        self._compile_gauge.set(total, bucket="total", mode=self.mode)
        return total

    def start(self) -> "RenderServer":
        if self.compile_ms is None:
            self.warmup()
        target = (
            self._scheduler_loop
            if self.mode == "continuous"
            else self._microbatch_loop
        )
        # Check-and-set under the lock: two racing start() calls must not
        # both see `_thread is None` and spawn two scheduler loops.
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("server already started")
            self._stopping = False
            self._thread = threading.Thread(target=target, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        # Flip the stopping flag under the same lock submit() enqueues
        # under: every successful submit's put strictly precedes the poison
        # pill, so the scheduler either serves it or its drain rejects it —
        # no future is ever stranded. The thread handle is claimed under
        # the same lock (so concurrent stop() calls join exactly once) but
        # joined outside it, or submit()'s rejection path would deadlock.
        with self._lock:
            thread = self._thread
            if thread is None:
                return
            self._thread = None
            self._stopping = True
            self._queue.put(None)  # poison pill
        thread.join()

    def __enter__(self) -> "RenderServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, camera: Camera) -> Future:
        """Enqueue one camera request; resolves to a :class:`RenderResult`."""
        key = (camera.width, camera.height)
        if key not in self._sentinels:
            self._rejected_total.inc()
            if self.slo is not None:
                self.slo.note_reject()
            raise ValueError(
                f"request size {key} not in the server's static bucket set "
                f"{self.buckets} (one compiled executable per bucket; pass "
                "the size via sizes= at construction to admit it)"
            )
        req = _Request(camera=camera, future=Future(), t_enqueue=time.perf_counter())
        with self._lock:
            if self._thread is None or self._stopping:
                raise RuntimeError("server not started")
            self._queue.put(req)
        self._requests_total.inc()
        if self.slo is not None:
            # Queue-depth accounting rides the future's own lifecycle: the
            # done callback fires on result, exception, AND cancel, so the
            # admitted count can never leak a phantom depth unit no matter
            # which path resolves the request.
            self.slo.note_admit()
            req.future.add_done_callback(lambda _f: self.slo.note_done())
        return req.future

    def render(self, camera: Camera) -> RenderResult:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(camera).result()

    def memory_stats(self) -> dict | None:
        """Resident-model footprint (``SceneTree.memory_stats``); None for
        raw clouds served without promotion."""
        if isinstance(self.model, SceneTree):
            return self.model.memory_stats()
        return None

    def stats(self) -> dict:
        """Latency percentiles + slot/batch occupancy over the lifetime.

        Built from the server's registry instruments: counts and means are
        exact over the lifetime, percentiles come from the histogram's
        bounded ring (the most recent ``ring_size`` observations) — so the
        schema is unchanged from the unbounded-list era but the memory is
        O(ring), never O(requests). ``memory`` reports the resident
        model's footprint (bytes by field, compression ratio) when the
        server holds a :class:`SceneTree`; ``None`` when serving a raw
        cloud. ``slo`` carries the live monitor's ``snapshot()`` (state,
        rolling window, transition history) when one is attached; ``None``
        otherwise — same-schema either way so pollers never KeyError.
        """
        lat = self._lat.summary()
        bs = self._batch.summary()
        mean_bs = float(bs["mean"]) if bs["mean"] is not None else 0.0
        # None -> 0.0 on the idle server: same schema as the served case
        # so pollers never KeyError.
        return {
            "mode": self.mode,
            "requests": int(lat["count"]),
            "batches": int(bs["count"]),
            "compile_ms": self.compile_ms,
            "latency_ms_p50": float(lat["p50"] or 0.0),
            "latency_ms_p95": float(lat["p95"] or 0.0),
            "latency_ms_mean": float(lat["mean"] or 0.0),
            "mean_batch_size": mean_bs,
            "occupancy": mean_bs / self.max_batch,
            "memory": self.memory_stats(),
            "slo": self.slo.snapshot() if self.slo is not None else None,
        }

    # -- continuous scheduler ---------------------------------------------

    def _drain_arrivals(
        self,
        pending: dict[tuple[int, int], collections.deque],
        *,
        block: bool,
        timeout: float | None = None,
    ) -> bool:
        """Move queued arrivals into per-bucket pending deques.

        Waits for the first item only when ``block`` (up to ``timeout``
        seconds; None = indefinitely); everything already queued behind it
        drains without blocking. Returns True once the poison pill is seen.
        """
        stopping = False
        first = True
        while True:
            try:
                if first and block:
                    item = self._queue.get(timeout=timeout)
                else:
                    item = self._queue.get_nowait()
            except queue.Empty:
                return stopping
            first = False
            if item is None:
                stopping = True
            else:
                pending[(item.camera.width, item.camera.height)].append(item)

    @staticmethod
    def _pick_bucket(
        pending: dict[tuple[int, int], collections.deque],
    ) -> tuple[int, int] | None:
        """Bucket whose head request has waited longest (FIFO across sizes)."""
        best, t_best = None, float("inf")
        for bucket, dq in pending.items():
            if dq and dq[0].t_enqueue < t_best:
                best, t_best = bucket, dq[0].t_enqueue
        return best

    def _dispatch(
        self, bucket: tuple[int, int], dq: collections.deque, free: list[int]
    ) -> _Step | None:
        """Fill free slots from one bucket's pending deque; dispatch async.

        Claims each future via ``set_running_or_notify_cancel`` — a request
        cancelled while queued never occupies a slot, and a claimed future
        can no longer be cancelled out from under the in-flight render.
        """
        lanes: list[_Lane] = []
        free_iter = iter(free)
        slot = next(free_iter, None)
        while dq and slot is not None:
            req = dq.popleft()
            if not req.future.set_running_or_notify_cancel():
                continue  # cancelled while queued; slot stays free
            self._slot_gen[slot] += 1
            self._slot_req[slot] = req
            lanes.append(_Lane(slot=slot, gen=self._slot_gen[slot], req=req))
            slot = next(free_iter, None)
        if not lanes:
            return None

        sentinel = self._sentinels[bucket]
        cams = [sentinel] * self.max_batch
        active = np.zeros((self.max_batch,), dtype=bool)
        for lane in lanes:
            cams[lane.slot] = lane.req.camera
            active[lane.slot] = True
        try:
            # Asynchronous dispatch: XLA renders on its own threads while
            # the scheduler returns to admitting the next step.
            images = render_batch_masked_jit(
                self.model, stack_cameras(cams), jnp.asarray(active), self.config
            )
        except Exception as e:  # fan the failure out, keep serving
            for lane in lanes:
                self._slot_req[lane.slot] = None
                if not lane.req.future.done():
                    lane.req.future.set_exception(e)
            return None
        return _Step(
            bucket=bucket, lanes=lanes, images=images,
            t_dispatch=time.perf_counter(),
        )

    def _harvest(self, step: _Step) -> None:
        """Block on a step's images and fan results out to its lanes.

        Slot freeing is NOT done here: the scheduler loop is the single
        owner of the slot table and frees a step's matching-generation
        slots the moment its compute is ready — before this harvest runs,
        so the next step can already be rendering. Each lane routes by its
        own (slot, gen, request) record, so a reused slot can never deliver
        to the wrong future.
        """
        try:
            images = np.asarray(jax.device_get(step.images))
        except Exception as e:
            for lane in step.lanes:
                if not lane.req.future.done():
                    lane.req.future.set_exception(e)
            return
        t_done = time.perf_counter()
        n = len(step.lanes)
        self._batch.observe(n)
        for lane in step.lanes:
            lat_ms = (t_done - lane.req.t_enqueue) * 1e3
            self._lat.observe(lat_ms)
            if self.slo is not None:
                self.slo.observe_latency(lat_ms)
        if self.tracer is not None:
            self._trace_step(step, t_done)
        for lane in step.lanes:
            if not lane.req.future.done():
                lane.req.future.set_result(
                    RenderResult(
                        image=images[lane.slot],
                        latency_ms=(t_done - lane.req.t_enqueue) * 1e3,
                        batch_size=n,
                    )
                )

    def _trace_step(self, step: _Step, t_done: float) -> None:
        """Emit per-request trace spans for one harvested step.

        Emitted at harvest because only then are all three boundaries
        known. Each lane gets a logical trace row per *slot* with three
        back-to-back spans — ``queue`` (enqueue -> dispatch: admission
        wait plus any compute the request contended with), ``render``
        (dispatch -> compute ready: the async XLA step the lane rode),
        ``harvest`` (ready -> fan-out: device transfer + bookkeeping,
        overlapped with the next step's render). ``args.gen`` carries the
        slot's generation counter at assignment, so a reused row's spans
        stay attributable to distinct requests.
        """
        tr = self.tracer
        n = len(step.lanes)
        bucket_name = f"{step.bucket[0]}x{step.bucket[1]}"
        for lane in step.lanes:
            tid = tr.lane_tid(lane.slot, f"slot {lane.slot}")
            args = {
                "slot": lane.slot, "gen": lane.gen,
                "bucket": bucket_name, "batch_size": n,
            }
            q0 = tr.ts_us(lane.req.t_enqueue)
            d0 = tr.ts_us(step.t_dispatch)
            r0 = tr.ts_us(step.t_ready)
            tr.emit("queue", q0, d0 - q0, tid=tid, cat="serve", args=args)
            tr.emit("render", d0, r0 - d0, tid=tid, cat="serve", args=args)
            tr.emit(
                "harvest", r0, tr.ts_us(t_done) - r0,
                tid=tid, cat="serve", args=args,
            )

    def _try_dispatch(
        self,
        pending: dict[tuple[int, int], collections.deque],
        inflight: collections.deque,
    ) -> bool:
        """Dispatch one step from the oldest-waiting bucket into the free
        slots; returns True if a step launched."""
        free = [i for i in range(self.max_batch) if self._slot_req[i] is None]
        bucket = self._pick_bucket(pending)
        if bucket is None or not free:
            return False
        step = self._dispatch(bucket, pending[bucket], free)
        if step is None:
            return False
        inflight.append(step)
        return True

    def _scheduler_loop(self) -> None:
        """Continuous batching: admit -> dispatch -> harvest, no windows.

        One step computes at a time (the substrate is one shared device —
        concurrent partial steps would just split the cores), but the
        pipeline still overlaps: the moment a step's compute finishes, its
        slots are freed and the *next* step is dispatched before the
        finished step's host-side harvest (device transfer, stats, future
        fan-out) runs — XLA renders the new step while results fan out.
        A request therefore waits only for compute it genuinely contends
        with, never for a batching window and never for host-side
        bookkeeping.
        """
        pending: dict[tuple[int, int], collections.deque] = {
            b: collections.deque() for b in self.buckets
        }
        inflight: collections.deque[_Step] = collections.deque()
        stopping = False
        while True:
            # Admit. Block only when fully idle (nothing pending anywhere,
            # nothing in flight); while a step renders, a 1 ms tick below
            # keeps arrivals flowing into the pending deques.
            idle = not inflight and not any(pending.values())
            stopping = self._drain_arrivals(
                pending, block=idle and not stopping
            ) or stopping

            if inflight:
                head = inflight[0]
                if head.images.is_ready():
                    head.t_ready = time.perf_counter()
                    # Refill-at-completion: compute is done, so the head's
                    # slots are free for the next step *before* its harvest
                    # — a reused slot's previous occupant may still be
                    # fanning out while the new step renders, which is why
                    # lanes route by their own (slot, gen, request) record.
                    # With single-step pipelining the gen guard below is an
                    # always-true invariant check (only the head ever holds
                    # slots); it is kept because it makes the reuse-before-
                    # delivery window auditable and stays correct if the
                    # pipeline ever deepens.
                    inflight.popleft()
                    for lane in head.lanes:
                        if self._slot_gen[lane.slot] == lane.gen:
                            self._slot_req[lane.slot] = None
                    self._try_dispatch(pending, inflight)
                    self._harvest(head)
                else:
                    # Head still rendering: wait for *arrivals*, not for
                    # the render — pending work keeps accumulating into
                    # the next full-width step.
                    stopping = (
                        self._drain_arrivals(pending, block=True, timeout=0.001)
                        or stopping
                    )
                continue

            # Nothing in flight: launch immediately with whatever is
            # pending (partial steps are fine — masked slots skip their
            # blend work and an idle server must never make a request
            # wait). One sub-millisecond coalesce tick first: siblings of
            # the same client burst are usually already in flight through
            # the queue, and catching them turns a 1-active ramp step into
            # a full one. This is interrupt coalescing, not a batching
            # window — 0.5 ms against a multi-ms render.
            if any(pending.values()) and sum(map(len, pending.values())) < self.max_batch:
                stopping = (
                    self._drain_arrivals(pending, block=True, timeout=0.0005)
                    or stopping
                )
            if self._try_dispatch(pending, inflight):
                continue
            # Exit only once every bucket's pending deque is empty: a
            # no-lane dispatch (e.g. the oldest bucket's requests were all
            # cancelled, or a dispatch error failed its lanes) must not
            # strand dispatchable work in *another* bucket — the loop
            # re-picks and drains it. Every retry pops at least one
            # request, so this terminates.
            if stopping and not any(pending.values()):
                break
        self._drain_after_stop()

    # -- micro-batching baseline (PR 3 semantics) --------------------------

    def _collect_window(self, first: _Request) -> list[_Request]:
        """Micro-batching window: up to max_batch requests or max_wait_ms."""
        group = [first]
        deadline = time.perf_counter() + self.max_wait_ms / 1e3
        while len(group) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                nxt = self._queue.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:  # poison pill mid-window: put back, serve group
                self._queue.put(None)
                break
            group.append(nxt)
        return group

    def _serve_batch(self, group: Sequence[_Request]) -> None:
        # Claim every future first: a request cancelled while it waited in
        # the window is dropped here, and a claimed future can no longer be
        # cancelled — so the set_result fan-out below cannot hit
        # InvalidStateError and poison the rest of the batch.
        live = [r for r in group if r.future.set_running_or_notify_cancel()]
        if not live:
            return
        # Pad to the slot count with sentinel cameras (static shapes); the
        # sentinel is a copy of the last real camera, its output discarded.
        pad = self.max_batch - len(live)
        cams = [r.camera for r in live] + [live[-1].camera] * pad
        batch: CameraBatch = stack_cameras(cams)
        with span(
            "microbatch_step", tracer=self.tracer,
            mode=self.mode, batch_size=len(live),
        ) as sp:
            imgs = render_batch_jit(self.model, batch, self.config)
            sp.fence(imgs)
        imgs = np.asarray(jax.device_get(imgs))
        t_done = time.perf_counter()
        self._batch.observe(len(live))
        for r in live:
            lat_ms = (t_done - r.t_enqueue) * 1e3
            self._lat.observe(lat_ms)
            if self.slo is not None:
                self.slo.observe_latency(lat_ms)
        for i, r in enumerate(live):
            if not r.future.done():
                r.future.set_result(
                    RenderResult(
                        image=imgs[i],
                        latency_ms=(t_done - r.t_enqueue) * 1e3,
                        batch_size=len(live),
                    )
                )

    def _microbatch_loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                break
            group = self._collect_window(req)
            try:
                self._serve_batch(group)
            except Exception as e:  # fan the failure out, keep serving
                for r in group:
                    if not r.future.done():
                        r.future.set_exception(e)
        self._drain_after_stop()

    # -- shared shutdown ---------------------------------------------------

    def _drain_after_stop(self) -> None:
        """Fail anything that raced in behind the poison pill (submit can
        pass the started check while stop() is joining) so no future is
        left unresolved forever."""
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("render server stopped before serving request")
                )
