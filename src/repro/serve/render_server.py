"""Async render serving — micro-batched camera requests over ``render_batch``.

The deployment shape the paper targets: one trained Gaussian model, a stream
of camera requests, throughput as the figure of merit. This server mirrors
``BatchedServer``'s static-shape discipline for the render path:

* requests enter a queue and are grouped by a **micro-batching window** —
  the batcher thread takes the first waiting request, then collects until
  either ``max_batch`` requests are in hand or ``max_wait_ms`` has elapsed
  since the window opened;
* the group is **padded to the fixed slot count** with sentinel cameras
  (copies of the last real request), so every batch hits the same compiled
  ``render_batch`` executable — no shape polymorphism, one warmup compile;
* results fan back out to per-request futures, and the server records
  per-request latency and batch occupancy (real requests / slots), the two
  numbers that tell you whether the window is tuned for the arrival rate.

The GIL is not a bottleneck here: the batcher thread spends its time inside
XLA (which releases the GIL), so client threads keep enqueueing while a
batch renders — queueing and compute overlap exactly as in a real server.

A production deployment would add continuous batching (fill freed slots
mid-flight) on top of the same jitted entry point; see DESIGN.md section 7.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Sequence

import jax
import numpy as np

from repro.core.camera import Camera
from repro.core.config import RenderConfig, as_config
from repro.core.gaussians import GaussianParams
from repro.core.multicam import CameraBatch, render_batch_jit, stack_cameras


@dataclasses.dataclass
class RenderResult:
    """One served frame plus its request-level timing."""

    image: np.ndarray  # (H, W, 3)
    latency_ms: float  # enqueue -> result available
    batch_size: int  # real requests in the batch that served this one


@dataclasses.dataclass
class _Request:
    camera: Camera
    future: Future
    t_enqueue: float


class RenderServer:
    """Fixed-slot micro-batching render server over a resident model.

    Args:
      model: the Gaussian cloud to serve (resident for the server lifetime).
      config: render configuration (static -> one executable per server).
      width, height: static image size every request must match (the
        batching contract; reject-on-mismatch keeps shapes static).
      max_batch: batch slot count (the padded render width).
      max_wait_ms: micro-batching window — how long the batcher waits for
        the batch to fill after the first request arrives.
    """

    def __init__(
        self,
        model: GaussianParams,
        config: RenderConfig | None = None,
        *,
        width: int = 128,
        height: int = 128,
        max_batch: int = 8,
        max_wait_ms: float = 5.0,
    ):
        self.model = model
        self.config = as_config(config)
        self.width = int(width)
        self.height = int(height)
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)

        self._queue: queue.Queue[_Request | None] = queue.Queue()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._stopping = False
        self.compile_ms: float | None = None
        # Stats (guarded by _lock): per-request latency, per-batch occupancy.
        self._latencies_ms: list[float] = []
        self._batch_sizes: list[int] = []

    # -- lifecycle ---------------------------------------------------------

    def warmup(self, camera: Camera | None = None) -> float:
        """Compile the fixed-shape batch executable; returns compile ms.

        Serving latencies must not fold compile time into request 0 — call
        this before accepting traffic (``start`` does it for you).
        """
        cam = camera if camera is not None else self._dummy_camera()
        batch = stack_cameras([cam] * self.max_batch)
        t0 = time.perf_counter()
        render_batch_jit(self.model, batch, self.config).block_until_ready()
        self.compile_ms = (time.perf_counter() - t0) * 1e3
        return self.compile_ms

    def start(self) -> "RenderServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if self.compile_ms is None:
            self.warmup()
        with self._lock:
            self._stopping = False
        self._thread = threading.Thread(target=self._batcher_loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        # Flip the stopping flag under the same lock submit() enqueues
        # under: every successful submit's put strictly precedes the poison
        # pill, so the batcher either serves it or its drain rejects it —
        # no future is ever stranded.
        with self._lock:
            self._stopping = True
            self._queue.put(None)  # poison pill
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "RenderServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, camera: Camera) -> Future:
        """Enqueue one camera request; resolves to a :class:`RenderResult`."""
        if (camera.width, camera.height) != (self.width, self.height):
            raise ValueError(
                f"request size {(camera.width, camera.height)} != server's "
                f"static {(self.width, self.height)} (one executable per "
                "server; run a second server for a second size)"
            )
        req = _Request(camera=camera, future=Future(), t_enqueue=time.perf_counter())
        with self._lock:
            if self._thread is None or self._stopping:
                raise RuntimeError("server not started")
            self._queue.put(req)
        return req.future

    def render(self, camera: Camera) -> RenderResult:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(camera).result()

    def stats(self) -> dict:
        """Latency percentiles + batch occupancy over the server lifetime."""
        with self._lock:
            lat = np.asarray(self._latencies_ms, dtype=np.float64)
            sizes = np.asarray(self._batch_sizes, dtype=np.float64)
        if lat.size == 0:
            # Same schema as the served case so pollers never KeyError on
            # an idle server.
            return {
                "requests": 0,
                "batches": 0,
                "compile_ms": self.compile_ms,
                "latency_ms_p50": 0.0,
                "latency_ms_p95": 0.0,
                "latency_ms_mean": 0.0,
                "mean_batch_size": 0.0,
                "occupancy": 0.0,
            }
        return {
            "requests": int(lat.size),
            "batches": int(sizes.size),
            "compile_ms": self.compile_ms,
            "latency_ms_p50": float(np.percentile(lat, 50)),
            "latency_ms_p95": float(np.percentile(lat, 95)),
            "latency_ms_mean": float(lat.mean()),
            "mean_batch_size": float(sizes.mean()),
            "occupancy": float(sizes.mean() / self.max_batch),
        }

    # -- batcher -----------------------------------------------------------

    def _dummy_camera(self) -> Camera:
        from repro.core.camera import look_at_camera

        return look_at_camera(
            (0.0, 1.0, -5.0), (0.0, 0.0, 0.0), width=self.width, height=self.height
        )

    def _collect_window(self, first: _Request) -> list[_Request]:
        """Micro-batching window: up to max_batch requests or max_wait_ms."""
        group = [first]
        deadline = time.perf_counter() + self.max_wait_ms / 1e3
        while len(group) < self.max_batch:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                break
            try:
                nxt = self._queue.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is None:  # poison pill mid-window: put back, serve group
                self._queue.put(None)
                break
            group.append(nxt)
        return group

    def _serve_batch(self, group: Sequence[_Request]) -> None:
        # Pad to the slot count with sentinel cameras (static shapes); the
        # sentinel is a copy of the last real camera, its output discarded.
        pad = self.max_batch - len(group)
        cams = [r.camera for r in group] + [group[-1].camera] * pad
        batch: CameraBatch = stack_cameras(cams)
        imgs = render_batch_jit(self.model, batch, self.config)
        imgs = np.asarray(jax.device_get(imgs))
        t_done = time.perf_counter()
        with self._lock:
            self._batch_sizes.append(len(group))
            for r in group:
                self._latencies_ms.append((t_done - r.t_enqueue) * 1e3)
        for i, r in enumerate(group):
            r.future.set_result(
                RenderResult(
                    image=imgs[i],
                    latency_ms=(t_done - r.t_enqueue) * 1e3,
                    batch_size=len(group),
                )
            )

    def _batcher_loop(self) -> None:
        while True:
            req = self._queue.get()
            if req is None:
                break
            group = self._collect_window(req)
            try:
                self._serve_batch(group)
            except Exception as e:  # fan the failure out, keep serving
                for r in group:
                    if not r.future.done():
                        r.future.set_exception(e)
        # Drain anything that raced in behind the poison pill (submit can
        # pass the started check while stop() is joining) so no future is
        # left unresolved forever.
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("render server stopped before serving request")
                )
