"""tinyllama-1.1b [arXiv:2401.02385; hf]: 22L d_model=2048 32H (GQA kv=4)
d_ff=5632 vocab=32000 — llama2-architecture small model."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b",
        family="dense",
        n_layers=22,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_head=64,
        d_ff=5632,
        vocab_size=32000,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        remat="none",
        compute_dtype="float32",
    )
