"""mamba2-1.3b [arXiv:2405.21060]: 48L d_model=2048 attention-free,
vocab=50280, ssm_state=128 — SSD (state-space duality)."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_head=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_head=0,
        d_ff=0,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=32,
        remat="none",
        compute_dtype="float32",
    )
