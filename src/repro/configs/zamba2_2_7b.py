"""zamba2-2.7b [arXiv:2411.15242; hf]: 54L d_model=2560 32H (kv=32, MHA)
d_ff=10240 vocab=32000, ssm_state=64 — Mamba2 backbone + one shared
attention block applied every 6 layers."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_head=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=128,
        hybrid_attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk=32,
        hybrid_attn_every=2,
        remat="none",
        compute_dtype="float32",
    )
