"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B]: 94L d_model=4096 64H
(GQA kv=4) per-expert d_ff=1536 vocab=151936, MoE 128 experts top-8."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_head=128,
        d_ff=0,
        vocab_size=151936,
        num_experts=128,
        experts_per_token=8,
        moe_d_ff=1536,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=0,
        vocab_size=256,
        num_experts=8,
        experts_per_token=2,
        moe_d_ff=32,
        remat="none",
        compute_dtype="float32",
    )
