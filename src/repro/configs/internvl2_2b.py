"""internvl2-2b [arXiv:2404.16821; hf]: 24L d_model=2048 16H (GQA kv=8)
d_ff=8192 vocab=92553 — InternViT frontend (stubbed to precomputed patch
embeddings) + InternLM2-style decoder."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab_size=92553,
        num_patches=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        num_patches=8,
        remat="none",
        compute_dtype="float32",
    )
