"""Maps architecture ids (with dashes, as assigned) to config modules."""

from __future__ import annotations

import importlib

from repro.models.api import ModelConfig

ARCH_IDS = [
    "qwen2-7b",
    "h2o-danube-1.8b",
    "tinyllama-1.1b",
    "starcoder2-7b",
    "mamba2-1.3b",
    "qwen3-moe-30b-a3b",
    "qwen3-moe-235b-a22b",
    "zamba2-2.7b",
    "whisper-small",
    "internvl2-2b",
]


def _module(arch_id: str):
    mod_name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(arch_id: str, **overrides) -> ModelConfig:
    cfg = _module(arch_id).full_config()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch_id: str, **overrides) -> ModelConfig:
    cfg = _module(arch_id).smoke_config()
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
