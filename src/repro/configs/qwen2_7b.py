"""qwen2-7b [arXiv:2407.10671; hf]: 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064 — GQA with QKV bias, rope theta 1e6."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_head=128,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        rope_theta=1e6,
        remat="none",
        compute_dtype="float32",
    )
