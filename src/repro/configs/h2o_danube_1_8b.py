"""h2o-danube-1.8b [arXiv:2401.16818; hf]: 24L d_model=2560 32H (GQA kv=8)
d_ff=6912 vocab=32000 — llama+mistral mix with sliding-window attention."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_head=80,
        d_ff=6912,
        vocab_size=32000,
        sliding_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        remat="none",
        compute_dtype="float32",
    )
