"""whisper-small [arXiv:2212.04356]: 12L enc + 12L dec, d_model=768 12H
d_ff=3072 vocab=51865 — encoder-decoder; conv audio frontend is a stub
(precomputed frame embeddings of length 1500)."""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        n_layers=12,
        n_encoder_layers=12,
        encoder_seq=1500,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_head=64,
        d_ff=3072,
        vocab_size=51865,
        qkv_bias=True,
        use_rope=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="encdec",
        n_layers=2,
        n_encoder_layers=2,
        encoder_seq=32,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        use_rope=False,
        remat="none",
        compute_dtype="float32",
    )
