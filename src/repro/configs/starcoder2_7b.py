"""starcoder2-7b [arXiv:2402.19173; hf]: 32L d_model=4608 36H (GQA kv=4)
d_ff=18432 vocab=49152 — GQA with RoPE. (The released model uses LayerNorm
with biases; we keep the framework-wide RMSNorm and note the simplification
in DESIGN.md — the compute/communication structure is unchanged.)"""

from repro.models.api import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_head=128,
        d_ff=18432,
        vocab_size=49152,
        qkv_bias=True,
        rope_theta=1e5,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        qkv_bias=True,
        remat="none",
        compute_dtype="float32",
    )
