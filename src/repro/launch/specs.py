"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

Follows the shannon/kernels pattern: weak-type-correct, shardable, zero
device allocation. ``[audio]``/``[vlm]`` frontends are stubs — the specs
provide precomputed frame/patch embeddings per the brief.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.api import ModelConfig, ShapeConfig, family_module


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, t = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, t), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), cfg.cdtype()
        )
    if cfg.family == "vlm":
        from repro.models.vlm import VIT_DIM

        t_text = t - cfg.num_patches
        specs["patches"] = jax.ShapeDtypeStruct((b, cfg.num_patches, VIT_DIM), cfg.cdtype())
        specs["tokens"] = jax.ShapeDtypeStruct((b, t_text), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, t_text), jnp.int32)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels", None)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple[dict, jax.ShapeDtypeStruct]:
    """Returns (abstract decode state, abstract token batch)."""
    mod = family_module(cfg)
    state = jax.eval_shape(
        lambda: mod.init_decode_state(cfg, shape.global_batch, shape.seq_len)
    )
    tokens = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    return state, tokens


def batch_logical(cfg: ModelConfig, specs: dict) -> dict:
    """Logical axis names for each input (for the sharding rules)."""
    out = {}
    for k, v in specs.items():
        if v.ndim == 2:
            out[k] = ("act_batch", "act_seq")
        elif v.ndim == 3:
            out[k] = ("act_batch", "act_seq", "act_embed")
        else:
            out[k] = ("act_batch",) + (None,) * (v.ndim - 1)
    return out
