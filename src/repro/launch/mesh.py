"""Production mesh construction.

A function (not a module constant) so importing never touches jax device
state. Production target: TPU v5e pods of 16x16 = 256 chips; the multi-pod
configuration stacks a leading ``pod`` axis (2 pods = 512 chips for the
dry-run; the axis generalizes to N pods).

Axis semantics:
  pod   — data parallelism across pods (gradient all-reduce over DCN).
  data  — data parallelism / FSDP storage within a pod.
  model — tensor/sequence/expert parallelism within a pod (ICI-local).
"""

from __future__ import annotations

from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests / small-scale runs)."""
    return compat.make_mesh(shape, axes)


def make_host_mesh(max_devices: int | None = None) -> Mesh:
    """Largest (data, model)-style mesh available on the current host —
    used by CPU integration tests; falls back to (1, 1)."""
    import jax

    n = len(jax.devices())
    if max_devices:
        n = min(n, max_devices)
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and n >= cand:
            model = cand
            break
    return make_mesh((n // model, model), ("data", "model"))
