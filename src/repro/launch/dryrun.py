import os

# all-reduce-promotion: XLA-CPU aborts promoting sub-32-bit all-reduces whose
# reducers carry Shardy annotations (shard_map EP MoE path); the pass is
# irrelevant for compile-only analysis and for the bf16-native TPU target.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    " --xla_disable_hlo_passes=all-reduce-promotion"
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract roofline terms from the compiled artifact.

The two lines above MUST precede any other import (jax locks the device
count at first init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # 40-cell sweep
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results land in results/dryrun/<arch>__<shape>__<mesh>.json (memory analysis,
cost analysis, roofline terms) — EXPERIMENTS.md section Dry-run / Roofline are
generated from these files.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.models import params as P
from repro.models.api import SHAPES, ModelConfig, ShapeConfig, family_module, supports_shape
from repro.optim import AdamWConfig
from repro.train.trainer import build_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def _tree_shardings_for(cfg, mesh, mode):
    mod = family_module(cfg)
    defs = mod.param_defs(cfg)
    logical = P.logical_tree(defs)
    abstract = P.abstract_tree(defs, cfg.pdtype())
    return abstract, shd.tree_shardings(logical, abstract, mesh, mode)


def _spec_shardings(specs: dict, logical: dict, mesh, mode):
    ctx = shd.ShardingContext(mesh=mesh, rules=shd.RULE_SETS[mode])
    return {
        k: ctx.sharding_for(v.shape, logical[k]) for k, v in specs.items()
    }


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    *,
    mode: str = "fsdp_sp",
    opt_cfg: AdamWConfig | None = None,
):
    """Lower + compile one (arch, shape) on a mesh. Returns (compiled, meta)."""
    mod = family_module(cfg)
    abstract_params, param_shardings = _tree_shardings_for(cfg, mesh, mode)

    with mesh, shd.axis_rules(mesh, mode):
        if shape.kind == "train":
            opt_cfg = opt_cfg or AdamWConfig()
            step = build_train_step(cfg, opt_cfg)
            opt_abstract = {
                "m": jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                    abstract_params,
                ),
                "v": jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                    abstract_params,
                ),
                "count": jax.ShapeDtypeStruct((), jnp.int32),
            }
            opt_shardings = {
                "m": param_shardings,
                "v": param_shardings,
                "count": None,
            }
            batch = specs_lib.train_input_specs(cfg, shape)
            batch_shardings = _spec_shardings(
                batch, specs_lib.batch_logical(cfg, batch), mesh, mode
            )
            # reprolint: disable=retrace-hazard -- dry-run AOT lowering: one
            # deliberate lower per launch cell, never executed.
            lowered = jax.jit(
                step,
                in_shardings=(param_shardings, opt_shardings, batch_shardings),
                donate_argnums=(0, 1),
            ).lower(abstract_params, opt_abstract, batch)
        elif shape.kind == "prefill":
            batch = specs_lib.prefill_input_specs(cfg, shape)
            batch_shardings = _spec_shardings(
                batch, specs_lib.batch_logical(cfg, batch), mesh, mode
            )

            def pf(params, b):
                return mod.prefill(cfg, params, b, shape.seq_len)

            # reprolint: disable=retrace-hazard -- ditto: per-cell AOT lower.
            lowered = jax.jit(
                pf, in_shardings=(param_shardings, batch_shardings)
            ).lower(abstract_params, batch)
        elif shape.kind == "decode":
            state, tokens = specs_lib.decode_input_specs(cfg, shape)
            state_logical = mod.decode_state_logical()
            ctx = shd.ShardingContext(mesh=mesh, rules=shd.RULE_SETS[mode])
            state_shardings = jax.tree.map(
                lambda logical, leaf: ctx.sharding_for(leaf.shape, logical),
                state_logical,
                state,
                is_leaf=shd.is_logical_leaf,
            )
            tok_sharding = ctx.sharding_for(tokens.shape, ("act_batch",))

            def dec(params, s, t):
                return mod.decode_step(cfg, params, s, t)

            # reprolint: disable=retrace-hazard -- ditto: per-cell AOT lower.
            lowered = jax.jit(
                dec,
                in_shardings=(param_shardings, state_shardings, tok_sharding),
                donate_argnums=(1,),
            ).lower(abstract_params, state, tokens)
        else:
            raise ValueError(shape.kind)

        compiled = lowered.compile()
    return compiled


def analyze_cell(cfg, shape, mesh, compiled) -> dict:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    from benchmarks import roofline as R

    n_dev = mesh.devices.size
    hlo = compiled.as_text()
    mf = R.model_flops_global(cfg, shape)
    report = R.analyze(hlo, num_partitions=n_dev, model_flops_global=mf)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            mem[f] = getattr(ma, f, None)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        cost = {
            "flops_unrolled_once": ca.get("flops"),
            "bytes_accessed_unrolled_once": ca.get("bytes accessed"),
        }
    except Exception as e:  # pragma: no cover
        cost["error"] = str(e)

    collective_ops = {}
    for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
        collective_ops[op] = hlo.count(f" {op}(")

    return {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": dict(mesh.shape),
        "num_devices": int(n_dev),
        "roofline": report.to_dict(),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collective_op_counts": collective_ops,
        "hlo_bytes": len(hlo),
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, mode: str, out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    result_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}__{mode}.json")
    if not ok:
        result = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_tag,
            "status": "skipped",
            "reason": why,
        }
        with open(result_path, "w") as f:
            json.dump(result, f, indent=2)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        compiled = lower_cell(cfg, shape, mesh, mode=mode)
        result = analyze_cell(cfg, shape, mesh, compiled)
        result["status"] = "ok"
        result["skip_reason"] = why
        result["compile_seconds"] = time.time() - t0
        result["sharding_mode"] = mode
        result["mesh_tag"] = mesh_tag
    except Exception as e:
        result = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_tag,
            "status": "failed",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(result_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="fsdp_sp", choices=list(shd.RULE_SETS))
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    out_dir = args.out_dir or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        res = run_cell(
            arch, shape_name, multi_pod=args.multi_pod, mode=args.mode, out_dir=out_dir
        )
        status = res.get("status")
        if status == "ok":
            r = res["roofline"]
            print(
                f"{arch:>22s} {shape_name:<12s} {res['mesh_tag']:<10s} OK "
                f"compute={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s "
                f"coll={r['collective_s']:.2e}s bottleneck={r['bottleneck']} "
                f"compile={res['compile_seconds']:.0f}s",
                flush=True,
            )
        elif status == "skipped":
            print(f"{arch:>22s} {shape_name:<12s} SKIP ({res['reason']})", flush=True)
        else:
            print(f"{arch:>22s} {shape_name:<12s} FAILED: {res['error']}", flush=True)


if __name__ == "__main__":
    main()
