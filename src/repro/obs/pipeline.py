"""Pipeline counters: registry folding + the jnp reference replay.

Two halves:

* **Folding** (`fold_*`): convert the stack's existing diagnostic dicts —
  the fused kernel's per-tile :data:`~repro.kernels.fused_raster.STAT_COLS`
  plane (``render_with_stats``), ``binning.lane_occupancy_stats``,
  ``scene.visibility_stats``, ``SceneTree.memory_stats`` — into *one
  canonical set of metric series* on a :class:`repro.obs.metrics.Registry`.
  Benchmarks and the RenderServer fold into the same names, so a registry
  snapshot in BENCH_PR*.json and a ``/metrics`` scrape of a live server
  report the same series (the perf-regression harness can assert either).

* **Reference replay** (`replay_fused_stats` / `replay_fused_stats_q`):
  recompute the kernel's in-loop counters in plain jnp by blending every
  compacted chunk unconditionally and deriving the exit point afterwards.
  The replay walks the exact forward transmittance trajectory — chunk
  ``j``'s pre-blend transmittance depends only on chunks ``< j``, both
  exit conditions (``j >= nsteps`` and transmittance saturation) are
  monotone once false, and per-chunk mask sums are small integers in f32 —
  so ``chunks_processed`` / ``lanes_blended`` / ``max_sh_band`` match the
  kernel *exactly*, not approximately (pinned by test). This is the same
  replay-exactness argument the fused backward kernel rests on.

Metric name catalog (see DESIGN.md §11):

================================  =========  =================================
name                              kind       meaning
================================  =========  =================================
render_cull_visible_fraction      gauge      visible / total scene chunks
render_cull_visible_chunks        gauge      visible chunk count
render_chunks_assigned            gauge      sum of per-tile compacted chunks
render_chunks_processed           gauge      chunks the kernel actually ran
render_early_exit_savings         gauge      1 - processed / assigned
render_early_exit_chunks          histogram  per-tile measured exit depth
render_chunk_occupancy_measured   gauge      lanes blended / (processed * BG)
render_sh_band_max                gauge      max SH band decoded this render
render_lane_occupancy_compact     gauge      live-lane frac, compacted lists
render_lane_occupancy_block       gauge      live-lane frac, block lists
render_tile_overflow_rate         gauge      tiles that dropped Gaussians
render_chunks_per_tile_mean       gauge      mean compacted chunks per tile
scene_resident_bytes              gauge      resident scene payload bytes
scene_resident_ratio_vs_f32       gauge      resident bytes / f32-equivalent
================================  =========  =================================
"""

from __future__ import annotations

import numpy as np

from repro.obs.metrics import Registry

__all__ = [
    "EXIT_DEPTH_BUCKETS",
    "fold_kernel_stats",
    "fold_occupancy",
    "fold_visibility",
    "fold_memory",
    "fold_render_stats",
    "summarize_kernel_stats",
    "replay_fused_stats",
    "replay_fused_stats_q",
]

# Per-tile chunk-depth buckets (a tile rarely streams >128 chunks).
EXIT_DEPTH_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256)


# ---------------------------------------------------------------------------
# Registry folding
# ---------------------------------------------------------------------------


def summarize_kernel_stats(kernel: dict, *, block_g: int) -> dict:
    """Aggregate the per-tile diagnostics plane to scalar pipeline rates."""
    processed = np.asarray(kernel["chunks_processed"], dtype=np.float64)
    assigned = np.asarray(kernel["chunks_assigned"], dtype=np.float64)
    lanes = float(np.sum(np.asarray(kernel["lanes_blended"], np.float64)))
    n_proc = float(processed.sum())
    n_asgn = float(assigned.sum())
    return {
        "num_tiles": int(processed.size),
        "chunks_assigned": n_asgn,
        "chunks_processed": n_proc,
        "early_exit_savings": 1.0 - n_proc / n_asgn if n_asgn else 0.0,
        "lanes_blended": lanes,
        "chunk_occupancy_measured": (
            lanes / (n_proc * block_g) if n_proc else 0.0
        ),
        "max_sh_band": float(np.max(np.asarray(kernel["max_sh_band"])))
        if np.asarray(kernel["max_sh_band"]).size
        else 0.0,
    }


def fold_kernel_stats(
    registry: Registry, kernel: dict, *, block_g: int, **labels: str
) -> dict:
    """Fold one render's in-kernel diagnostics plane into the registry."""
    agg = summarize_kernel_stats(kernel, block_g=block_g)
    g = registry.gauge
    g("render_chunks_assigned", "compacted chunks assigned per render").set(
        agg["chunks_assigned"], **labels
    )
    g("render_chunks_processed", "chunks executed before early exit").set(
        agg["chunks_processed"], **labels
    )
    g("render_early_exit_savings", "1 - processed/assigned chunks").set(
        agg["early_exit_savings"], **labels
    )
    g(
        "render_chunk_occupancy_measured",
        "lanes blended / (chunks processed * block_g)",
    ).set(agg["chunk_occupancy_measured"], **labels)
    g("render_sh_band_max", "max SH band decoded in-kernel").set(
        agg["max_sh_band"], **labels
    )
    hist = registry.histogram(
        "render_early_exit_chunks",
        "per-tile chunks processed before exit",
        buckets=EXIT_DEPTH_BUCKETS,
    )
    for depth in np.asarray(kernel["chunks_processed"]).ravel():
        hist.observe(float(depth), **labels)
    return agg


def fold_occupancy(registry: Registry, occ: dict, **labels: str) -> None:
    """Fold ``binning.lane_occupancy_stats`` output (the estimate the
    measured in-kernel occupancy is compared against)."""
    mapping = {
        "compact_occupancy": (
            "render_lane_occupancy_compact",
            "live-lane fraction of the compacted per-tile lists",
        ),
        "block_occupancy": (
            "render_lane_occupancy_block",
            "live-lane fraction of the 128-wide block lists",
        ),
        "overflow_rate": (
            "render_tile_overflow_rate",
            "fraction of tiles that dropped Gaussians at capacity",
        ),
        "chunks_per_tile_mean": (
            "render_chunks_per_tile_mean",
            "mean compacted chunks per screen tile",
        ),
        "chunk_full_fraction": (
            "render_chunk_full_fraction",
            "fraction of compacted chunks that are completely live",
        ),
    }
    for key, (name, help_) in mapping.items():
        if key in occ:
            registry.gauge(name, help_).set(float(occ[key]), **labels)


def fold_visibility(registry: Registry, vis: dict, **labels: str) -> None:
    """Fold ``scene.visibility_stats`` output (frustum-cull health)."""
    registry.gauge(
        "render_cull_visible_fraction",
        "visible / total scene chunks after frustum culling",
    ).set(float(vis["visible_fraction"]), **labels)
    registry.gauge(
        "render_cull_visible_chunks", "visible chunk count after culling"
    ).set(float(vis["num_visible"]), **labels)


def fold_memory(registry: Registry, mem: dict, **labels: str) -> None:
    """Fold ``SceneTree.memory_stats`` output (resident footprint)."""
    registry.gauge(
        "scene_resident_bytes", "resident scene payload bytes"
    ).set(float(mem["total_bytes"]), **labels)
    if mem.get("ratio_vs_f32") is not None:
        registry.gauge(
            "scene_resident_ratio_vs_f32",
            "resident bytes / f32-equivalent bytes",
        ).set(float(mem["ratio_vs_f32"]), **labels)


def fold_render_stats(
    registry: Registry, stats: dict | None, **labels: str
) -> dict | None:
    """Fold a ``core.render.render_with_stats`` stats dict — whichever
    sections its raster path produced. Returns the kernel aggregate (if
    any) for callers that also want the scalars."""
    if stats is None:
        return None
    agg = None
    if "kernel" in stats:
        agg = fold_kernel_stats(
            registry, stats["kernel"], block_g=stats["block_g"], **labels
        )
    if "occupancy" in stats:
        fold_occupancy(registry, stats["occupancy"], **labels)
    if "visibility" in stats:
        fold_visibility(registry, stats["visibility"], **labels)
    return agg


# ---------------------------------------------------------------------------
# Reference replay of the in-kernel counters
# ---------------------------------------------------------------------------


def _replay_counters(
    feats,  # (T, steps, FEAT_ROWS, block_g) all-chunk features
    pix,  # (T * TILE_PIX, 2)
    nsteps,  # (T,) float32 per-tile live-chunk counts
    chunk_band,  # (T, steps) float32 per-chunk SH bands
    *,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
) -> dict:
    """Blend every chunk unconditionally; derive the kernel's counters.

    For each tile the scan records chunk ``j``'s *pre-blend* transmittance
    max and live-lane mask sum. A chunk was processed by the kernel iff
    ``j < nsteps`` and (under early exit) its pre-blend max was still
    ``>= EARLY_EXIT_EPS`` — both conditions are monotone once false, and
    the replayed transmittance equals the kernel's bitwise up to the exit
    point (identical ``_blend_chunk`` ops on identical features), so the
    processed prefix is exact.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.constants import EARLY_EXIT_EPS
    from repro.kernels.fused_raster.kernel import TILE_PIX, _blend_chunk

    num_tiles, steps = feats.shape[0], feats.shape[1]
    pix_t = pix.reshape(num_tiles, TILE_PIX, 2)

    def one_tile(feats_tile, pix_tile, n, bands):
        def step(t_pix, feat):
            pre_max = jnp.max(t_pix)
            mask_sum = jnp.sum(feat[11, :])
            t_pix, _ = _blend_chunk(
                pix_tile, feat, t_pix, jnp.zeros((TILE_PIX, 3), jnp.float32)
            )
            return t_pix, (pre_max, mask_sum)

        t0 = jnp.ones((TILE_PIX, 1), jnp.float32)
        _, (pre_max, mask_sums) = jax.lax.scan(step, t0, feats_tile)
        live = jnp.arange(steps, dtype=jnp.float32) < n
        if early_exit:
            live = live & (pre_max >= EARLY_EXIT_EPS)
        livef = live.astype(jnp.float32)
        chunks = jnp.sum(livef)
        lanes = jnp.sum(jnp.where(live, mask_sums, 0.0))
        if banded:
            band_max = jnp.max(
                jnp.where(live, bands, 0.0), initial=0.0
            )
        else:
            band_max = jnp.where(chunks > 0, jnp.float32(sh_degree), 0.0)
        return chunks, lanes, band_max

    chunks, lanes, band_max = jax.vmap(one_tile)(
        feats, pix_t, nsteps, chunk_band
    )
    return {
        "chunks_processed": chunks,
        "lanes_blended": lanes,
        "max_sh_band": band_max,
        "chunks_assigned": nsteps,
    }


def replay_fused_stats(
    raw_compact,
    cam_vec,
    pix,
    nsteps,
    chunk_band,
    *,
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
) -> dict:
    """jnp reference for the f32 fused kernel's diagnostics plane.

    Takes the exact compacted operands ``ops.build_fused_operands`` /
    ``fused_render_stats`` feed the kernel; returns per-tile arrays with
    the same keys as the ``fused_render_stats`` stats dict.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_raster.kernel import RAW_ROWS, lane_features

    total = raw_compact.shape[1]
    num_tiles = total // (steps * block_g)
    raws = raw_compact.reshape(RAW_ROWS, num_tiles * steps, block_g)
    raws = raws.transpose(1, 0, 2)  # (T*steps, RAW_ROWS, block_g)
    bands = chunk_band.reshape(-1).astype(jnp.int32)
    if banded:
        feats = jax.vmap(
            lambda raw, band: lane_features(
                raw, cam_vec, sh_degree=sh_degree, band=band
            )
        )(raws, bands)
    else:
        feats = jax.vmap(
            lambda raw: lane_features(raw, cam_vec, sh_degree=sh_degree)
        )(raws)
    feats = feats.reshape(num_tiles, steps, *feats.shape[1:])
    return _replay_counters(
        feats,
        pix,
        nsteps,
        chunk_band,
        sh_degree=sh_degree,
        banded=banded,
        early_exit=early_exit,
    )


def replay_fused_stats_q(
    qf_c,
    qi_c,
    qdc_c,
    cam_vec,
    pix,
    nsteps,
    chunk_band,
    *,
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
) -> dict:
    """jnp reference for the quantized fused kernel's diagnostics plane."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fused_raster.kernel import (
        QDC_ROWS,
        QF_ROWS,
        QI_ROWS,
        lane_features_q,
    )

    total = qf_c.shape[1]
    num_tiles = total // (steps * block_g)

    def chunked(plane, rows):
        return plane.reshape(rows, num_tiles * steps, block_g).transpose(
            1, 0, 2
        )

    qfs = chunked(qf_c, QF_ROWS)
    qis = chunked(qi_c, QI_ROWS)
    qdcs = chunked(qdc_c, QDC_ROWS)
    bands = chunk_band.reshape(-1).astype(jnp.int32)
    if banded:
        feats = jax.vmap(
            lambda qf, qi, qdc, band: lane_features_q(
                qf, qi, qdc, cam_vec, sh_degree=sh_degree, band=band
            )
        )(qfs, qis, qdcs, bands)
    else:
        feats = jax.vmap(
            lambda qf, qi, qdc: lane_features_q(
                qf, qi, qdc, cam_vec, sh_degree=sh_degree
            )
        )(qfs, qis, qdcs)
    feats = feats.reshape(num_tiles, steps, *feats.shape[1:])
    return _replay_counters(
        feats,
        pix,
        nsteps,
        chunk_band,
        sh_degree=sh_degree,
        banded=banded,
        early_exit=early_exit,
    )
