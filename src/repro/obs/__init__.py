"""Observability: metrics registry, tracing, and pipeline counters.

Three dependency-free layers (DESIGN.md §11):

* :mod:`repro.obs.metrics` — thread-safe Counter/Gauge/Histogram with
  labeled series and bounded ring-buffer percentiles; ``snapshot()`` for
  BENCH_PR*.json, ``render_prometheus()`` for a ``/metrics`` endpoint.
* :mod:`repro.obs.tracing` — Chrome trace-event spans (Perfetto-loadable)
  with explicit ``block_until_ready`` fencing for honest device timing.
* :mod:`repro.obs.pipeline` — folds the stack's diagnostics (the fused
  kernel's in-kernel counters, cull visibility, lane occupancy, resident
  bytes) into one canonical metric-name catalog, plus the jnp reference
  replay the kernel counters are tested against.
* :mod:`repro.obs.slo` — rolling-window SLO monitor + overload state
  machine over the registry; feeds ``/healthz`` and ``/slo`` on the
  ``serve_metrics()`` endpoint (DESIGN.md §13).
"""

from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS_MS,
    Counter,
    Gauge,
    Histogram,
    Registry,
    get_registry,
    serve_metrics,
    validate_prometheus,
)
from repro.obs.pipeline import (
    fold_kernel_stats,
    fold_memory,
    fold_occupancy,
    fold_render_stats,
    fold_visibility,
    replay_fused_stats,
    replay_fused_stats_q,
    summarize_kernel_stats,
)
from repro.obs.slo import (
    SLOMonitor,
    SLOTargets,
)
from repro.obs.tracing import (
    Tracer,
    get_tracer,
    set_tracer,
    span,
    validate_trace,
)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS_MS",
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "get_registry",
    "serve_metrics",
    "validate_prometheus",
    "fold_kernel_stats",
    "fold_memory",
    "fold_occupancy",
    "fold_render_stats",
    "fold_visibility",
    "replay_fused_stats",
    "replay_fused_stats_q",
    "summarize_kernel_stats",
    "SLOMonitor",
    "SLOTargets",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "validate_trace",
]
