"""Lightweight tracing: Chrome trace-event JSON for Perfetto.

A :class:`Tracer` collects complete ("X") events; ``with span("name")``
wraps a region, and ``sp.fence(arrays)`` marks device values that must be
``block_until_ready`` before the span closes — without a fence, a span
around an async XLA dispatch measures only enqueue time, not compute.

The output (``tracer.save(path)`` / ``tracer.to_json()``) is the Chrome
trace-event format: ``{"traceEvents": [...]}`` with microsecond ``ts`` /
``dur`` fields. Open it at https://ui.perfetto.dev (drag the file in) or
``chrome://tracing``. Thread rows carry real thread names via "M"
metadata events; callers can also pin events to logical rows (e.g. one
row per server slot) with an explicit ``tid``.

Dependency-free: ``jax`` is imported lazily and only when a span actually
fences device values.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager

__all__ = ["Tracer", "span", "get_tracer", "set_tracer"]


class _Span:
    """Handle yielded by :func:`span` — mutate args, fence device values."""

    __slots__ = ("name", "args", "_fences")

    def __init__(self, name: str, args: dict) -> None:
        self.name = name
        self.args = args
        self._fences: list = []

    def fence(self, value) -> None:
        """Block on ``value`` (any pytree of jax arrays) before the span
        closes, so the recorded duration covers device compute."""
        self._fences.append(value)

    def set(self, **kwargs) -> None:
        self.args.update(kwargs)


class _NullSpan:
    __slots__ = ()

    def fence(self, value) -> None:
        pass

    def set(self, **kwargs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects Chrome trace events. Thread-safe; bounded by ``max_events``."""

    def __init__(self, max_events: int = 200_000) -> None:
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._epoch = time.perf_counter()
        self._tids: dict[int, int] = {}
        self._dropped = 0
        self.max_events = max_events
        self.pid = 1

    # -- time ------------------------------------------------------------
    def now_us(self) -> float:
        """Microseconds since tracer epoch (the trace's time axis)."""
        return (time.perf_counter() - self._epoch) * 1e6

    def ts_us(self, t_perf: float) -> float:
        """Convert a stored ``time.perf_counter()`` stamp to trace time.

        Lets callers that already keep wall stamps (e.g. a request's
        enqueue time recorded on the client thread) emit events at those
        exact points after the fact.
        """
        return (t_perf - self._epoch) * 1e6

    # -- thread rows -----------------------------------------------------
    def _tid_for_current_thread(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = len(self._tids) + 1
                self._tids[ident] = tid
                self._events.append({
                    "ph": "M", "pid": self.pid, "tid": tid,
                    "name": "thread_name",
                    "args": {"name": threading.current_thread().name},
                })
        return tid

    def lane_tid(self, lane: int, name: str | None = None) -> int:
        """A logical trace row (e.g. a server slot) rather than a real
        thread; rows start at 100 to stay clear of thread rows."""
        tid = 100 + lane
        if name is not None:
            with self._lock:
                key = -(lane + 1)  # sentinel so real idents never collide
                if key not in self._tids:
                    self._tids[key] = tid
                    self._events.append({
                        "ph": "M", "pid": self.pid, "tid": tid,
                        "name": "thread_name", "args": {"name": name},
                    })
        return tid

    # -- events ----------------------------------------------------------
    def emit(
        self,
        name: str,
        ts_us: float,
        dur_us: float,
        tid: int | None = None,
        cat: str = "repro",
        args: dict | None = None,
    ) -> None:
        """Record one complete ("X") event at explicit timestamps."""
        event = {
            "name": name, "cat": cat, "ph": "X",
            "ts": round(ts_us, 3), "dur": round(max(dur_us, 0.0), 3),
            "pid": self.pid,
            "tid": self._tid_for_current_thread() if tid is None else tid,
        }
        if args:
            event["args"] = args
        with self._lock:
            if len(self._events) >= self.max_events:
                self._dropped += 1
                return
            self._events.append(event)

    def instant(self, name: str, tid: int | None = None,
                args: dict | None = None) -> None:
        event = {
            "name": name, "cat": "repro", "ph": "i", "s": "t",
            "ts": round(self.now_us(), 3), "pid": self.pid,
            "tid": self._tid_for_current_thread() if tid is None else tid,
        }
        if args:
            event["args"] = args
        with self._lock:
            if len(self._events) < self.max_events:
                self._events.append(event)

    # -- export ----------------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_json(self) -> dict:
        out = {"traceEvents": self.events(), "displayTimeUnit": "ms"}
        if self._dropped:
            out["droppedEvents"] = self._dropped
        return out

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


_current: Tracer | None = None
_current_lock = threading.Lock()


def get_tracer() -> Tracer | None:
    """The process-default tracer, or None when tracing is off."""
    return _current


def set_tracer(tracer: Tracer | None) -> Tracer | None:
    """Install (or clear, with None) the process-default tracer."""
    global _current
    with _current_lock:
        prev, _current = _current, tracer
    return prev


@contextmanager
def span(name: str, *, tracer: Tracer | None = None, tid: int | None = None,
         **attrs):
    """Trace a region: ``with span("bin_gaussians", tier="raster") as sp``.

    Keyword attrs land in the event's ``args``. When tracing is disabled
    (no tracer installed and none passed) this is a cheap no-op. Call
    ``sp.fence(out)`` on device values produced inside the span to make
    the duration cover device compute, not just async dispatch.
    """
    tr = tracer if tracer is not None else _current
    if tr is None:
        yield _NULL_SPAN
        return
    sp = _Span(name, dict(attrs))
    t0 = tr.now_us()
    try:
        yield sp
    finally:
        if sp._fences:
            import jax

            jax.block_until_ready(sp._fences)
        tr.emit(name, t0, tr.now_us() - t0, tid=tid, args=sp.args or None)


def validate_trace(trace: dict) -> int:
    """Check Chrome trace-event schema; return the number of "X" events.

    Requires a ``traceEvents`` list where every complete event carries
    numeric ``ts``/``dur``, a ``name``, ``pid``/``tid``. Raises
    ``ValueError`` on the first violation — used by tests and the CI
    serving smoke (the same file Perfetto loads).
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    n_complete = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"event {i}: missing ph")
        if ev["ph"] == "X":
            for field in ("name", "ts", "dur", "pid", "tid"):
                if field not in ev:
                    raise ValueError(f"event {i}: X event missing {field!r}")
            if not isinstance(ev["ts"], (int, float)) or not isinstance(
                ev["dur"], (int, float)
            ):
                raise ValueError(f"event {i}: ts/dur must be numeric")
            if ev["dur"] < 0:
                raise ValueError(f"event {i}: negative dur")
            n_complete += 1
    return n_complete


__all__.append("validate_trace")
