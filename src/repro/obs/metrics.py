"""Thread-safe, dependency-free metrics registry.

Three instrument kinds, all with labeled series and bounded memory:

* :class:`Counter` — monotonically increasing float (``inc``).
* :class:`Gauge` — last-write-wins float (``set``).
* :class:`Histogram` — fixed cumulative buckets (Prometheus semantics)
  *plus* a bounded ring buffer of raw observations for exact percentiles
  at serving scale. The ring holds the most recent ``ring_size``
  observations, so a long-lived server's stats cost O(ring_size) memory,
  never O(requests) — this is the fix for the unbounded latency /
  batch-size lists the RenderServer used to keep.

A :class:`Registry` owns the instruments, renders them as a JSON-friendly
``snapshot()`` dict (what benchmarks store in BENCH_PR*.json) and as
Prometheus text exposition (``render_prometheus()``, what the
``--metrics-port`` endpoint serves). A process-global default registry is
available via :func:`get_registry` for scripts that don't want to thread
one through; servers and tests construct their own to stay isolated.

Only the standard library is used — ``numpy`` is imported lazily for
percentiles and is already a repo-wide dependency.
"""

from __future__ import annotations

import errno
import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Iterable, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "get_registry",
    "serve_metrics",
    "validate_prometheus",
    "DEFAULT_LATENCY_BUCKETS_MS",
]

# Latency-style buckets (ms): roughly log-spaced, shared by the server and
# the benchmarks so exported series are comparable across surfaces.
DEFAULT_LATENCY_BUCKETS_MS = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
    500.0, 1000.0, 2000.0, 5000.0, 10000.0,
)

_DEFAULT_RING = 4096


def _label_key(labels: dict[str, str]) -> tuple[tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple[tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in key)
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(x: float) -> str:
    if x == math.inf:
        return "+Inf"
    if x == -math.inf:
        return "-Inf"
    f = float(x)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Metric:
    """Base: a named family of labeled series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict[tuple[tuple[str, str], ...], object] = {}

    def labels(self, **labels: str):
        key = _label_key(labels)
        with self._lock:
            child = self._series.get(key)
            if child is None:
                child = self._new_child()
                self._series[key] = child
        return child

    def _new_child(self):  # pragma: no cover - overridden
        raise NotImplementedError

    def _items(self):
        with self._lock:
            return list(self._series.items())


class _CounterChild:
    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount


class Counter(_Metric):
    kind = "counter"

    def _new_child(self) -> _CounterChild:
        return _CounterChild()

    def inc(self, amount: float = 1.0, **labels: str) -> None:
        self.labels(**labels).inc(amount)

    def value(self, **labels: str) -> float:
        return self.labels(**labels).value


class _GaugeChild:
    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self) -> _GaugeChild:
        return _GaugeChild()

    def set(self, value: float, **labels: str) -> None:
        self.labels(**labels).set(value)

    def value(self, **labels: str) -> float:
        return self.labels(**labels).value


class _HistogramChild:
    __slots__ = ("_lock", "bounds", "bucket_counts", "count", "sum", "_ring", "_ring_pos")

    def __init__(self, bounds: tuple[float, ...], ring_size: int) -> None:
        self._lock = threading.Lock()
        self.bounds = bounds  # finite upper bounds, ascending; +Inf implicit
        self.bucket_counts = [0] * (len(bounds) + 1)
        self.count = 0
        self.sum = 0.0
        self._ring: list[float] = [0.0] * ring_size
        self._ring_pos = 0

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            i = 0
            for b in self.bounds:
                if v <= b:
                    break
                i += 1
            self.bucket_counts[i] += 1
            self.count += 1
            self.sum += v
            ring = self._ring
            if ring:
                ring[self._ring_pos % len(ring)] = v
                self._ring_pos += 1

    def _recent(self) -> list[float]:
        with self._lock:
            n = min(self.count, len(self._ring))
            if n == 0:
                return []
            if self.count <= len(self._ring):
                return self._ring[: self.count]
            return list(self._ring)

    def percentile(self, q: float | Sequence[float]):
        """Exact percentile(s) over the retained (most recent) observations."""
        import numpy as np

        recent = self._recent()
        if not recent:
            return None
        return np.percentile(np.asarray(recent, dtype=np.float64), q)

    def mean(self) -> float | None:
        with self._lock:
            return (self.sum / self.count) if self.count else None

    def summary(self) -> dict:
        """JSON-friendly view: count/sum/mean + p50/p95/p99/max from the ring."""
        with self._lock:
            count, total = self.count, self.sum
        out: dict = {"count": count, "sum": total}
        out["mean"] = (total / count) if count else None
        recent = self._recent()
        if recent:
            import numpy as np

            arr = np.asarray(recent, dtype=np.float64)
            p50, p95, p99 = np.percentile(arr, [50.0, 95.0, 99.0])
            out.update(p50=float(p50), p95=float(p95), p99=float(p99),
                       max=float(arr.max()))
        else:
            out.update(p50=None, p95=None, p99=None, max=None)
        return out


class Histogram(_Metric):
    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str = "",
        buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS_MS,
        ring_size: int = _DEFAULT_RING,
    ) -> None:
        super().__init__(name, help)
        bounds = tuple(sorted(float(b) for b in buckets if math.isfinite(b)))
        if not bounds:
            raise ValueError("histogram needs at least one finite bucket bound")
        self.bounds = bounds
        self.ring_size = int(ring_size)

    def _new_child(self) -> _HistogramChild:
        return _HistogramChild(self.bounds, self.ring_size)

    def observe(self, value: float, **labels: str) -> None:
        self.labels(**labels).observe(value)


class Registry:
    """A named collection of metrics; get-or-create semantics per name."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str, **kwargs) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self,
        name: str,
        help: str = "",
        buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS_MS,
        ring_size: int = _DEFAULT_RING,
    ) -> Histogram:
        return self._get_or_create(
            Histogram, name, help, buckets=buckets, ring_size=ring_size
        )

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self) -> dict:
        """JSON-serializable dump: ``{name: {type, help, series: [...]}}``.

        Histogram series carry a ``summary`` (count/sum/mean/p50/p95/p99/max)
        plus the cumulative bucket counts; counters and gauges carry a plain
        ``value``. This is the form benchmarks persist into BENCH_PR*.json.
        """
        out: dict = {}
        for m in self.metrics():
            series = []
            for key, child in m._items():
                entry: dict = {"labels": dict(key)}
                if isinstance(child, _HistogramChild):
                    entry["summary"] = child.summary()
                    with child._lock:
                        entry["buckets"] = {
                            _fmt(b): int(sum(child.bucket_counts[: i + 1]))
                            for i, b in enumerate(child.bounds)
                        }
                        entry["buckets"]["+Inf"] = int(child.count)
                else:
                    entry["value"] = child.value
                series.append(entry)
            out[m.name] = {"type": m.kind, "help": m.help, "series": series}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for key, child in m._items():
                ls = _label_str(key)
                if isinstance(child, _HistogramChild):
                    with child._lock:
                        counts = list(child.bucket_counts)
                        count, total = child.count, child.sum
                    cum = 0
                    for b, c in zip(child.bounds, counts):
                        cum += c
                        bl = _label_str(key + (("le", _fmt(b)),))
                        lines.append(f"{m.name}_bucket{bl} {cum}")
                    bl = _label_str(key + (("le", "+Inf"),))
                    lines.append(f"{m.name}_bucket{bl} {count}")
                    lines.append(f"{m.name}_sum{ls} {_fmt(total)}")
                    lines.append(f"{m.name}_count{ls} {count}")
                else:
                    lines.append(f"{m.name}{ls} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"


_global_registry = Registry()


def get_registry() -> Registry:
    """The process-global default registry."""
    return _global_registry


_NAME_RE = r"[a-zA-Z_:][a-zA-Z0-9_:]*"


def validate_prometheus(text: str) -> dict[str, dict]:
    """Validate Prometheus text exposition; return ``{family: info}``.

    Checks line grammar, TYPE declarations, histogram bucket monotonicity,
    the mandatory ``+Inf`` bucket, and ``_count`` == ``+Inf`` agreement.
    Raises ``ValueError`` on the first violation. Used by tests and the CI
    serving smoke — intentionally strict but dependency-free.
    """
    import re

    families: dict[str, dict] = {}
    sample_re = re.compile(
        rf"^({_NAME_RE})(\{{[^{{}}]*\}})? (-?[0-9.eE+]+|[+-]Inf|NaN)$"
    )
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) != 4 or parts[3] not in (
                "counter", "gauge", "histogram", "summary", "untyped"
            ):
                raise ValueError(f"line {lineno}: bad TYPE line: {line!r}")
            families[parts[2]] = {"type": parts[3], "samples": []}
            continue
        if line.startswith("#"):
            continue
        mt = sample_re.match(line)
        if not mt:
            raise ValueError(f"line {lineno}: unparseable sample: {line!r}")
        name, labels, value = mt.group(1), mt.group(2) or "", mt.group(3)
        fam = name
        for suffix in ("_bucket", "_sum", "_count"):
            base = name[: -len(suffix)] if name.endswith(suffix) else None
            if base in families and families[base]["type"] == "histogram":
                fam = base
                break
        if fam not in families:
            raise ValueError(f"line {lineno}: sample {name!r} without TYPE")
        families[fam]["samples"].append((name, labels, value))
    # Histogram structural checks.
    for fam, info in families.items():
        if info["type"] != "histogram":
            continue
        def _series_key(labels: str) -> str:
            inner = labels.strip("{}")
            parts = [p for p in inner.split(",") if p and not p.startswith('le="')]
            return ",".join(sorted(parts))

        by_series: dict[str, list[tuple[float, float]]] = {}
        counts: dict[str, float] = {}
        for name, labels, value in info["samples"]:
            if name == fam + "_bucket":
                mle = re.search(r'le="([^"]+)"', labels)
                if not mle:
                    raise ValueError(f"{fam}: bucket sample missing le label")
                le = math.inf if mle.group(1) == "+Inf" else float(mle.group(1))
                by_series.setdefault(_series_key(labels), []).append(
                    (le, float(value))
                )
            elif name == fam + "_count":
                counts[_series_key(labels)] = float(value)
        for series, buckets in by_series.items():
            buckets.sort(key=lambda p: p[0])
            if buckets[-1][0] != math.inf:
                raise ValueError(f"{fam}{{{series}}}: missing +Inf bucket")
            vals = [v for _, v in buckets]
            if any(b > a for b, a in zip(vals, vals[1:])):
                raise ValueError(f"{fam}{{{series}}}: non-monotonic buckets")
            if series in counts and counts[series] != buckets[-1][1]:
                raise ValueError(f"{fam}{{{series}}}: _count != +Inf bucket")
    return families


def serve_metrics(registry: Registry, port: int = 0, *, slo=None):
    """Serve ``registry.render_prometheus()`` at ``/metrics`` on ``port``.

    With ``slo=`` (an :class:`repro.obs.slo.SLOMonitor`), two JSON
    endpoints join ``/metrics``:

    * ``/healthz`` — liveness for load balancers: 200 while the monitor is
      ``ok`` or ``degraded``, 503 once ``overloaded``.
    * ``/slo`` — the full ``snapshot()`` (state, rolling window, targets,
      transition history), always 200.

    Returns the started ``ThreadingHTTPServer`` (daemon thread); the bound
    port — resolved even when ``port=0`` asked the OS to pick — is on the
    handle as ``server.port`` (and ``server.server_address[1]``). Call
    ``server.shutdown()`` to stop. A ``port`` that is already in use
    raises ``OSError`` naming the port instead of the bare bind errno.
    """

    class Handler(BaseHTTPRequestHandler):
        def _send(self, code: int, body: bytes, ctype: str) -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self) -> None:  # noqa: N802 (http.server API)
            path = self.path.rstrip("/")
            if path in ("", "/metrics"):
                body = registry.render_prometheus().encode()
                self._send(200, body, "text/plain; version=0.0.4")
            elif path == "/healthz" and slo is not None:
                healthy, doc = slo.healthz()
                self._send(
                    200 if healthy else 503,
                    json.dumps(doc).encode(),
                    "application/json",
                )
            elif path == "/slo" and slo is not None:
                self._send(
                    200, json.dumps(slo.snapshot()).encode(), "application/json"
                )
            else:
                self.send_error(404)

        def log_message(self, *args) -> None:  # silence per-request stderr
            pass

    try:
        server = ThreadingHTTPServer(("127.0.0.1", port), Handler)
    except OSError as e:
        if e.errno == errno.EADDRINUSE:
            raise OSError(
                errno.EADDRINUSE,
                f"metrics port {port} already in use on 127.0.0.1 — pass "
                "port=0 to let the OS pick a free one",
            ) from e
        raise
    server.port = server.server_address[1]
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    return server
