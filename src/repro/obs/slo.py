"""Live SLO monitoring: rolling-window serving health + overload states.

The runtime half of the perf contract whose static half is
``tools/perfguard`` (DESIGN.md §13): perfguard gates *commits* on the
BENCH trajectory; this module watches a *running* :class:`RenderServer`
against declared targets and exposes the admission-control signal the
fleet-scale roadmap item will consume.

Three pieces:

* :class:`SLOTargets` — the declared objectives: windowed p95/p99 latency
  ceilings, a req/s floor, queue-depth and reject-rate ceilings, plus the
  state-machine knobs (window span, trip/clear hold times, overload
  factor).
* :class:`SLOMonitor` — a thread-safe rolling window over request events
  (``observe_latency`` / ``note_admit`` / ``note_done`` / ``note_reject``)
  with exact percentiles (numpy's linear interpolation, computed stdlib-
  side and pinned equal by test), windowed req/s, instantaneous queue
  depth (admitted minus resolved), and windowed reject rate.
* the **overload state machine** — ``ok -> degraded -> overloaded`` with
  time-based hysteresis. Every evaluation classifies current *pressure*:

  - level 2 (overloaded): any hard breach — queue depth or reject rate
    over target, or a latency percentile beyond ``overload_factor`` times
    its ceiling, or req/s under ``min_req_s / overload_factor`` while
    demand exists;
  - level 1 (degraded): any soft breach — a latency percentile over its
    ceiling, or req/s under ``min_req_s`` while demand exists;
  - level 0 (ok): no breach.

  The state only moves after the new level has held continuously for
  ``trip_s`` (escalation) or ``clear_s`` (recovery) — so a single slow
  request can't flap the health signal, and a step load can legitimately
  jump ``ok -> overloaded`` directly once ``trip_s`` elapses. The
  ``min_req_s`` floor is only judged while the admission window is
  non-empty *and* demand has been visible for at least one expected
  service interval: an idle server is healthy, and a just-admitted first
  request is not yet starvation.

The monitor is clock-injectable (``clock=``) so the hysteresis schedule
is testable with scripted time, and registry-backed (``registry=``) so
``slo_state`` / ``slo_window_*`` gauges ride the same ``/metrics``
exposition as everything else. ``serve_metrics(..., slo=monitor)`` adds
``/healthz`` (200 until overloaded, then 503) and ``/slo`` (full JSON
snapshot) next to ``/metrics``.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Callable

from repro.obs.metrics import Registry

__all__ = ["SLOTargets", "SLOMonitor", "STATES"]

STATES = ("ok", "degraded", "overloaded")
_MAX_TRANSITIONS = 64  # bounded history, like every other obs buffer


@dataclasses.dataclass(frozen=True)
class SLOTargets:
    """Declared service-level objectives + state-machine knobs.

    Every objective is optional (None = not monitored); the state machine
    runs over whichever are set. ``window_s`` bounds both the memory and
    the reaction horizon: percentiles/rates are computed over events in
    the last ``window_s`` seconds only.
    """

    p95_ms: float | None = None
    p99_ms: float | None = None
    min_req_s: float | None = None
    max_queue_depth: float | None = None
    max_reject_rate: float | None = None
    overload_factor: float = 2.0  # hard-breach multiplier on latency/req_s
    window_s: float = 30.0
    trip_s: float = 0.0  # how long pressure must hold before escalating
    clear_s: float = 5.0  # how long calm must hold before recovering

    def __post_init__(self):
        if self.overload_factor < 1.0:
            raise ValueError("overload_factor must be >= 1")
        if self.window_s <= 0:
            raise ValueError("window_s must be positive")


def _percentile(sorted_vals: list[float], q: float) -> float:
    """numpy's default linear-interpolation percentile over a sorted list.

    Kept stdlib-side so the serving hot path never imports numpy; equality
    with ``np.percentile`` over the same window is pinned by test.
    """
    n = len(sorted_vals)
    if n == 1:
        return sorted_vals[0]
    k = (n - 1) * (q / 100.0)
    f = math.floor(k)
    c = math.ceil(k)
    if f == c:
        return sorted_vals[int(k)]
    return sorted_vals[f] * (c - k) + sorted_vals[c] * (k - f)


class SLOMonitor:
    """Thread-safe rolling-window SLO evaluation + overload state machine.

    All mutators evaluate the state machine inline (the window is small —
    O(events in window) — and serving rates here are tens of req/s), so
    the health signal is current the moment ``snapshot()`` or a gauge is
    read; ``snapshot()`` itself also evaluates, so pollers see recovery
    even when traffic has stopped.
    """

    def __init__(
        self,
        targets: SLOTargets,
        *,
        registry: Registry | None = None,
        clock: Callable[[], float] = time.monotonic,
        **labels: str,
    ) -> None:
        self.targets = targets
        self._clock = clock
        self._labels = {str(k): str(v) for k, v in labels.items()}
        self._lock = threading.Lock()
        self._t0 = clock()
        self._lat: collections.deque[tuple[float, float]] = collections.deque()
        self._done: collections.deque[float] = collections.deque()
        self._admit: collections.deque[float] = collections.deque()
        self._reject: collections.deque[float] = collections.deque()
        self._depth = 0
        self._state = 0
        self._state_since = self._t0
        self._pending_level: int | None = None
        self._pending_since = self._t0
        self._transitions: collections.deque[dict] = collections.deque(
            maxlen=_MAX_TRANSITIONS
        )
        self._gauges = None
        if registry is not None:
            g = registry.gauge
            self._gauges = {
                "state": g("slo_state", "0=ok 1=degraded 2=overloaded"),
                "p95": g("slo_window_p95_ms", "windowed request latency p95"),
                "p99": g("slo_window_p99_ms", "windowed request latency p99"),
                "req_s": g("slo_window_req_s", "completed requests per second"),
                "depth": g("slo_queue_depth", "admitted minus resolved requests"),
                "reject": g("slo_reject_rate", "windowed rejected / offered"),
                "transitions": registry.counter(
                    "slo_state_transitions_total",
                    "overload state-machine transitions",
                ),
            }
            self._gauges["state"].set(0.0, **self._labels)

    # -- event intake ------------------------------------------------------

    def observe_latency(self, ms: float) -> None:
        """One served request's latency (enqueue -> result, ms)."""
        with self._lock:
            self._lat.append((self._clock(), float(ms)))
            self._evaluate_locked()

    def note_admit(self, n: int = 1) -> None:
        """``n`` requests admitted (queue depth rises)."""
        with self._lock:
            t = self._clock()
            self._admit.extend([t] * n)
            self._depth += n
            self._evaluate_locked()

    def note_done(self, n: int = 1) -> None:
        """``n`` admitted requests resolved — served, failed, or cancelled
        (queue depth falls; only served requests also observe a latency)."""
        with self._lock:
            t = self._clock()
            self._done.extend([t] * n)
            self._depth = max(0, self._depth - n)
            self._evaluate_locked()

    def note_reject(self, n: int = 1) -> None:
        """``n`` requests rejected at admission (never occupied the queue)."""
        with self._lock:
            t = self._clock()
            self._reject.extend([t] * n)
            self._evaluate_locked()

    # -- window math -------------------------------------------------------

    def _prune_locked(self, now: float) -> None:
        horizon = now - self.targets.window_s
        while self._lat and self._lat[0][0] < horizon:
            self._lat.popleft()
        for dq in (self._done, self._admit, self._reject):
            while dq and dq[0] < horizon:
                dq.popleft()

    def _window_locked(self, now: float) -> dict:
        self._prune_locked(now)
        vals = sorted(v for _, v in self._lat)
        # req/s over the elapsed-capped window: a monitor younger than
        # window_s divides by its true age, not the full span.
        span = max(min(self.targets.window_s, now - self._t0), 1e-9)
        offered = len(self._admit) + len(self._reject)
        return {
            "n_latency": len(vals),
            "p50_ms": _percentile(vals, 50.0) if vals else None,
            "p95_ms": _percentile(vals, 95.0) if vals else None,
            "p99_ms": _percentile(vals, 99.0) if vals else None,
            "req_s": len(self._done) / span,
            "queue_depth": self._depth,
            "admitted": len(self._admit),
            "oldest_admit_age_s": (now - self._admit[0]) if self._admit else None,
            "reject_rate": (len(self._reject) / offered) if offered else 0.0,
            "span_s": span,
        }

    def window(self) -> dict:
        """Current rolling-window statistics (prunes, does not evaluate)."""
        with self._lock:
            return self._window_locked(self._clock())

    # -- state machine -----------------------------------------------------

    def _level(self, w: dict) -> int:
        t = self.targets
        f = t.overload_factor
        hard = soft = False
        for ceil_ms, got in ((t.p95_ms, w["p95_ms"]), (t.p99_ms, w["p99_ms"])):
            if ceil_ms is not None and got is not None:
                hard = hard or got > ceil_ms * f
                soft = soft or got > ceil_ms
        if t.min_req_s is not None and w["admitted"] > 0:
            # Cold-start guard: a just-admitted request makes req_s read 0
            # until something completes, which is not starvation. Judge the
            # throughput floor only once demand has been visible for a full
            # expected service interval (1/min_req_s, capped at the window)
            # — after that, zero completions IS a stall.
            age = w["oldest_admit_age_s"]
            grace = min(1.0 / t.min_req_s, t.window_s)
            if age is not None and age >= grace:
                hard = hard or w["req_s"] < t.min_req_s / f
                soft = soft or w["req_s"] < t.min_req_s
        if t.max_queue_depth is not None:
            hard = hard or w["queue_depth"] > t.max_queue_depth
        if t.max_reject_rate is not None:
            hard = hard or w["reject_rate"] > t.max_reject_rate
        return 2 if hard else (1 if soft else 0)

    def _evaluate_locked(self) -> int:
        now = self._clock()
        w = self._window_locked(now)
        level = self._level(w)
        if level == self._state:
            self._pending_level = None
        else:
            if self._pending_level != level:
                self._pending_level, self._pending_since = level, now
            hold = (
                self.targets.trip_s
                if level > self._state
                else self.targets.clear_s
            )
            if now - self._pending_since >= hold:
                self._transitions.append(
                    {
                        "t_s": now - self._t0,
                        "from": STATES[self._state],
                        "to": STATES[level],
                    }
                )
                self._state = level
                self._state_since = now
                self._pending_level = None
                if self._gauges is not None:
                    self._gauges["transitions"].inc(
                        to=STATES[level], **self._labels
                    )
        if self._gauges is not None:
            gs = self._gauges
            gs["state"].set(float(self._state), **self._labels)
            gs["req_s"].set(w["req_s"], **self._labels)
            gs["depth"].set(float(w["queue_depth"]), **self._labels)
            gs["reject"].set(w["reject_rate"], **self._labels)
            if w["p95_ms"] is not None:
                gs["p95"].set(w["p95_ms"], **self._labels)
            if w["p99_ms"] is not None:
                gs["p99"].set(w["p99_ms"], **self._labels)
        return self._state

    def evaluate(self) -> str:
        """Re-evaluate now (pollers get recovery without new traffic)."""
        with self._lock:
            return STATES[self._evaluate_locked()]

    @property
    def state(self) -> str:
        return STATES[self._state]

    def transitions(self) -> list[dict]:
        with self._lock:
            return list(self._transitions)

    # -- export surfaces ---------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-friendly full picture: state + window + targets + history.

        This is what ``/slo`` serves and what ``RenderServer.stats()``
        embeds under ``"slo"``.
        """
        with self._lock:
            self._evaluate_locked()
            now = self._clock()
            return {
                "state": STATES[self._state],
                "state_id": self._state,
                "state_age_s": now - self._state_since,
                "window": self._window_locked(now),
                "targets": {
                    k: v
                    for k, v in dataclasses.asdict(self.targets).items()
                    if v is not None
                },
                "transitions": list(self._transitions),
            }

    def healthz(self) -> tuple[bool, dict]:
        """Liveness summary for ``/healthz``: healthy unless overloaded.

        ``degraded`` still reports healthy=True — the server is serving,
        just out of SLO; load balancers should stop sending traffic only
        on overload. The body carries the state either way.
        """
        with self._lock:
            self._evaluate_locked()
            w = self._window_locked(self._clock())
            return self._state < 2, {
                "status": STATES[self._state],
                "ok": self._state < 2,
                "queue_depth": w["queue_depth"],
                "window_p95_ms": w["p95_ms"],
                "window_req_s": w["req_s"],
            }
