"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The backbone is organized as super-blocks: ``hybrid_attn_every`` Mamba-2
layers followed by one application of a single weight-shared attention+MLP
block (arXiv:2411.15242). We scan over super-blocks (outer) and the Mamba
layers inside each (inner), so the shared block's KV caches are allocated
once per *application* rather than per layer.

Simplifications vs the released Zamba2 (noted in DESIGN.md): the shared
block consumes the pre-normed hidden state directly (no concat-with-original-
embedding projector, no per-application LoRA deltas).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import dense
from repro.models import layers as L
from repro.models import mamba2
from repro.models.api import ModelConfig
from repro.models.params import ParamDef


def _super(cfg: ModelConfig) -> tuple[int, int]:
    every = cfg.hybrid_attn_every
    assert cfg.n_layers % every == 0, (cfg.n_layers, every)
    return cfg.n_layers // every, every


def param_defs(cfg: ModelConfig) -> dict:
    n_super, every = _super(cfg)
    d = cfg.d_model
    # Mamba defs stacked (n_super, every, ...): prepend the super dim.
    inner = mamba2.block_param_defs(cfg, stacked=every)

    def restack(pd: ParamDef) -> ParamDef:
        return ParamDef(
            (n_super,) + pd.shape,
            ("stack",) + pd.logical,
            init=pd.init,
            scale=pd.scale,
        )

    mamba_defs = jax.tree.map(restack, inner, is_leaf=lambda x: isinstance(x, ParamDef))
    return {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "mamba": mamba_defs,
        "shared": {
            "ln1": ParamDef((d,), (None,), init="ones"),
            "attn": L.attn_param_defs(cfg),
            "ln2": ParamDef((d,), (None,), init="ones"),
            "mlp": L.mlp_param_defs(cfg),
        },
        "ln_f": ParamDef((d,), (None,), init="ones"),
        "lm_head": ParamDef((d, cfg.vocab_size), ("embed", "vocab")),
    }


def _shared_block(cfg: ModelConfig, sp: dict, h: jax.Array, positions) -> jax.Array:
    hn = L.rmsnorm(h, sp["ln1"], cfg.norm_eps)
    h = h + L.attn_block(cfg, sp["attn"], hn, positions)
    hn = L.rmsnorm(h, sp["ln2"], cfg.norm_eps)
    h = h + L.mlp_block(cfg, sp["mlp"], hn)
    return h


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    positions = jnp.arange(tokens.shape[1])
    shared = params["shared"]

    def super_body(carry, slp):
        h = carry

        def inner(h2, lp):
            hn = L.rmsnorm(h2, lp["ln"], cfg.norm_eps)
            return h2 + mamba2.mamba_block(cfg, lp, hn), None

        h, _ = jax.lax.scan(inner, h, slp)
        h = _shared_block(cfg, shared, h, positions)
        return constrain(h, ("act_batch", "act_seq", "act_embed")), None

    super_body = L.remat_wrap(cfg, super_body)
    h, _ = jax.lax.scan(super_body, h, params["mamba"])
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    return L.lm_logits(h, params["lm_head"], transpose=False)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    n_super, every = _super(cfg)
    d_inner, n_heads, n_state, conv_ch, _ = mamba2._dims(cfg)
    return {
        "conv": jnp.zeros(
            (n_super, every, batch, cfg.ssm_conv_width - 1, conv_ch), cfg.cdtype()
        ),
        "ssm": jnp.zeros(
            (n_super, every, batch, n_heads, n_state, cfg.ssm_head_dim), jnp.float32
        ),
        "k": jnp.zeros(
            (n_super, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cfg.cdtype()
        ),
        "v": jnp.zeros(
            (n_super, batch, max_seq, cfg.n_kv_heads, cfg.d_head), cfg.cdtype()
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_state_logical() -> dict:
    return {
        "conv": ("stack", "layers", "act_batch", None, "wout"),
        "ssm": ("stack", "layers", "act_batch", "act_heads", None, None),
        "k": ("stack", "act_batch", "act_kv_seq", None, None),
        "v": ("stack", "act_batch", "act_kv_seq", None, None),
        "pos": (),
    }


def decode_step(cfg: ModelConfig, params: dict, state: dict, tokens: jax.Array):
    pos = state["pos"]
    h = L.embed_tokens(params["embed"], tokens[:, None], cfg.cdtype())
    shared = params["shared"]

    def super_body(carry, xs):
        h = carry
        slp, conv_s, ssm_s, kc, vc = xs

        def inner(h2, xs2):
            lp, conv, ssm = xs2
            hn = L.rmsnorm(h2, lp["ln"], cfg.norm_eps)
            out, conv, ssm = mamba2.block_decode(cfg, lp, hn, conv, ssm)
            return h2 + out, (conv, ssm)

        h, (conv_s, ssm_s) = jax.lax.scan(inner, h, (slp, conv_s, ssm_s))

        # Shared attention application with its per-application KV cache.
        hn = L.rmsnorm(h, shared["ln1"], cfg.norm_eps)
        q, kk, vv = dense._attn_qkv_1tok(cfg, {"attn": shared["attn"]}, hn, pos)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kk, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vv, pos, axis=1)
        kc = constrain(kc, ("act_batch", "act_kv_seq", None, None))
        vc = constrain(vc, ("act_batch", "act_kv_seq", None, None))
        out = L.decode_attention(q, kc, vc, pos)
        out = out.reshape(h.shape[0], 1, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, shared["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, shared["ln2"], cfg.norm_eps)
        h = h + L.mlp_block(cfg, shared["mlp"], hn)
        return h, (conv_s, ssm_s, kc, vc)

    h, (new_conv, new_ssm, new_k, new_v) = jax.lax.scan(
        super_body,
        h,
        (params["mamba"], state["conv"], state["ssm"], state["k"], state["v"]),
    )
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = L.lm_logits(h, params["lm_head"], transpose=False)[:, 0]
    return {
        "conv": new_conv,
        "ssm": new_ssm,
        "k": new_k,
        "v": new_v,
        "pos": pos + 1,
    }, logits


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Prompt pass building both SSM states and shared-attention KV caches."""
    tokens = batch["tokens"]
    bsz, t = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    positions = jnp.arange(t)
    shared = params["shared"]
    d_inner, n_heads, n_state, conv_ch, _ = mamba2._dims(cfg)

    def super_body(carry, slp):
        h = carry

        def inner(h2, lp):
            hn = L.rmsnorm(h2, lp["ln"], cfg.norm_eps)
            dt_ = hn.dtype
            zxbcdt = jnp.einsum("btd,dk->btk", hn, lp["in_proj"].astype(dt_))
            xbc = zxbcdt[..., d_inner : d_inner + conv_ch]
            conv_state = xbc[:, -(cfg.ssm_conv_width - 1) :]
            xbc_act = jax.nn.silu(
                mamba2.causal_conv1d(
                    xbc, lp["conv_w"].astype(dt_), lp["conv_b"].astype(dt_)
                )
            )
            x_in = xbc_act[..., :d_inner].reshape(
                bsz, t, n_heads, cfg.ssm_head_dim
            )
            b_in = xbc_act[..., d_inner : d_inner + n_state]
            c_in = xbc_act[..., d_inner + n_state :]
            dtv = jax.nn.softplus(
                zxbcdt[..., d_inner + conv_ch :].astype(jnp.float32)
                + lp["dt_bias"].astype(jnp.float32)
            )
            a = -jnp.exp(lp["a_log"].astype(jnp.float32))
            _, fin = mamba2.ssd_chunked(x_in, dtv, b_in, c_in, a, cfg.ssm_chunk)
            out = mamba2.mamba_block(cfg, lp, hn)
            return h2 + out, (conv_state, fin)

        h, (convs, ssms) = jax.lax.scan(inner, h, slp)

        hn = L.rmsnorm(h, shared["ln1"], cfg.norm_eps)
        q, kk, vv = L.attn_qkv(cfg, shared["attn"], hn, positions)
        if t <= cfg.attn_chunk:
            out = L.dense_attention(q, kk, vv, causal=True)
        else:
            out = L.chunked_attention(q, kk, vv, causal=True, chunk=cfg.attn_chunk)
        out = out.reshape(bsz, t, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, shared["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, shared["ln2"], cfg.norm_eps)
        h = h + L.mlp_block(cfg, shared["mlp"], hn)
        return h, (convs, ssms, kk, vv)

    super_body = L.remat_wrap(cfg, super_body)
    h, (convs, ssms, ks, vs) = jax.lax.scan(super_body, h, params["mamba"])
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = L.lm_logits(h[:, -1:], params["lm_head"], transpose=False)[:, 0]

    state = init_decode_state(cfg, bsz, max_seq)
    state["conv"] = convs.astype(cfg.cdtype())
    state["ssm"] = ssms
    state["k"] = jax.lax.dynamic_update_slice_in_dim(
        state["k"], ks.astype(cfg.cdtype()), 0, axis=2
    )
    state["v"] = jax.lax.dynamic_update_slice_in_dim(
        state["v"], vs.astype(cfg.cdtype()), 0, axis=2
    )
    state["pos"] = jnp.asarray(t, jnp.int32)
    return state, logits
