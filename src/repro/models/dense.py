"""Dense decoder-only transformer (llama-family): qwen2 / danube / tinyllama /
starcoder2. Scan-over-layers with stacked parameters (compile time is
layer-count independent), remat policy per config.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.api import ModelConfig
from repro.models.params import ParamDef


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_defs(cfg: ModelConfig) -> dict:
    n = cfg.n_layers
    d = cfg.d_model
    defs = {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "layers": {
            "ln1": ParamDef((n, d), ("layers", None), init="ones"),
            "attn": L.attn_param_defs(cfg, stacked=n),
            "ln2": ParamDef((n, d), ("layers", None), init="ones"),
            "mlp": L.mlp_param_defs(cfg, stacked=n),
        },
        "ln_f": ParamDef((d,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab_size), ("embed", "vocab"))
    return defs


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def _layer_fwd(cfg: ModelConfig, h: jax.Array, lp: dict, positions: jax.Array):
    hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
    h = h + L.attn_block(cfg, lp["attn"], hn, positions)
    hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    h = h + L.mlp_block(cfg, lp["mlp"], hn)
    return constrain(h, ("act_batch", "act_seq", "act_embed"))


def backbone(cfg: ModelConfig, params: dict, h: jax.Array, positions: jax.Array):
    """Run the layer stack on embedded inputs h (B, T, D)."""

    def body(carry, lp):
        return _layer_fwd(cfg, carry, lp, positions), None

    body = L.remat_wrap(cfg, body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return L.rmsnorm(h, params["ln_f"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]  # (B, T)
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    positions = jnp.arange(tokens.shape[1])
    h = backbone(cfg, params, h, positions)
    head = params.get("lm_head", params["embed"])
    return L.lm_logits(h, head, transpose="lm_head" not in params)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch)
    return L.softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill + cached decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Zeroed KV caches. Cache seq axis is sharded on the ``model`` mesh axis
    (split-KV decode). SWA models only retain the window."""
    s = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, cfg.cdtype()),
        "v": jnp.zeros(shape, cfg.cdtype()),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_state_logical() -> dict:
    return {
        "k": ("layers", "act_batch", "act_kv_seq", None, None),
        "v": ("layers", "act_batch", "act_kv_seq", None, None),
        "pos": (),
    }


def _attn_qkv_1tok(cfg: ModelConfig, lp: dict, x: jax.Array, pos: jax.Array):
    """Projections + RoPE for one token. x: (B, 1, D)."""
    b = x.shape[0]
    dt = x.dtype
    p = lp["attn"]
    q = jnp.einsum("btd,dk->btk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dk->btk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dk->btk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, 1, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        posb = pos[None, None].astype(jnp.int32) * jnp.ones((b, 1), jnp.int32)
        q = L.apply_rope(q, posb, cfg.rope_theta)
        k = L.apply_rope(k, posb, cfg.rope_theta)
    return q, k, v


def decode_step(
    cfg: ModelConfig, params: dict, state: dict, tokens: jax.Array
) -> tuple[dict, jax.Array]:
    """One autoregressive step. tokens: (B,) int32. Returns (state, logits)."""
    pos = state["pos"]
    cache_len = state["k"].shape[2]
    # SWA caches are ring buffers over the window.
    slot = pos % cache_len if cfg.sliding_window else pos
    h = L.embed_tokens(params["embed"], tokens[:, None], cfg.cdtype())  # (B,1,D)

    def body(carry, xs):
        h = carry
        lp, kc, vc = xs
        hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = _attn_qkv_1tok(cfg, lp, hn, pos)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, slot, axis=1)
        kc = constrain(kc, ("act_batch", "act_kv_seq", None, None))
        vc = constrain(vc, ("act_batch", "act_kv_seq", None, None))
        if cfg.sliding_window:
            # Ring buffer: all populated slots are within the window by
            # construction; mask only un-populated slots (pos < cache_len).
            attn_pos = jnp.minimum(pos, cache_len - 1)
            out = L.decode_attention(q, kc, vc, attn_pos, window=None)
        else:
            out = L.decode_attention(q, kc, vc, pos, window=None)
        out = out.reshape(h.shape[0], 1, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, lp["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.mlp_block(cfg, lp["mlp"], hn)
        return h, (kc, vc)

    h, (new_k, new_v) = jax.lax.scan(
        body, h, (params["layers"], state["k"], state["v"])
    )
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = L.lm_logits(h, head, transpose="lm_head" not in params)[:, 0]
    new_state = {"k": new_k, "v": new_v, "pos": pos + 1}
    return new_state, logits


def prefill(
    cfg: ModelConfig, params: dict, batch: dict, max_seq: int
) -> tuple[dict, jax.Array]:
    """Process a full prompt, build the KV cache, return last-token logits."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    positions = jnp.arange(t)

    def body(carry, lp):
        h = carry
        hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["attn"], hn, positions)
        if cfg.use_pallas and t % 128 == 0:
            from repro.kernels.flash_attention.ops import flash_attention

            out = jnp.moveaxis(
                flash_attention(
                    jnp.moveaxis(q, 2, 1),
                    jnp.moveaxis(k, 2, 1),
                    jnp.moveaxis(v, 2, 1),
                    causal=True,
                    window=cfg.sliding_window,
                ),
                1,
                2,
            )
        elif t <= cfg.attn_chunk:
            out = L.dense_attention(q, k, v, causal=True, window=cfg.sliding_window)
        else:
            out = chunk_attn = L.chunked_attention(
                q, k, v, causal=True, window=cfg.sliding_window, chunk=cfg.attn_chunk
            )
        out = out.reshape(b, t, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, lp["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.mlp_block(cfg, lp["mlp"], hn)
        return h, (k, v)

    body = L.remat_wrap(cfg, body)
    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])  # ks: (L, B, T, Hk, Dh)
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = L.lm_logits(h[:, -1:], head, transpose="lm_head" not in params)[:, 0]

    state = init_decode_state(cfg, b, max_seq)
    cache_len = state["k"].shape[2]
    if cfg.sliding_window and t > cache_len:
        # Keep only the trailing window, aligned to the ring-buffer slots.
        start = t - cache_len
        shift = start % cache_len
        ks = jnp.roll(ks[:, :, start:], shift, axis=2)
        vs = jnp.roll(vs[:, :, start:], shift, axis=2)
        state["k"] = ks.astype(cfg.cdtype())
        state["v"] = vs.astype(cfg.cdtype())
    else:
        state["k"] = jax.lax.dynamic_update_slice_in_dim(
            state["k"], ks.astype(cfg.cdtype()), 0, axis=2
        )
        state["v"] = jax.lax.dynamic_update_slice_in_dim(
            state["v"], vs.astype(cfg.cdtype()), 0, axis=2
        )
    state["pos"] = jnp.asarray(t, jnp.int32)
    return state, logits
