"""Whisper-style encoder-decoder. The conv audio frontend is a STUB per the
brief: ``input_specs`` provides precomputed frame embeddings (B, S_enc, D).

Simplifications noted in DESIGN.md: sinusoidal positions on both sides
(instead of Whisper's learned decoder embedding — keeps arbitrary decode
lengths), RMSNorm-free (LayerNorm with bias as in Whisper), GELU MLPs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.api import ModelConfig
from repro.models.params import ParamDef


def _ln_defs(n: int, d: int) -> dict:
    return {
        "scale": ParamDef((n, d), ("layers", None), init="ones"),
        "bias": ParamDef((n, d), ("layers", None), init="zeros"),
    }


def _gelu_mlp_defs(cfg: ModelConfig, stacked: int) -> dict:
    n, d, f = stacked, cfg.d_model, cfg.d_ff
    return {
        "w1": ParamDef((n, d, f), ("layers", "win", "wout")),
        "b1": ParamDef((n, f), ("layers", "wout"), init="zeros"),
        "w2": ParamDef((n, f, d), ("layers", "wout", "win")),
        "b2": ParamDef((n, d), ("layers", None), init="zeros"),
    }


def param_defs(cfg: ModelConfig) -> dict:
    ne, nd, d = cfg.n_encoder_layers, cfg.n_layers, cfg.d_model
    return {
        "encoder": {
            "layers": {
                "ln1": _ln_defs(ne, d),
                "attn": L.attn_param_defs(cfg, stacked=ne),
                "ln2": _ln_defs(ne, d),
                "mlp": _gelu_mlp_defs(cfg, ne),
            },
            "ln_post": {
                "scale": ParamDef((d,), (None,), init="ones"),
                "bias": ParamDef((d,), (None,), init="zeros"),
            },
        },
        "decoder": {
            "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
            "layers": {
                "ln1": _ln_defs(nd, d),
                "self_attn": L.attn_param_defs(cfg, stacked=nd),
                "ln2": _ln_defs(nd, d),
                "cross_attn": L.attn_param_defs(cfg, stacked=nd),
                "ln3": _ln_defs(nd, d),
                "mlp": _gelu_mlp_defs(cfg, nd),
            },
            "ln_f": {
                "scale": ParamDef((d,), (None,), init="ones"),
                "bias": ParamDef((d,), (None,), init="zeros"),
            },
        },
    }


def _ln(x, p, eps):
    return L.layernorm(x, p["scale"], p["bias"], eps)


def _gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("btd,df->btf", x, p["w1"].astype(dt)) + p["b1"].astype(dt)
    h = jax.nn.gelu(h)
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    out = jnp.einsum("btf,fd->btd", h, p["w2"].astype(dt)) + p["b2"].astype(dt)
    return constrain(out, ("act_batch", "act_seq", "act_embed"))


def sinusoid_positions(t: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32) + offset
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


def _cross_attn(
    cfg: ModelConfig, p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array
) -> jax.Array:
    """Cross attention: queries from decoder x, K/V precomputed from encoder."""
    b, t, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("btd,dk->btk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(b, t, cfg.n_heads, cfg.d_head)
    out = L.dense_attention(q, enc_k, enc_v, causal=False)
    out = out.reshape(b, t, cfg.n_heads * cfg.d_head)
    return jnp.einsum("btk,kd->btd", out, p["wo"].astype(dt))


def _enc_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    b, s, _ = enc_out.shape
    dt = enc_out.dtype
    k = jnp.einsum("btd,dk->btk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("btd,dk->btk", enc_out, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return (
        k.reshape(b, s, cfg.n_kv_heads, cfg.d_head),
        v.reshape(b, s, cfg.n_kv_heads, cfg.d_head),
    )


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder output."""
    h = frames.astype(cfg.cdtype())
    h = h + sinusoid_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    h = constrain(h, ("act_batch", "act_seq", "act_embed"))
    positions = jnp.arange(h.shape[1])
    enc = params["encoder"]

    def body(carry, lp):
        h = carry
        hn = _ln(h, lp["ln1"], cfg.norm_eps)
        h = h + L.attn_block(cfg, lp["attn"], hn, positions, causal=False)
        hn = _ln(h, lp["ln2"], cfg.norm_eps)
        h = h + _gelu_mlp(lp["mlp"], hn)
        return h, None

    body = L.remat_wrap(cfg, body)
    h, _ = jax.lax.scan(body, h, enc["layers"])
    return _ln(h, enc["ln_post"], cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    dec = params["decoder"]
    h = L.embed_tokens(dec["embed"], tokens, cfg.cdtype())
    h = h + sinusoid_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lp):
        h = carry
        hn = _ln(h, lp["ln1"], cfg.norm_eps)
        h = h + L.attn_block(cfg, lp["self_attn"], hn, positions, causal=True)
        hn = _ln(h, lp["ln2"], cfg.norm_eps)
        ek, ev = _enc_kv(cfg, lp["cross_attn"], enc_out)
        h = h + _cross_attn(cfg, lp["cross_attn"], hn, ek, ev)
        hn = _ln(h, lp["ln3"], cfg.norm_eps)
        h = h + _gelu_mlp(lp["mlp"], hn)
        return h, None

    body = L.remat_wrap(cfg, body)
    h, _ = jax.lax.scan(body, h, dec["layers"])
    h = _ln(h, dec["ln_f"], cfg.norm_eps)
    return L.lm_logits(h, dec["embed"], transpose=True)  # tied head


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    xshape = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, cfg.cdtype()),
        "v": jnp.zeros(shape, cfg.cdtype()),
        "xk": jnp.zeros(xshape, cfg.cdtype()),
        "xv": jnp.zeros(xshape, cfg.cdtype()),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_state_logical() -> dict:
    return {
        "k": ("layers", "act_batch", "act_kv_seq", None, None),
        "v": ("layers", "act_batch", "act_kv_seq", None, None),
        "xk": ("layers", "act_batch", "act_kv_seq", None, None),
        "xv": ("layers", "act_batch", "act_kv_seq", None, None),
        "pos": (),
    }


def decode_step(cfg: ModelConfig, params: dict, state: dict, tokens: jax.Array):
    from repro.models import dense  # for _attn_qkv_1tok

    pos = state["pos"]
    dec = params["decoder"]
    h = L.embed_tokens(dec["embed"], tokens[:, None], cfg.cdtype())
    h = h + jax.lax.dynamic_slice_in_dim(
        sinusoid_positions(state["k"].shape[2], cfg.d_model), pos, 1, 0
    ).astype(h.dtype)

    def body(carry, xs):
        h = carry
        lp, kc, vc, xk, xv = xs
        hn = _ln(h, lp["ln1"], cfg.norm_eps)
        q, k, v = dense._attn_qkv_1tok(cfg, {"attn": lp["self_attn"]}, hn, pos)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos, axis=1)
        kc = constrain(kc, ("act_batch", "act_kv_seq", None, None))
        vc = constrain(vc, ("act_batch", "act_kv_seq", None, None))
        out = L.decode_attention(q, kc, vc, pos)
        out = out.reshape(h.shape[0], 1, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum(
            "btk,kd->btd", out, lp["self_attn"]["wo"].astype(h.dtype)
        )
        hn = _ln(h, lp["ln2"], cfg.norm_eps)
        h = h + _cross_attn(cfg, lp["cross_attn"], hn, xk, xv)
        hn = _ln(h, lp["ln3"], cfg.norm_eps)
        h = h + _gelu_mlp(lp["mlp"], hn)
        return h, (kc, vc)

    h, (new_k, new_v) = jax.lax.scan(
        body, h, (dec["layers"], state["k"], state["v"], state["xk"], state["xv"])
    )
    h = _ln(h, dec["ln_f"], cfg.norm_eps)
    logits = L.lm_logits(h, dec["embed"], transpose=True)[:, 0]
    new_state = dict(state, k=new_k, v=new_v, pos=pos + 1)
    return new_state, logits


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Encode frames, precompute cross-attention K/V, prime the decoder with
    the prompt tokens (teacher-forced pass that fills the self-attn cache)."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, t = tokens.shape
    dec = params["decoder"]
    h = L.embed_tokens(dec["embed"], tokens, cfg.cdtype())
    h = h + sinusoid_positions(t, cfg.d_model).astype(h.dtype)
    positions = jnp.arange(t)

    def body(carry, lp):
        h = carry
        hn = _ln(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["self_attn"], hn, positions)
        out = L.dense_attention(q, k, v, causal=True)
        out = out.reshape(b, t, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum(
            "btk,kd->btd", out, lp["self_attn"]["wo"].astype(h.dtype)
        )
        hn = _ln(h, lp["ln2"], cfg.norm_eps)
        ek, ev = _enc_kv(cfg, lp["cross_attn"], enc_out)
        h = h + _cross_attn(cfg, lp["cross_attn"], hn, ek, ev)
        hn = _ln(h, lp["ln3"], cfg.norm_eps)
        h = h + _gelu_mlp(lp["mlp"], hn)
        return h, (k, v, ek, ev)

    body = L.remat_wrap(cfg, body)
    h, (ks, vs, xks, xvs) = jax.lax.scan(body, h, dec["layers"])
    h = _ln(h, dec["ln_f"], cfg.norm_eps)
    logits = L.lm_logits(h[:, -1:], dec["embed"], transpose=True)[:, 0]

    state = init_decode_state(cfg, b, max_seq)
    state["k"] = jax.lax.dynamic_update_slice_in_dim(
        state["k"], ks.astype(cfg.cdtype()), 0, axis=2
    )
    state["v"] = jax.lax.dynamic_update_slice_in_dim(
        state["v"], vs.astype(cfg.cdtype()), 0, axis=2
    )
    state["xk"] = xks.astype(cfg.cdtype())
    state["xv"] = xvs.astype(cfg.cdtype())
    state["pos"] = jnp.asarray(t, jnp.int32)
    return state, logits
