"""Unified model configuration + family registry.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
``family`` field dispatches to the implementing module (dense / moe / ssm /
hybrid / encdec / vlm). Each family module exposes the same functional API:

    param_defs(cfg)                        -> ParamDef tree
    forward(cfg, params, batch)            -> logits           (training fwd)
    init_decode_state(cfg, batch, max_seq) -> abstract-friendly cache pytree
    prefill(cfg, params, batch)            -> (state, logits)
    decode_step(cfg, params, state, token) -> (state, logits)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # dense-attention options
    qkv_bias: bool = False
    sliding_window: int | None = None
    use_rope: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one *shared* attention block applied every k ssm blocks
    hybrid_attn_every: int = 0
    # enc-dec (whisper): encoder layers + stub frontend length
    n_encoder_layers: int = 0
    encoder_seq: int = 0
    # vlm (internvl2): stub patch embeddings prepended to the text sequence
    num_patches: int = 0
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # none | dots | full
    attn_chunk: int = 1024  # KV-chunk size of the scan-based flash attention
    use_pallas: bool = False  # kernels only on real TPU runs

    @property
    def n_q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (arch x shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def family_module(cfg: ModelConfig):
    from repro.models import dense, encdec, hybrid, mamba2, moe, vlm

    return {
        "dense": dense,
        "moe": moe,
        "ssm": mamba2,
        "hybrid": hybrid,
        "encdec": encdec,
        "vlm": vlm,
    }[cfg.family]


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Skip rules per brief: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, "constant-state SSM"
        if cfg.sliding_window is not None:
            return True, f"sliding window {cfg.sliding_window}"
        return False, "pure full attention is O(L^2) at 524k; skipped per brief"
    return True, ""
