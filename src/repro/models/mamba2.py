"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) decoder.

The sequence mixer is the chunked SSD algorithm (same math as the Pallas
``ssd_scan`` kernel, vectorized in jnp for the GSPMD path): intra-chunk
quadratic "attention form" + inter-chunk linear recurrence carried with an
associative scan. Decode keeps O(1) state per layer (conv window + SSM
state) — the ``long_500k`` cell runs at constant memory.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.api import ModelConfig
from repro.models.params import ParamDef


def _dims(cfg: ModelConfig):
    d_inner = cfg.d_inner
    n_heads = cfg.ssm_heads
    n_state = cfg.ssm_state
    conv_ch = d_inner + 2 * n_state  # x plus B and C streams (1 group)
    d_in_proj = 2 * d_inner + 2 * n_state + n_heads  # z, x, B, C, dt
    return d_inner, n_heads, n_state, conv_ch, d_in_proj


def block_param_defs(cfg: ModelConfig, *, stacked: int) -> dict:
    n = stacked
    d = cfg.d_model
    d_inner, n_heads, n_state, conv_ch, d_in_proj = _dims(cfg)
    return {
        "ln": ParamDef((n, d), ("layers", None), init="ones"),
        "in_proj": ParamDef((n, d, d_in_proj), ("layers", "win", "wout")),
        "conv_w": ParamDef(
            (n, cfg.ssm_conv_width, conv_ch), ("layers", None, "wout"), scale=0.3
        ),
        "conv_b": ParamDef((n, conv_ch), ("layers", "wout"), init="zeros"),
        "a_log": ParamDef((n, n_heads), ("layers", None), init="zeros"),
        "d_skip": ParamDef((n, n_heads), ("layers", None), init="ones"),
        "dt_bias": ParamDef((n, n_heads), ("layers", None), init="zeros"),
        "norm": ParamDef((n, d_inner), ("layers", None), init="ones"),
        "out_proj": ParamDef((n, d_inner, d), ("layers", "wout", "win")),
    }


def param_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "embed": ParamDef((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "layers": block_param_defs(cfg, stacked=cfg.n_layers),
        "ln_f": ParamDef((d,), (None,), init="ones"),
        "lm_head": ParamDef((d, cfg.vocab_size), ("embed", "vocab")),
    }


# ---------------------------------------------------------------------------
# Chunked SSD (jnp, GSPMD-friendly)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,  # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)  positive
    b: jax.Array,  # (B, T, N)  shared across heads (1 group)
    c: jax.Array,  # (B, T, N)
    a: jax.Array,  # (H,)       negative
    chunk: int,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,T,H,P), final_state (B,H,N,P)). fp32 internally.

    Sequences that do not divide the chunk length are padded with dt = 0
    steps (decay 1, zero input weight) — mathematically inert.
    """
    bsz, t_orig, h, p = x.shape
    n = b.shape[-1]
    lc = min(chunk, t_orig)
    pad = (-t_orig) % lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    t = t_orig + pad
    nc = t // lc

    xf = x.astype(jnp.float32).reshape(bsz, nc, lc, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, lc, h)
    bf = b.astype(jnp.float32).reshape(bsz, nc, lc, n)
    cf = c.astype(jnp.float32).reshape(bsz, nc, lc, n)

    loga = dtf * a.astype(jnp.float32)  # (B, nc, L, H)
    s = jnp.cumsum(loga, axis=2)  # inclusive within chunk
    s_h = jnp.moveaxis(s, 3, 2)  # (B, nc, H, L)
    s_tot = s_h[..., -1]  # (B, nc, H)

    # ---- intra-chunk ("attention form") ---------------------------------
    cb = jnp.einsum("bnik,bnjk->bnij", cf, bf)  # (B, nc, L, L)
    expo = s_h[..., :, None] - s_h[..., None, :]  # (B, nc, H, L, L)
    tri = jnp.tril(jnp.ones((lc, lc), bool))
    expo = jnp.where(tri, expo, -jnp.inf)
    m = cb[:, :, None] * jnp.exp(expo)  # (B, nc, H, L, L)
    m = m * jnp.moveaxis(dtf, 3, 2)[..., None, :]  # * dt_j
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp", m, xf)

    # ---- chunk states -----------------------------------------------------
    w = jnp.exp(s_tot[..., None] - s_h) * jnp.moveaxis(dtf, 3, 2)  # (B,nc,H,L)
    states = jnp.einsum("bnjk,bnhj,bnjhp->bnhkp", bf, w, xf)  # (B,nc,H,N,P)

    # ---- inter-chunk linear recurrence (associative scan over chunks) ----
    decay = jnp.exp(s_tot)  # (B, nc, H)

    def combine(left, right):
        a1, s1 = left
        a2, s2 = right
        return a1 * a2, a2[..., None, None] * s1 + s2

    dec_inc, st_inc = jax.lax.associative_scan(
        combine, (decay, states), axis=1
    )  # inclusive: state after chunk i
    # exclusive "state before chunk i":
    st_prev = jnp.concatenate(
        [jnp.zeros_like(st_inc[:, :1]), st_inc[:, :-1]], axis=1
    )
    final_state = st_inc[:, -1]  # (B, H, N, P)

    y_inter = jnp.einsum("bnik,bnhkp->bnihp", cf, st_prev) * jnp.exp(s)[..., None]
    y = (y_intra + y_inter).reshape(bsz, t, h, p)[:, :t_orig]
    return y.astype(x.dtype), final_state


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal 1D conv. x: (B, T, C); w: (W, C); b: (C,)."""
    width = w.shape[0]
    xpad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xpad,
        w[:, None, :],  # (W, 1, C) HIO with groups=C
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------


def mamba_block(cfg: ModelConfig, lp: dict, hn: jax.Array) -> jax.Array:
    """One Mamba-2 block on *pre-normed* input hn (residual add is external)."""
    bsz, t, _ = hn.shape
    d_inner, n_heads, n_state, conv_ch, _ = _dims(cfg)
    dt_ = hn.dtype

    zxbcdt = jnp.einsum("btd,dk->btk", hn, lp["in_proj"].astype(dt_))
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch :]  # (B, T, H)

    xbc = causal_conv1d(xbc, lp["conv_w"].astype(dt_), lp["conv_b"].astype(dt_))
    xbc = jax.nn.silu(xbc)
    x_in = xbc[..., :d_inner]
    b_in = xbc[..., d_inner : d_inner + n_state]
    c_in = xbc[..., d_inner + n_state :]

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))

    x_heads = x_in.reshape(bsz, t, n_heads, cfg.ssm_head_dim)
    x_heads = constrain(x_heads, ("act_batch", "act_seq", "act_heads", None))
    if cfg.use_pallas:
        from repro.kernels.ssd_scan.ops import ssd_scan

        y, _ = ssd_scan(
            jnp.moveaxis(x_heads, 2, 1),
            jnp.moveaxis(dt, 2, 1),
            jnp.repeat(b_in[:, None], n_heads, 1),
            jnp.repeat(c_in[:, None], n_heads, 1),
            a,
            chunk=cfg.ssm_chunk,
        )
        y = jnp.moveaxis(y, 1, 2)
    else:
        y, _ = ssd_chunked(x_heads, dt, b_in, c_in, a, cfg.ssm_chunk)
    y = y + lp["d_skip"].astype(y.dtype)[None, None, :, None] * x_heads
    y = y.reshape(bsz, t, d_inner)
    y = L.rmsnorm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, lp["out_proj"].astype(dt_))
    return constrain(out, ("act_batch", "act_seq", "act_embed"))


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())

    def body(carry, lp):
        hn = L.rmsnorm(carry, lp["ln"], cfg.norm_eps)
        return carry + mamba_block(cfg, lp, hn), None

    body = L.remat_wrap(cfg, body)
    h, _ = jax.lax.scan(body, h, params["layers"])
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    return L.lm_logits(h, params["lm_head"], transpose=False)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"])


# ---------------------------------------------------------------------------
# Serving — constant-size recurrent state
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    d_inner, n_heads, n_state, conv_ch, _ = _dims(cfg)
    del max_seq  # O(1) state — the whole point of the SSM cell
    return {
        "conv": jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv_width - 1, conv_ch), cfg.cdtype()
        ),
        "ssm": jnp.zeros(
            (cfg.n_layers, batch, n_heads, n_state, cfg.ssm_head_dim), jnp.float32
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_state_logical() -> dict:
    return {
        "conv": ("layers", "act_batch", None, "wout"),
        "ssm": ("layers", "act_batch", "act_heads", None, None),
        "pos": (),
    }


def block_decode(
    cfg: ModelConfig, lp: dict, hn: jax.Array, conv_state, ssm_state
):
    """Single-token mamba block on pre-normed hn: (B, 1, D).
    Returns (out, conv, ssm)."""
    bsz = hn.shape[0]
    d_inner, n_heads, n_state, conv_ch, _ = _dims(cfg)
    dt_ = hn.dtype

    zxbcdt = jnp.einsum("btd,dk->btk", hn, lp["in_proj"].astype(dt_))[:, 0]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch :]

    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B, W, ch)
    conv_out = jnp.einsum("bwc,wc->bc", window, lp["conv_w"].astype(dt_))
    conv_out = jax.nn.silu(conv_out + lp["conv_b"].astype(dt_))
    new_conv = window[:, 1:]

    x_in = conv_out[..., :d_inner]
    b_in = conv_out[..., d_inner : d_inner + n_state].astype(jnp.float32)
    c_in = conv_out[..., d_inner + n_state :].astype(jnp.float32)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32)
    )  # (B, H)
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B, H)

    x_heads = x_in.reshape(bsz, n_heads, cfg.ssm_head_dim).astype(jnp.float32)
    new_ssm = da[..., None, None] * ssm_state + jnp.einsum(
        "bn,bhp->bhnp", b_in, dt[..., None] * x_heads
    )
    y = jnp.einsum("bn,bhnp->bhp", c_in, new_ssm)
    y = y + lp["d_skip"].astype(jnp.float32)[None, :, None] * x_heads
    y = y.reshape(bsz, d_inner).astype(dt_)
    y = L.rmsnorm(y * jax.nn.silu(z), lp["norm"], cfg.norm_eps)
    out = jnp.einsum("bk,kd->bd", y, lp["out_proj"].astype(dt_))
    return out[:, None], new_conv, new_ssm


def decode_step(cfg: ModelConfig, params: dict, state: dict, tokens: jax.Array):
    h = L.embed_tokens(params["embed"], tokens[:, None], cfg.cdtype())

    def body(carry, xs):
        h = carry
        lp, conv, ssm = xs
        hn = L.rmsnorm(h, lp["ln"], cfg.norm_eps)
        out, conv, ssm = block_decode(cfg, lp, hn, conv, ssm)
        return h + out, (conv, ssm)

    h, (new_conv, new_ssm) = jax.lax.scan(
        body, h, (params["layers"], state["conv"], state["ssm"])
    )
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = L.lm_logits(h, params["lm_head"], transpose=False)[:, 0]
    return {"conv": new_conv, "ssm": new_ssm, "pos": state["pos"] + 1}, logits


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Prompt pass that also produces the recurrent state for decoding."""
    tokens = batch["tokens"]
    bsz, t = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    d_inner, n_heads, n_state, conv_ch, _ = _dims(cfg)

    def body(carry, lp):
        h = carry
        bszl, tl, _ = h.shape
        dt_ = h.dtype
        hn = L.rmsnorm(h, lp["ln"], cfg.norm_eps)
        zxbcdt = jnp.einsum("btd,dk->btk", hn, lp["in_proj"].astype(dt_))
        xbc = zxbcdt[..., d_inner : d_inner + conv_ch]
        conv_tail = causal_conv1d(
            xbc, lp["conv_w"].astype(dt_), lp["conv_b"].astype(dt_)
        )
        out = mamba_block(cfg, lp, hn)
        conv_state = xbc[:, -(cfg.ssm_conv_width - 1) :]
        # Recompute final ssm state via the chunked scan:
        xbc_act = jax.nn.silu(conv_tail)
        x_in = xbc_act[..., :d_inner].reshape(bszl, tl, n_heads, cfg.ssm_head_dim)
        b_in = xbc_act[..., d_inner : d_inner + n_state]
        c_in = xbc_act[..., d_inner + n_state :]
        dt = jax.nn.softplus(
            zxbcdt[..., d_inner + conv_ch :].astype(jnp.float32)
            + lp["dt_bias"].astype(jnp.float32)
        )
        a = -jnp.exp(lp["a_log"].astype(jnp.float32))
        _, fin = ssd_chunked(x_in, dt, b_in, c_in, a, cfg.ssm_chunk)
        return h + out, (conv_state, fin)

    body = L.remat_wrap(cfg, body)
    h, (convs, ssms) = jax.lax.scan(body, h, params["layers"])
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = L.lm_logits(h[:, -1:], params["lm_head"], transpose=False)[:, 0]
    state = {
        "conv": convs.astype(cfg.cdtype()),
        "ssm": ssms,
        "pos": jnp.asarray(t, jnp.int32),
    }
    return state, logits
