"""Shared transformer building blocks (norms, RoPE, attention, MLP).

All functions are pure; parameters arrive as nested dicts built from
``ParamDef`` trees. Activation sharding is requested through logical-axis
``constrain`` calls, so the same code runs single-device (no-op) and on the
production mesh (GSPMD collectives).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.api import ModelConfig
from repro.models.params import ParamDef

# ---------------------------------------------------------------------------
# Norms, embeddings, losses
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def embed_tokens(table: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    h = table.astype(compute_dtype)[tokens]
    return constrain(h, ("act_batch", "act_seq", "act_embed"))


def lm_logits(h: jax.Array, table_or_head: jax.Array, *, transpose: bool) -> jax.Array:
    """Final projection to vocab. fp32 logits for a stable softmax."""
    w = table_or_head.astype(jnp.float32)
    hf = h.astype(jnp.float32)
    if transpose:  # tied embedding table (V, D)
        logits = jnp.einsum("btd,vd->btv", hf, w)
    else:  # separate head (D, V)
        logits = jnp.einsum("btd,dv->btv", hf, w)
    return constrain(logits, ("act_batch", "act_seq", "act_heads"))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross entropy. logits (B, T, V) fp32, labels (B, T)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)
    )  # (d_head/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, D); positions: (B, T) or (T,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — scan-based flash (train/prefill) and cached decode
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int = 1024,
) -> jax.Array:
    """IO-aware attention as a lax.scan over KV chunks (online softmax).

    Pure-JAX analogue of the Pallas flash kernel: peak memory is
    O(B*H*T*chunk) instead of O(B*H*T*S). Differentiable; the body is
    rematerialized so the backward pass stores only the per-chunk carries.

    q: (B, T, H, D); k, v: (B, S, Hk, D) with H % Hk == 0. Query positions
    are aligned to the *end* of the key range (self-attention when T == S).
    """
    b, t, h, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = d**-0.5
    pad = (-s) % chunk
    if pad:  # pad keys/values; padded positions are masked out below
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = s + pad
    nc = s_pad // chunk

    qf = (q.astype(jnp.float32) * scale).astype(q.dtype)
    kc = k.reshape(b, nc, chunk, hk, d)
    vc = v.reshape(b, nc, chunk, hk, d)
    q_pos = jnp.arange(t) + (s - t)  # (T,) aligned to the *unpadded* end

    qg = qf.reshape(b, t, hk, g, d)  # grouped: no K/V head replication

    def body(carry, inp):
        m, l, acc = carry  # m, l: (B, Hk, G, T); acc: (B, Hk, G, T, D)
        ci, k_i, v_i = inp  # (B, C, Hk, D) blocks
        sc = jnp.einsum(
            "btkgd,bckd->bkgtc", qg, k_i, preferred_element_type=jnp.float32
        )  # (B, Hk, G, T, C)
        k_pos = ci * chunk + jnp.arange(chunk)  # (C,)
        mask = jnp.broadcast_to(k_pos[None, :] < s, (t, chunk))  # drop padding
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        # Additive 2D bias instead of two 5D selects: exp(NEG_INF - m) == 0
        # zeroes masked lanes for free (the 5D where/select_n pair was ~14%
        # of the train-cell HBM traffic, §Perf cell A iteration 5).
        bias = jnp.where(mask, 0.0, NEG_INF)  # (T, C)
        sc = sc + bias[None, None, None]
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        # Fully-masked rows keep m == NEG_INF; clamp the subtrahend so
        # exp(NEG_INF - clamp) underflows to 0 instead of exp(0) == 1.
        m_use = jnp.maximum(m_new, 0.1 * NEG_INF)
        p = jnp.exp(sc - m_use[..., None])
        alpha = jnp.exp(jnp.minimum(m - m_new, 0.0))
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd",
            p.astype(v_i.dtype),
            v_i,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    body = jax.checkpoint(body)

    m0 = jnp.full((b, hk, g, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, t), jnp.float32)
    acc0 = jnp.zeros((b, hk, g, t, d), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)  # (nc, B, C, Hk, D)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nc), kc_t, vc_t)
    )
    safe_l = jnp.where(l == 0.0, 1.0, l)
    out = acc / safe_l[..., None]  # (B, Hk, G, T, D)
    out = jnp.moveaxis(out.reshape(b, h, t, d), 1, 2)  # (B, T, H, D)
    return out.astype(q.dtype)


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    """Plain O(T*S)-memory attention (small shapes / oracle)."""
    b, t, h, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = d**-0.5
    qg = (q.astype(jnp.float32) * scale).reshape(b, t, hk, g, d)
    sc = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    q_pos = jnp.arange(t)[:, None] + (s - t)
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), dtype=bool)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-token attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, H, D); caches: (B, S, Hk, D); pos: () current index (the new
    token's position). The cache's seq axis may be sharded on the ``model``
    mesh axis (split-KV decode) — the softmax reductions below then lower to
    the cross-shard collectives of flash-decoding.
    """
    b, _, h, d = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    scale = d**-0.5
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(b, 1, hk, g, d)
    # bf16 operands + f32 accumulation: never materialize an f32 cache copy.
    sc = jnp.einsum(
        "btkgd,bskd->bkgts", qg, k_cache, preferred_element_type=jnp.float32
    )
    k_pos = jnp.arange(s)
    mask = k_pos <= pos
    if window is not None:
        mask = mask & (k_pos > pos - window)
    sc = jnp.where(mask[None, None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum(
        "bkgts,bskd->btkgd",
        p.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + RoPE + attention + output)
# ---------------------------------------------------------------------------


def attn_param_defs(cfg: ModelConfig, *, stacked: int | None = None) -> dict:
    """QKV/O projection ParamDefs. ``stacked``: leading layer dim for scan."""
    lead = (stacked,) if stacked else ()
    lead_log = ("layers",) if stacked else ()
    h, hk, dh, dm = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    defs = {
        "wq": ParamDef(lead + (dm, h * dh), lead_log + ("win", "wout")),
        "wk": ParamDef(lead + (dm, hk * dh), lead_log + ("win", "wout")),
        "wv": ParamDef(lead + (dm, hk * dh), lead_log + ("win", "wout")),
        "wo": ParamDef(lead + (h * dh, dm), lead_log + ("win", "wout")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef(lead + (h * dh,), lead_log + ("wout",), init="zeros")
        defs["bk"] = ParamDef(lead + (hk * dh,), lead_log + ("wout",), init="zeros")
        defs["bv"] = ParamDef(lead + (hk * dh,), lead_log + ("wout",), init="zeros")
    return defs


def attn_qkv(
    cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, t, _ = x.shape
    dt = x.dtype
    q = jnp.einsum("btd,dk->btk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dk->btk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dk->btk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, t, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, t, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = constrain(k, ("act_batch", None, "act_heads", None))  # replicated seq
    v = constrain(v, ("act_batch", None, "act_heads", None))
    return q, k, v


def attn_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Full self-attention block on (B, T, D) activations."""
    b, t, _ = x.shape
    q, k, v = attn_qkv(cfg, p, x, positions)
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention

        out = flash_attention(
            jnp.moveaxis(q, 2, 1),
            jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1),
            causal=causal,
            window=cfg.sliding_window,
        )
        out = jnp.moveaxis(out, 1, 2)
    elif t <= cfg.attn_chunk:
        out = dense_attention(q, k, v, causal=causal, window=cfg.sliding_window)
    else:
        out = chunked_attention(
            q, k, v, causal=causal, window=cfg.sliding_window, chunk=cfg.attn_chunk
        )
    out = constrain(out, ("act_batch", "act_seq", "act_heads", None))
    out = out.reshape(b, t, cfg.n_heads * cfg.d_head)
    out = jnp.einsum("btk,kd->btd", out, p["wo"].astype(x.dtype))
    return constrain(out, ("act_batch", "act_seq", "act_embed"))


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_param_defs(cfg: ModelConfig, *, stacked: int | None = None) -> dict:
    lead = (stacked,) if stacked else ()
    lead_log = ("layers",) if stacked else ()
    dm, ff = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamDef(lead + (dm, ff), lead_log + ("win", "wout")),
        "w_up": ParamDef(lead + (dm, ff), lead_log + ("win", "wout")),
        "w_down": ParamDef(lead + (ff, dm), lead_log + ("wout", "win")),
    }


def mlp_block(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(dt))
    up = jnp.einsum("btd,df->btf", x, p["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = constrain(h, ("act_batch", "act_seq", "act_mlp"))
    out = jnp.einsum("btf,fd->btd", h, p["w_down"].astype(dt))
    return constrain(out, ("act_batch", "act_seq", "act_embed"))


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------


def remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full"
