"""InternVL2-style VLM: stub ViT patch embeddings + InternLM2-style decoder.

Per the brief the vision frontend is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, num_patches, VIT_DIM). A small MLP projector
(the "mlp1" of InternVL) maps them into the LM embedding space; they are
prepended to the text tokens and the standard dense decoder runs over the
combined sequence. Loss is computed on text positions only.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import dense
from repro.models import layers as L
from repro.models.api import ModelConfig
from repro.models.params import ParamDef

VIT_DIM = 1024  # InternViT-300M output width (stubbed)


def param_defs(cfg: ModelConfig) -> dict:
    defs = dense.param_defs(cfg)
    d = cfg.d_model
    defs["projector"] = {
        "ln": ParamDef((VIT_DIM,), (None,), init="ones"),
        "w1": ParamDef((VIT_DIM, d), ("win", "wout")),
        "b1": ParamDef((d,), (None,), init="zeros"),
        "w2": ParamDef((d, d), ("win", "wout")),
        "b2": ParamDef((d,), (None,), init="zeros"),
    }
    return defs


def project_patches(cfg: ModelConfig, p: dict, patches: jax.Array) -> jax.Array:
    dt = cfg.cdtype()
    x = patches.astype(dt)
    x = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    x = jnp.einsum("bnd,dk->bnk", x, p["w1"].astype(dt)) + p["b1"].astype(dt)
    x = jax.nn.gelu(x)
    x = jnp.einsum("bnd,dk->bnk", x, p["w2"].astype(dt)) + p["b2"].astype(dt)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def _combined_hidden(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    patches = project_patches(cfg, params["projector"], batch["patches"])
    text = L.embed_tokens(params["embed"], batch["tokens"], cfg.cdtype())
    h = jnp.concatenate([patches, text], axis=1)
    return constrain(h, ("act_batch", "act_seq", "act_embed"))


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """Returns logits over *text* positions: (B, T_text, V)."""
    h = _combined_hidden(cfg, params, batch)
    positions = jnp.arange(h.shape[1])
    h = dense.backbone(cfg, params, h, positions)
    h_text = h[:, batch["patches"].shape[1] :]
    head = params.get("lm_head", params["embed"])
    return L.lm_logits(h_text, head, transpose="lm_head" not in params)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    return L.softmax_xent(forward(cfg, params, batch), batch["labels"])


# ---------------------------------------------------------------------------
# Serving — combined-sequence KV cache, then standard dense decode
# ---------------------------------------------------------------------------

init_decode_state = dense.init_decode_state
decode_state_logical = dense.decode_state_logical
decode_step = dense.decode_step  # params superset is scanned by subtree


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Prefill over [patches; prompt tokens]; decode continues text-only."""
    h = _combined_hidden(cfg, params, batch)
    b, t, _ = h.shape
    positions = jnp.arange(t)

    def body(carry, lp):
        h = carry
        hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["attn"], hn, positions)
        if t <= cfg.attn_chunk:
            out = L.dense_attention(q, k, v, causal=True)
        else:
            out = L.chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        out = out.reshape(b, t, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, lp["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + L.mlp_block(cfg, lp["mlp"], hn)
        return h, (k, v)

    body = L.remat_wrap(cfg, body)
    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = L.lm_logits(h[:, -1:], head, transpose="lm_head" not in params)[:, 0]

    state = init_decode_state(cfg, b, max_seq)
    state["k"] = jax.lax.dynamic_update_slice_in_dim(
        state["k"], ks.astype(cfg.cdtype()), 0, axis=2
    )
    state["v"] = jax.lax.dynamic_update_slice_in_dim(
        state["v"], vs.astype(cfg.cdtype()), 0, axis=2
    )
    state["pos"] = jnp.asarray(t, jnp.int32)
    return state, logits
