"""Mixture-of-Experts decoder (qwen3-moe family): token-choice top-k routing
with per-group capacity (GShard-style), expert-parallel sharding on the
``model`` mesh axis, scatter/gather dispatch (differentiable).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, current_context
from repro.models import dense
from repro.models import layers as L
from repro.models.api import ModelConfig
from repro.models.params import ParamDef


def moe_param_defs(cfg: ModelConfig, *, stacked: int) -> dict:
    n, d, e, f = stacked, cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    return {
        "router": ParamDef((n, d, e), ("layers", "win", None)),
        "w_gate": ParamDef((n, e, d, f), ("layers", "experts", "win", None)),
        "w_up": ParamDef((n, e, d, f), ("layers", "experts", "win", None)),
        "w_down": ParamDef((n, e, f, d), ("layers", "experts", None, "win")),
    }


def param_defs(cfg: ModelConfig) -> dict:
    defs = dense.param_defs(cfg)
    defs["layers"]["mlp"] = moe_param_defs(cfg, stacked=cfg.n_layers)
    return defs


def expert_capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    cap = int(
        cfg.capacity_factor
        * tokens_per_group
        * cfg.experts_per_token
        / cfg.num_experts
    )
    return max(8, (cap + 7) // 8 * 8)  # pad to a lane-friendly multiple


def _route(cfg: ModelConfig, router: jax.Array, x: jax.Array):
    """Top-k routing (replicable, collective-free).

    Returns (gates (B,T,K), eid (B,T*K), pos (B,T*K), keep, aux)."""
    b, t, _ = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = expert_capacity(cfg, t)
    router_logits = jnp.einsum(
        "btd,de->bte", x.astype(jnp.float32), router.astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)  # (B, T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B, T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * P_e.
    me = jnp.mean(probs, axis=(0, 1))
    onehot_top1 = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    fe = jnp.mean(onehot_top1, axis=(0, 1))
    aux = e * jnp.sum(me * fe)

    # Position-in-expert: rank of each (token, k) among assignments to the
    # same expert within the group, in (t, k) raster order. Sort-based: a
    # stable argsort groups equal expert-ids while preserving raster order,
    # so rank = index - start-of-run. O(B*T*K) memory — the one-hot cumsum
    # formulation is O(B*T*K*E), 128x more HBM traffic at E=128 (it was the
    # single largest traffic term in the 235B train cell, §Perf cell A).
    eid = expert_idx.reshape(b, t * k)
    pos = _pos_in_expert(eid)
    keep = (pos < c).astype(jnp.float32)
    return gate_vals, eid, jnp.minimum(pos, c - 1), keep, aux


def _pos_in_expert(eid: jax.Array) -> jax.Array:
    """Rank of each assignment within its expert, raster order. eid: (B, TK)."""
    tk = eid.shape[1]

    def one(e_row):
        order = jnp.argsort(e_row, stable=True)
        sorted_eid = e_row[order]
        idx = jnp.arange(tk, dtype=jnp.int32)
        is_start = jnp.concatenate(
            [jnp.ones((1,), bool), sorted_eid[1:] != sorted_eid[:-1]]
        )
        group_start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_start, idx, 0)
        )
        pos_sorted = idx - group_start
        return jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)

    return jax.vmap(one)(eid)


def _dispatch_ffn_combine(
    cfg: ModelConfig,
    x: jax.Array,  # (B, T, D)
    wg: jax.Array,  # (E_shard, D, F)
    wu: jax.Array,
    wd: jax.Array,  # (E_shard, F, D)
    gates: jax.Array,
    eid: jax.Array,
    pos: jax.Array,
    keep: jax.Array,
    e_offset,
) -> jax.Array:
    """Scatter -> per-expert FFN -> gather, for the experts in [e_offset,
    e_offset + E_shard). Assignments outside the range are masked. Fully
    local (no collectives) — the EP wrapper psums partial outputs."""
    b, t, d = x.shape
    e_shard = wg.shape[0]
    k = cfg.experts_per_token
    c = expert_capacity(cfg, t)
    dt = x.dtype

    local = (eid >= e_offset) & (eid < e_offset + e_shard)
    eid_l = jnp.clip(eid - e_offset, 0, e_shard - 1)
    mask = keep * local.astype(jnp.float32)

    def dispatch_one(xb, eb, pb, mb):
        # Inverse-map dispatch: scatter only the tiny int32 slot map
        # (E_s, C+1), then build the expert buffer with a GATHER. Forward
        # traffic is one (E_s, C, D) write instead of a (T*K, D) + buffer
        # read-modify-write scatter-add, and the VJP is an (E_s, C, D)-sized
        # scatter instead of (T*K, D) (§Perf cell A iteration 6).
        sentinel = jnp.int32(t * k)
        pb_safe = jnp.where(mb > 0, pb, c)  # invalid -> dump column
        slot = jnp.full((e_shard, c + 1), sentinel, jnp.int32)
        slot = slot.at[eb, pb_safe].min(jnp.arange(t * k, dtype=jnp.int32))
        slot = slot[:, :c]
        valid = slot != sentinel
        tok = jnp.clip(slot // k, 0, t - 1)
        return xb[tok] * valid[..., None].astype(dt)  # (E_s, C, D)

    buf = jax.vmap(dispatch_one)(x, eid_l, pos, mask)  # (B, E_s, C, D)

    hidden = jax.nn.silu(
        jnp.einsum("becd,edf->becf", buf, wg.astype(dt))
    ) * jnp.einsum("becd,edf->becf", buf, wu.astype(dt))
    out_buf = jnp.einsum("becf,efd->becd", hidden, wd.astype(dt))

    def combine_one(ob, eb, pb, mb, gb):
        gathered = ob[eb, pb]  # (T*K, D)
        return (gathered * (mb * gb)[:, None].astype(dt)).reshape(t, k, d).sum(
            axis=1
        )

    gates_flat = gates.reshape(b, t * k)
    return jax.vmap(combine_one)(out_buf, eid_l, pos, mask, gates_flat)


def moe_block(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE FFN. x: (B, T, D). Group = one batch row.

    Two execution paths:
      * expert-parallel ``shard_map`` over the ``model`` mesh axis (active
        whenever a sharding context with a dividing model axis is installed):
        routing is computed redundantly per shard (collective-free), each
        shard dispatches ONLY to its local experts, and partial outputs are
        psum'd — wire traffic per layer is one bf16 (B,T,D) gather + one
        psum instead of GSPMD replicating the (B,T*K,D) scatter (see
        EXPERIMENTS.md §Perf cell A).
      * plain single-device path (smoke tests / no mesh).
    Returns (output, aux_loss); overflow tokens beyond the expert capacity
    are dropped (standard capacity-factor routing).
    """
    ctx = current_context()
    use_ep = (
        ctx is not None
        and ctx.mesh is not None
        and "model" in ctx.mesh.shape
        and cfg.num_experts % ctx.mesh.shape["model"] == 0
        and ctx.mesh.shape["model"] > 1
    )
    if not use_ep:
        gates, eid, pos, keep, aux = _route(cfg, p["router"], x)
        out = _dispatch_ffn_combine(
            cfg, x, p["w_gate"], p["w_up"], p["w_down"], gates, eid, pos, keep, 0
        )
        return constrain(out, ("act_batch", "act_seq", "act_embed")), aux

    mesh = ctx.mesh
    e_shard = cfg.num_experts // mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dt = x.dtype
    # Sequence-sharded boundary only when T divides the model axis (train /
    # prefill). Decode (T=1) enters replicated over model — no backward
    # exists there, so the invariant-cotangent psum issue does not apply.
    seq_sharded = x.shape[1] % mesh.shape["model"] == 0

    import functools

    # Fully-manual shard_map: every collective below is explicit —
    #   boundary: gather x's seq shards over `model` (bf16 B*T*D once, NOT
    #             the K-fold-expanded dispatch tensor GSPMD moved before);
    #   inside:   FSDP all-gather of the local experts' weights over the
    #             data axes, cast to bf16 *before* the wire;
    #   combine:  one bf16 psum of partial outputs over `model`.
    # NOTE: bf16 all-reduces whose reducers carry Shardy annotations abort
    # XLA-CPU's AllReducePromotion pass; compile-only entry points disable it
    # (--xla_disable_hlo_passes=all-reduce-promotion). TPU is bf16-native.
    x_spec = P(dp_axes, "model") if seq_sharded else P(dp_axes)
    # Weight boundary follows the active rule set: FSDP-stored layouts
    # (train) enter D-sharded and are gathered in bf16 inside; TP-resident
    # layouts (serve_tp) enter whole — no per-step weight collectives.
    w_dp = tuple(ctx.rules.get("win", ()))
    w_dp = tuple(a for a in w_dp if a in mesh.shape)

    from repro.compat import shard_map

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(
            # x enters with its storage sharding (batch on dp, seq on model):
            # the gather happens *inside* via lax.all_gather, so its output
            # is "varying" and the transpose is a cheap (B,T,D)/16
            # reduce-scatter instead of a psum of the (B,T*K,D) cotangent
            # that the invariant-input formulation produced.
            x_spec,
            P(),  # router replicated (tiny)
            P("model", w_dp if w_dp else None),  # experts on model
            P("model", w_dp if w_dp else None),
            P("model", None, w_dp if w_dp else None),  # w_down: (E, F, D)
        ),
        out_specs=(x_spec, P()),
    )
    def _ep(xb, router, wg, wu, wd):
        if seq_sharded:
            xg = jax.lax.all_gather(xb.astype(dt), "model", axis=1, tiled=True)
        else:
            xg = xb.astype(dt)
        wg = wg.astype(dt)
        wu = wu.astype(dt)
        wd = wd.astype(dt)
        for ax in w_dp:
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
        gates, eid, pos, keep, aux = _route(cfg, router, xg)
        e_off = jax.lax.axis_index("model") * e_shard
        partial = _dispatch_ffn_combine(
            cfg, xg, wg, wu, wd, gates, eid, pos, keep, e_off
        )
        # Combine: reduce-scatter back to the seq-sharded layout (wire cost
        # (P-1)/P of one (B,T,D) vs 2x for a full psum); full psum when the
        # sequence is too short to shard (decode).
        if seq_sharded:
            out = jax.lax.psum_scatter(
                partial, "model", scatter_dimension=1, tiled=True
            )
        else:
            out = jax.lax.psum(partial, "model")
        aux = jax.lax.pmean(aux, "model")
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return out, aux

    out, aux = _ep(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    out = constrain(out, ("act_batch", "act_seq", "act_embed"))
    return out, aux


def _layer_fwd(cfg: ModelConfig, h, lp, positions):
    hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
    h = h + L.attn_block(cfg, lp["attn"], hn, positions)
    hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    out, aux = moe_block(cfg, lp["mlp"], hn)
    h = h + out
    return constrain(h, ("act_batch", "act_seq", "act_embed")), aux


def forward(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits, _ = forward_with_aux(cfg, params, batch)
    return logits


def forward_with_aux(cfg: ModelConfig, params: dict, batch: dict):
    tokens = batch["tokens"]
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lp):
        h, aux_sum = carry
        h, aux = _layer_fwd(cfg, h, lp, positions)
        return (h, aux_sum + aux), None

    body = L.remat_wrap(cfg, body)
    (h, aux_sum), _ = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), params["layers"]
    )
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = L.lm_logits(h, head, transpose="lm_head" not in params)
    return logits, aux_sum / cfg.n_layers


def loss_fn(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits, aux = forward_with_aux(cfg, params, batch)
    return L.softmax_xent(logits, batch["labels"]) + cfg.router_aux_coef * aux


# ---------------------------------------------------------------------------
# Serving — dense attention caches + per-token MoE FFN
# ---------------------------------------------------------------------------

init_decode_state = dense.init_decode_state
decode_state_logical = dense.decode_state_logical


def _moe_block_1tok(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Decode-time MoE on (B, 1, D) tokens.

    Under a mesh context this reuses the expert-parallel ``moe_block`` with
    t=1 groups: capacity per group is >= K, so routing is drop-free and
    exact, experts stay resident on their shards, and the only collective is
    the (B,1,D) combine — the per-token (B,K,D,F) weight gather of the naive
    formulation was the decode-cell collective bottleneck (§Perf extras).
    """
    from repro.distributed.sharding import current_context

    ctx = current_context()
    if ctx is not None and ctx.mesh is not None and "model" in ctx.mesh.shape:
        out, _ = moe_block(cfg, p, x)
        return out

    b, _, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    dt = x.dtype
    router_logits = jnp.einsum(
        "btd,de->bte", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )[:, 0]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B, K)
    gate_vals = (gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)).astype(dt)

    wg = p["w_gate"].astype(dt)[expert_idx]  # (B, K, D, F)
    wu = p["w_up"].astype(dt)[expert_idx]
    wd = p["w_down"].astype(dt)[expert_idx]  # (B, K, F, D)
    xb = x[:, 0]  # (B, D)
    hidden = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xb, wg)) * jnp.einsum(
        "bd,bkdf->bkf", xb, wu
    )
    out = jnp.einsum("bkf,bkfd->bkd", hidden, wd)
    out = jnp.sum(out * gate_vals[..., None], axis=1)
    return out[:, None]  # (B, 1, D)


def decode_step(cfg: ModelConfig, params: dict, state: dict, tokens: jax.Array):
    pos = state["pos"]
    h = L.embed_tokens(params["embed"], tokens[:, None], cfg.cdtype())

    def body(carry, xs):
        h = carry
        lp, kc, vc = xs
        hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, kk, vv = dense._attn_qkv_1tok(cfg, lp, hn, pos)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kk, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vv, pos, axis=1)
        kc = constrain(kc, ("act_batch", "act_kv_seq", None, None))
        vc = constrain(vc, ("act_batch", "act_kv_seq", None, None))
        out = L.decode_attention(q, kc, vc, pos, window=None)
        out = out.reshape(h.shape[0], 1, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, lp["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + _moe_block_1tok(cfg, lp["mlp"], hn)
        return h, (kc, vc)

    h, (new_k, new_v) = jax.lax.scan(
        body, h, (params["layers"], state["k"], state["v"])
    )
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = L.lm_logits(h, head, transpose="lm_head" not in params)[:, 0]
    return {"k": new_k, "v": new_v, "pos": pos + 1}, logits


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Prompt processing with MoE FFNs; returns (state, last logits)."""
    tokens = batch["tokens"]
    b, t = tokens.shape
    h = L.embed_tokens(params["embed"], tokens, cfg.cdtype())
    positions = jnp.arange(t)

    def body(carry, lp):
        h = carry
        hn = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, kk, vv = L.attn_qkv(cfg, lp["attn"], hn, positions)
        if t <= cfg.attn_chunk:
            out = L.dense_attention(q, kk, vv, causal=True)
        else:
            out = L.chunked_attention(q, kk, vv, causal=True, chunk=cfg.attn_chunk)
        out = out.reshape(b, t, cfg.n_heads * cfg.d_head)
        h = h + jnp.einsum("btk,kd->btd", out, lp["attn"]["wo"].astype(h.dtype))
        hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        mo, _ = moe_block(cfg, lp["mlp"], hn)
        h = h + mo
        return h, (kk, vv)

    body = L.remat_wrap(cfg, body)
    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = L.lm_logits(h[:, -1:], head, transpose="lm_head" not in params)[:, 0]
    state = init_decode_state(cfg, b, max_seq)
    state["k"] = jax.lax.dynamic_update_slice_in_dim(
        state["k"], ks.astype(cfg.cdtype()), 0, axis=2
    )
    state["v"] = jax.lax.dynamic_update_slice_in_dim(
        state["v"], vs.astype(cfg.cdtype()), 0, axis=2
    )
    state["pos"] = jnp.asarray(t, jnp.int32)
    return state, logits
