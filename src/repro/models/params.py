"""Parameter-definition helper: one spec tree drives init / abstract / sharding.

Each leaf is a :class:`ParamDef` (shape + logical axis names + init rule).
From one ``defs`` tree we derive:
  * ``init_tree``      — materialized parameters (real RNG init),
  * ``abstract_tree``  — ShapeDtypeStructs (dry-run: no allocation),
  * ``logical_tree``   — logical-axes annotations for the sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # stddev override (default: 1/sqrt(fan_in))

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _fan_in(shape: tuple[int, ...]) -> int:
    # Convention: last dim is the output dim; everything else is fan-in
    # (stacked-layer leading dims excluded by the caller via scale).
    if len(shape) <= 1:
        return shape[0] if shape else 1
    return int(np.prod(shape[:-1]))


def init_param(key: jax.Array, d: ParamDef, dtype: Any) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        std = d.scale if d.scale is not None else 0.02
        return std * jax.random.normal(key, d.shape, dtype)
    if d.init == "normal":
        # Stacked layer dims (logical name "layers"/"stack") don't count as fan-in.
        fan_dims = [
            s
            for s, l in zip(d.shape[:-1], d.logical[:-1])
            if l not in ("layers", "stack", "experts")
        ]
        fan = int(np.prod(fan_dims)) if fan_dims else max(1, _fan_in(d.shape))
        std = d.scale if d.scale is not None else fan**-0.5
        return std * jax.random.normal(key, d.shape, dtype)
    raise ValueError(f"unknown init {d.init}")


def init_tree(key: jax.Array, defs, dtype: Any = jnp.float32):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    out = []
    for i, d in enumerate(leaves):
        out.append(init_param(jax.random.fold_in(key, i), d, dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_tree(defs, dtype: Any = jnp.float32):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=_is_def
    )


def logical_tree(defs):
    return jax.tree.map(lambda d: d.logical, defs, is_leaf=_is_def)


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return int(sum(np.prod(d.shape) for d in leaves))
