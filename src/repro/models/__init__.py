"""LM model substrate for the assigned architectures."""
