"""AdamW + cosine schedule + global-norm clipping (no external deps).

Optimizer moments inherit the parameter sharding (they are tree-mapped from
params), so the 2D-sharded storage layout of the production mesh applies to
the full optimizer state automatically.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * scale


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip_scale, grads)

    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], grads)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cosine_schedule(cfg, count)

    def upd(p, mm, vv):
        mhat = mm / c1
        vhat = vv / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": m, "v": v, "count": count}, metrics
