"""Checkpointing: sharded-tree save/restore with resharding on load.

Layout per checkpoint:  <dir>/step_<N>/
    manifest.json   — tree structure, shapes, dtypes, crc32 per tensor, step
    <key>.npy       — one file per leaf (flattened '/'-joined key path)

Design notes for 1000+ node scale (this container is single-host):
  * each leaf is written from the fully-addressable host value; on a real
    multi-host pod each host would write only its owned shards (the manifest
    format already records per-leaf shape/dtype so a per-shard layout is a
    drop-in change — e.g. tensorstore/OCDBT);
  * restore takes *abstract* targets + shardings, so a checkpoint written on
    one mesh restores onto any other (elastic scaling / failover reshard);
  * the async writer overlaps serialization with the next training step and
    is awaited before the next save (bounded queue of 1);
  * integrity: crc32 per tensor, manifest written last (atomic rename), a
    checkpoint without a manifest is ignored by ``latest_step``.
"""

from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import zlib
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_part(p) for p in path)
        flat[key] = leaf
    return flat


def _path_part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree) -> str:
    """Synchronous sharded-tree save. Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "tensors": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["tensors"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    abstract_tree,
    shardings=None,
    *,
    verify: bool = True,
):
    """Load a checkpoint onto (possibly different) shardings.

    Args:
      abstract_tree: pytree of ShapeDtypeStructs (or arrays) giving targets.
      shardings: matching pytree of Shardings (or None leaves -> default).
    """
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_abstract = _flatten_with_paths(abstract_tree)
    flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}

    loaded = {}
    for key, target in flat_abstract.items():
        meta = manifest["tensors"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch for {key} in {path}")
        if tuple(arr.shape) != tuple(target.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs target {target.shape}"
            )
        arr = arr.astype(target.dtype)
        sh = flat_shard.get(key)
        loaded[key] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)

    # Rebuild the tree in the abstract tree's structure.
    paths, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
    leaves = ["/".join(_path_part(p) for p in path) for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in leaves])


class CheckpointManager:
    """Async writer + keep-last-k garbage collection."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: concurrent.futures.Future | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        # device_get on the main thread (arrays may be donated/overwritten next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = self._pool.submit(self._save_and_gc, step, host_tree)

    def _save_and_gc(self, step: int, host_tree) -> None:
        save_checkpoint(self.directory, step, host_tree)
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for old in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{old:09d}"), ignore_errors=True
            )

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None
