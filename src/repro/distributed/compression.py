"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

1-bit/8-bit SGD-style compression (Seide et al. '14; error feedback per
Karimireddy et al. '19, arXiv:1901.09847): each device quantizes its local
gradient shard to int8 with a per-block fp32 scale, all-reduces the int8
payload (8/32 = 4x less DP traffic; on the multi-pod mesh this is the
cross-DCN ``pod`` axis where bandwidth is scarcest), dequantizes the sum,
and accumulates the quantization residual into an error buffer that is added
back the next step — preserving convergence.

Used via ``shard_map`` around the gradient sync (pure-DP axes); the 2D
TP/FSDP shardings are untouched.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def symmetric_scale(maxabs: jax.Array) -> jax.Array:
    """Per-block decode scale for symmetric int8: ``maxabs / 127``, guarded.

    Degenerate blocks must never poison the round trip:

    * all-zero / constant-zero blocks (``maxabs == 0``) fall back to a
      positive scale — their codes are 0, so they still decode to exact
      zeros, but downstream ``q * scale`` never multiplies by 0.0 and the
      quantize-side division never produces 0/0 NaNs;
    * non-finite ``maxabs`` (an inf/NaN slipped into the block) would make
      ``q * scale`` NaN for *every* member; it also falls back to 1.0.

    Shared by the gradient compressor below and the quantized resident
    scenes in ``core.quant`` (per-chunk, per-band SH scales).
    """
    ok = jnp.isfinite(maxabs) & (maxabs > 0.0)
    return jnp.where(ok, maxabs, 1.0).astype(jnp.float32) / 127.0


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    """Blockwise symmetric int8 quantization. Returns (q, scales, orig_len).

    Non-finite inputs are zeroed before the block max so one bad value
    cannot blow up its whole block's scale; all-zero blocks get a positive
    fallback scale (see :func:`symmetric_scale`) and decode to exact zeros.
    """
    flat, n = _pad_to_block(x)
    blocks = flat.reshape(-1, BLOCK)
    blocks = jnp.where(jnp.isfinite(blocks), blocks, 0.0)
    scale = symmetric_scale(jnp.max(jnp.abs(blocks), axis=-1, keepdims=True))
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(
    q: jax.Array, scale: jax.Array, n: int, shape: Sequence[int]
) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return out.reshape(shape)


def compressed_psum(x: jax.Array, axis_name: str, err: jax.Array):
    """Error-feedback compressed psum over ``axis_name`` (inside shard_map).

    Returns (summed fp32 tensor, new error buffer).
    """
    corrected = x.astype(jnp.float32) + err
    q, scale, n = quantize_int8(corrected)
    deq_local = dequantize_int8(q, scale, n, x.shape)
    new_err = corrected - deq_local
    # The wire payload is the int8 q (+ tiny fp32 per-block scales): devices
    # all-gather the quantized shards and dequantize+sum locally. (A psum of
    # dequantized fp32 would void the bandwidth win.)
    q_all = jax.lax.all_gather(q, axis_name)  # (P, nblocks, BLOCK) int8
    s_all = jax.lax.all_gather(scale, axis_name)  # (P, nblocks, 1) fp32
    total = jnp.sum(q_all.astype(jnp.float32) * s_all, axis=0)
    out = total.reshape(-1)[:n].reshape(x.shape)
    return out, new_err


def init_error_buffers(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_tree_psum(grads, axis_name: str, err_tree):
    """Apply compressed_psum leaf-wise over a gradient tree."""
    outs = jax.tree.map(
        lambda g, e: compressed_psum(g, axis_name, e), grads, err_tree
    )
    summed = jax.tree.map(lambda o: o[0], outs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], outs, is_leaf=lambda x: isinstance(x, tuple))
    return summed, new_err
