"""Logical-axis sharding (MaxText-style) with divisibility guards.

Every parameter / activation dimension carries a *logical* name; a rule table
maps logical names to mesh axes. A rule only applies when the dimension size
divides the mesh-axis size — otherwise that dimension falls back to
replication (e.g. qwen2's 28 query heads do not divide a 16-way ``model``
axis, so head-sharded attention degrades gracefully instead of failing).

Two built-in rule sets (selected per run, hillclimbable):

* ``fsdp_sp``  — batch on (pod, data); sequence on model (sequence
  parallelism); weights 2D-sharded (input dim on (pod, data), output dim on
  model) and re-gathered per layer (ZeRO-3 behavior under GSPMD).
* ``tensor_parallel`` — batch on (pod, data); heads / mlp / experts on model
  (Megatron-style), sequence replicated inside a model group; weights stay
  model-sharded through the matmuls (no per-layer full gather).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Iterable, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Mesh axis groups. "pod" exists only on the multi-pod mesh; rules list it
# first and the guard drops missing axes automatically.
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"

RULE_SETS: dict[str, dict[str, tuple[str, ...]]] = {
    "fsdp_sp": {
        # --- activations ---
        "act_batch": DATA_AXES,
        "act_seq": (MODEL_AXIS,),
        "act_heads": (),
        "act_mlp": (),
        "act_kv_seq": (MODEL_AXIS,),  # decode-time split-KV
        "act_experts": (MODEL_AXIS,),
        "act_embed": (),
        # --- weights (storage sharding; gathered per layer by GSPMD) ---
        "win": DATA_AXES,
        "wout": (MODEL_AXIS,),
        "vocab": (MODEL_AXIS,),
        "embed": DATA_AXES,
        "experts": (MODEL_AXIS,),
        "layers": (),
        "stack": (),
    },
    "tensor_parallel": {
        "act_batch": DATA_AXES,
        "act_seq": (),
        "act_heads": (MODEL_AXIS,),
        "act_mlp": (MODEL_AXIS,),
        "act_kv_seq": (MODEL_AXIS,),
        "act_experts": (MODEL_AXIS,),
        "act_embed": (),
        "win": DATA_AXES,
        "wout": (MODEL_AXIS,),
        "vocab": (MODEL_AXIS,),
        "embed": DATA_AXES,
        "experts": (MODEL_AXIS,),
        "layers": (),
        "stack": (),
    },
    # Serving layout: weights resident, TP-sharded on `model` only (no FSDP
    # storage axis -> no per-token weight regathers, the §Roofline decode
    # bottleneck). Requires bf16 params; fits models up to ~25B on a 16-way
    # model axis of v5e (params/16 x 2B + caches).
    "serve_tp": {
        "act_batch": DATA_AXES,
        "act_seq": (),
        "act_heads": (),
        "act_mlp": (MODEL_AXIS,),
        "act_kv_seq": (MODEL_AXIS,),
        "act_experts": (MODEL_AXIS,),
        "act_embed": (),
        "win": (),
        "wout": (MODEL_AXIS,),
        "vocab": (MODEL_AXIS,),
        "embed": (MODEL_AXIS,),
        "experts": (MODEL_AXIS,),
        "layers": (),
        "stack": (),
    },
}


@dataclasses.dataclass(frozen=True)
class ShardingContext:
    mesh: Mesh | None
    rules: Mapping[str, tuple[str, ...]]

    def spec_for(self, shape: Sequence[int], logical: Sequence[str | None]) -> P:
        """Build a PartitionSpec, dropping non-dividing / absent axes."""
        if self.mesh is None:
            return P()
        assert len(shape) == len(logical), (shape, logical)
        used: set[str] = set()
        parts = []
        for size, name in zip(shape, logical):
            axes: list[str] = []
            if name is not None:
                extent = 1
                for ax in self.rules.get(name, ()):
                    if ax not in self.mesh.shape or ax in used:
                        continue
                    ax_size = self.mesh.shape[ax]
                    if size % (extent * ax_size) != 0:
                        continue
                    axes.append(ax)
                    extent *= ax_size
            parts.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
            used.update(axes)
        return P(*parts)

    def sharding_for(
        self, shape: Sequence[int], logical: Sequence[str | None]
    ) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(shape, logical))


_CTX: contextvars.ContextVar[ShardingContext | None] = contextvars.ContextVar(
    "sharding_ctx", default=None
)


@contextlib.contextmanager
def axis_rules(mesh: Mesh | None, mode: str = "fsdp_sp"):
    """Install a sharding context (mesh + logical rules) for the duration."""
    ctx = ShardingContext(mesh=mesh, rules=RULE_SETS[mode])
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def current_context() -> ShardingContext | None:
    return _CTX.get()


def constrain(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a context."""
    ctx = _CTX.get()
    if ctx is None or ctx.mesh is None:
        return x
    spec = ctx.spec_for(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def is_logical_leaf(node) -> bool:
    """A logical-axes annotation: tuple of dim names (str or None)."""
    return isinstance(node, tuple) and all(
        isinstance(e, (str, type(None))) for e in node
    )


def tree_shardings(logical_tree, abstract_tree, mesh: Mesh, mode: str = "fsdp_sp"):
    """Shardings pytree for (logical axes, abstract params) trees.

    ``logical_tree`` mirrors ``abstract_tree`` but with tuple-of-names leaves;
    it is passed first so ``is_leaf`` can stop recursion at the annotations.
    """
    ctx = ShardingContext(mesh=mesh, rules=RULE_SETS[mode])
    return jax.tree.map(
        lambda logical, leaf: ctx.sharding_for(leaf.shape, logical),
        logical_tree,
        abstract_tree,
        is_leaf=is_logical_leaf,
    )
