"""Training loop with fault tolerance, grad accumulation, and sharding.

``build_train_step`` produces the jitted SPMD step used both by the real
trainer and by the multi-pod dry-run (the dry-run lowers exactly what
production runs). The host-side :class:`Trainer` adds the reliability layer:
deterministic data replay, periodic async checkpoints, crash-restart, a
straggler watchdog, and elastic resume onto a different mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.data import SyntheticLMData
from repro.distributed import sharding as shd
from repro.models import params as P
from repro.models.api import ModelConfig, family_module
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: int


def microbatch_split(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scan-based gradient accumulation."""
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch
    )


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, gradients accumulate over a lax.scan of microbatch
    slices (compute/overlap trick: each microbatch's backward overlaps the
    next microbatch's data movement under XLA's scheduler).
    """
    mod = family_module(cfg)

    def loss_of(params, batch):
        return mod.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            mb = microbatch_split(batch, microbatches)

            def accum(carry, b):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_of)(params, b)
                return (
                    loss_sum + l,
                    jax.tree.map(lambda a, x: a + x.astype(jnp.float32), gsum, g),
                ), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), mb
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt_state, metrics = adamw_update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_sharded_state(
    cfg: ModelConfig,
    mesh,
    *,
    mode: str = "fsdp_sp",
    seed: int = 0,
) -> tuple[Any, Any, Any]:
    """Initialize params + optimizer state directly into their shardings."""
    mod = family_module(cfg)
    defs = mod.param_defs(cfg)
    logical = P.logical_tree(defs)
    abstract = P.abstract_tree(defs, cfg.pdtype())
    shardings = shd.tree_shardings(logical, abstract, mesh, mode)

    with mesh:
        # reprolint: disable=retrace-hazard -- one-shot setup: params and
        # optimizer state are initialized into their shardings exactly once
        # per training run.
        params = jax.jit(
            lambda key: P.init_tree(key, defs, cfg.pdtype()),
            out_shardings=shardings,
        )(jax.random.PRNGKey(seed))
        opt = jax.jit(  # reprolint: disable=retrace-hazard
            adamw_init,
            out_shardings={
                "m": shardings,
                "v": shardings,
                "count": None,
            },
        )(params)
    return params, opt, shardings


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    microbatches: int = 1
    sharding_mode: str = "fsdp_sp"
    straggler_factor: float = 3.0  # step slower than factor x median -> flagged
    max_restarts: int = 2


class Trainer:
    """Host-side reliability loop around the SPMD train step."""

    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        data: SyntheticLMData,
        mesh,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data = data
        self.mesh = mesh
        self.ckpt = CheckpointManager(
            tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints
        )
        self.step_fn = None
        self.step_times: list[float] = []
        self.straggler_events = 0
        self.restarts = 0
        self._failure_hook: Callable[[int], None] | None = None

    # -- failure injection (tests) --------------------------------------
    def inject_failure_at(self, step: int) -> None:
        fired = {"done": False}

        def hook(s):
            if s == step and not fired["done"]:
                fired["done"] = True
                raise RuntimeError(f"injected node failure at step {s}")

        self._failure_hook = hook

    # -- state ------------------------------------------------------------
    def _fresh_state(self) -> TrainState:
        params, opt, self.shardings = init_sharded_state(
            self.cfg, self.mesh, mode=self.tcfg.sharding_mode
        )
        return TrainState(params=params, opt=opt, step=0)

    def _abstract_state(self):
        mod = family_module(self.cfg)
        defs = mod.param_defs(self.cfg)
        abstract = P.abstract_tree(defs, self.cfg.pdtype())
        opt_abs = {
            "m": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), abstract
            ),
            "v": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), abstract
            ),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }
        return {"params": abstract, "opt": opt_abs}

    def restore_or_init(self) -> TrainState:
        step = latest_step(self.tcfg.checkpoint_dir)
        state = self._fresh_state()
        if step is None:
            return state
        abstract = self._abstract_state()
        shardings = {
            "params": self.shardings,
            "opt": {"m": self.shardings, "v": self.shardings, "count": None},
        }
        restored = restore_checkpoint(
            self.tcfg.checkpoint_dir, step, abstract, shardings
        )
        return TrainState(params=restored["params"], opt=restored["opt"], step=step)

    # -- main loop ----------------------------------------------------------
    def run(self) -> dict:
        with self.mesh, shd.axis_rules(self.mesh, self.tcfg.sharding_mode):
            return self._run_inner()

    def _run_inner(self) -> dict:
        state = self.restore_or_init()
        # reprolint: disable=retrace-hazard -- one compile per run() (and per
        # restart attempt, where the rebuilt executable is the point).
        step_fn = jax.jit(
            build_train_step(
                self.cfg, self.opt_cfg, microbatches=self.tcfg.microbatches
            ),
            donate_argnums=(0, 1),
        )
        metrics_log = []
        step = state.step
        params, opt = state.params, state.opt
        while step < self.tcfg.steps:
            try:
                if self._failure_hook:
                    self._failure_hook(step)
                t0 = time.perf_counter()
                batch = self.data.sharded_batch(
                    self.mesh, step, batch_axes=("pod", "data")
                )
                params, opt, metrics = step_fn(params, opt, batch)
                metrics["loss"].block_until_ready()
                dt = time.perf_counter() - t0
                # straggler watchdog (host-side; a slow step on any worker
                # shows up here as a slow global step)
                if len(self.step_times) >= 5:
                    med = float(np.median(self.step_times[-20:]))
                    if dt > self.tcfg.straggler_factor * med:
                        self.straggler_events += 1
                self.step_times.append(dt)
                step += 1
                if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                    metrics_log.append(
                        {
                            "step": step,
                            "loss": float(metrics["loss"]),
                            "grad_norm": float(metrics["grad_norm"]),
                            "sec_per_step": dt,
                        }
                    )
                if step % self.tcfg.checkpoint_every == 0:
                    self.ckpt.save_async(step, {"params": params, "opt": opt})
            except Exception:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                # crash-restart path: reload the latest durable checkpoint
                self.ckpt.wait()
                state = self.restore_or_init()
                params, opt, step = state.params, state.opt, state.step
        self.ckpt.wait()
        self.ckpt.save_async(step, {"params": params, "opt": opt})
        self.ckpt.wait()
        return {
            "final_step": step,
            "metrics": metrics_log,
            "straggler_events": self.straggler_events,
            "restarts": self.restarts,
        }
