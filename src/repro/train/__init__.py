from repro.train.trainer import Trainer, TrainState, build_train_step

__all__ = ["Trainer", "TrainState", "build_train_step"]
