"""Jitted public wrapper: full-image Pallas rasterization from packed features."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rasterize as rast_lib
from repro.kernels.tile_rasterize import kernel as k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("height", "width", "block_g", "interpret"))
def tile_rasterize(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    background: jax.Array,
    *,
    block_g: int = k.DEFAULT_BLOCK_G,
    interpret: bool | None = None,
) -> jax.Array:
    """Rasterize packed depth-sorted features to an (H, W, 3) image.

    Pads pixels to full tiles and Gaussians to full blocks (mask row zeroed on
    the padding so blending is unaffected).
    """
    if interpret is None:
        interpret = _default_interpret()
    num_g = packed_sorted.shape[1]
    bg4 = jnp.concatenate([background, jnp.zeros((1,), background.dtype)])[None, :]

    block_g = min(block_g, max(128, num_g))
    pad_g = (-num_g) % block_g
    packed = jnp.pad(packed_sorted, ((0, 0), (0, pad_g)))
    # Zero out the mask row for padding lanes (pad writes zeros already).

    pix = rast_lib.pixel_grid(height, width)
    num_pix = height * width
    pad_p = (-num_pix) % k.TILE_PIX
    pix = jnp.pad(pix, ((0, pad_p), (0, 0)), constant_values=-1e6)

    call = k.build_pallas_call(
        num_pix + pad_p,
        num_g + pad_g,
        block_g=block_g,
        interpret=interpret,
        dtype=packed.dtype,
    )
    out = call(pix, packed, bg4)  # (P, 4)
    return out[:num_pix, 0:3].reshape(height, width, 3)
