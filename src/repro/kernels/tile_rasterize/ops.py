"""Jitted public wrappers: full-image Pallas rasterization from packed features.

``tile_rasterize`` is the dense on-device oracle (every tile visits every
block). ``tile_rasterize_binned`` visits only the 128-wide feature blocks on
each screen tile's block list (``repro.core.binning``), consumed through a
scalar-prefetched BlockSpec index map; forward-only.
``tile_rasterize_compact`` is the production path: a gather-to-compact stage
densifies each tile's exact Gaussian list so every kernel lane blends a live
Gaussian, and a ``jax.custom_vjp`` backed by a backward Pallas kernel makes
the whole thing trainable — gradients scatter back to per-Gaussian packed
features through the compaction gather's VJP.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binning as bin_lib
from repro.core import rasterize as rast_lib
from repro.kernels.gaussian_features.ref import unpack_features
from repro.kernels.tile_rasterize import kernel as k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("height", "width", "block_g", "interpret"))
def tile_rasterize(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    background: jax.Array,
    *,
    block_g: int = k.DEFAULT_BLOCK_G,
    interpret: bool | None = None,
) -> jax.Array:
    """Dense kernel: rasterize packed depth-sorted features to (H, W, 3).

    Pads pixels to full tiles and Gaussians to full blocks (mask row zeroed on
    the padding so blending is unaffected).
    """
    if interpret is None:
        interpret = _default_interpret()
    num_g = packed_sorted.shape[1]
    bg4 = jnp.concatenate([background, jnp.zeros((1,), background.dtype)])[None, :]

    block_g = min(block_g, max(128, num_g))
    pad_g = (-num_g) % block_g
    packed = jnp.pad(packed_sorted, ((0, 0), (0, pad_g)))
    # Zero out the mask row for padding lanes (pad writes zeros already).

    pix = rast_lib.pixel_grid(height, width)
    num_pix = height * width
    pad_p = (-num_pix) % k.TILE_PIX
    pix = jnp.pad(pix, ((0, pad_p), (0, 0)), constant_values=-1e6)

    call = k.build_pallas_call(
        num_pix + pad_p,
        num_g + pad_g,
        block_g=block_g,
        interpret=interpret,
        dtype=packed.dtype,
    )
    out = call(pix, packed, bg4)  # (P, 4)
    return out[:num_pix, 0:3].reshape(height, width, 3)


def _tile_order_pixels(height_pad: int, width_pad: int, tile: int) -> jax.Array:
    """Pixel centers of an H_pad x W_pad image in screen-tile-major order."""
    pix = rast_lib.pixel_grid(height_pad, width_pad)
    pix = pix.reshape(height_pad // tile, tile, width_pad // tile, tile, 2)
    return pix.transpose(0, 2, 1, 3, 4).reshape(-1, 2)


@functools.partial(
    jax.jit,
    static_argnames=(
        "height", "width", "tile_size", "block_g", "max_blocks", "interpret"
    ),
)
def tile_rasterize_binned(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    background: jax.Array,
    *,
    tile_size: int = 16,
    block_g: int = k.DEFAULT_BLOCK_G,
    max_blocks: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Binned kernel: each screen tile blends only its live feature blocks.

    The per-tile block lists are built in JAX (``binning.tile_block_lists``)
    from the packed record's uv/radius/mask rows and handed to the kernel as
    a scalar-prefetch operand; sentinel entries point at one extra all-zero
    block appended past the real features.

    ``max_blocks`` statically caps each tile's list length — and with it the
    kernel's inner grid dimension, the actual compute saving. None keeps the
    worst-case bound (exact everywhere, but every tile pays the full trip
    count; only DMA of repeated sentinel blocks is saved). On overflow the
    front-most blocks win, mirroring ``tile_capacity``.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"pallas raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    num_g = packed_sorted.shape[1]
    bg4 = jnp.concatenate([background, jnp.zeros((1,), background.dtype)])[None, :]

    feats = unpack_features(packed_sorted)
    block_ids, num_blocks, max_blocks = bin_lib.tile_block_lists(
        feats,
        height,
        width,
        tile_size=tile_size,
        block_g=block_g,
        max_blocks=max_blocks,
    )

    # Features: pad the real lanes to whole blocks, then append the all-zero
    # sentinel block (index num_blocks).
    pad_g = num_blocks * block_g - num_g
    packed = jnp.pad(packed_sorted, ((0, 0), (0, pad_g + block_g)))

    tiles_y, tiles_x = bin_lib.tile_grid_shape(height, width, tile_size)
    num_tiles = tiles_y * tiles_x
    h_pad, w_pad = tiles_y * tile_size, tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)

    call = k.build_binned_pallas_call(
        num_tiles * k.TILE_PIX,
        (num_blocks + 1) * block_g,
        num_tiles,
        max_blocks,
        block_g=block_g,
        interpret=interpret,
        dtype=packed.dtype,
    )
    out = call(block_ids, pix, packed, bg4)  # (T*TILE_PIX, 4)
    img = out[:, 0:3].reshape(tiles_y, tiles_x, tile_size, tile_size, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(h_pad, w_pad, 3)
    return img[:height, :width]


# ---------------------------------------------------------------------------
# Compact path: gather-to-compact lists + custom VJP (the trainable kernel)
# ---------------------------------------------------------------------------


def build_compact_operands(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    *,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
) -> tuple[jax.Array, jax.Array, "bin_lib.TileBins", int]:
    """Gather-to-compact over the *packed* row layout the kernel streams.

    This is the same compaction ``binning.compact_tile_features`` defines
    (a gather of each tile's ``TileBins.indices`` into dense sentinel-padded
    per-tile records — a test pins the two together), laid out kernel-side:
    all 12 packed rows kept, lists padded to whole ``block_g`` chunks and
    flattened to (FEAT_ROWS, T * K) lanes. Differentiable w.r.t.
    ``packed_sorted`` (the gather's VJP scatter-adds across tiles).

    Returns (compact, nsteps (T,) float32 live-chunk counts, bins, steps).
    """
    num_g = packed_sorted.shape[1]
    feats = unpack_features(packed_sorted)
    bins = bin_lib.bin_gaussians(
        feats,
        height,
        width,
        tile_size=tile_size,
        capacity=capacity,
        tile_chunk=tile_chunk,
    )
    kk = bins.capacity
    k_pad = max(block_g, -(-kk // block_g) * block_g)
    idx = jnp.pad(
        bins.indices, ((0, 0), (0, k_pad - kk)), constant_values=jnp.int32(num_g)
    )

    # One all-zero sentinel column appended, then the per-tile lists
    # flattened along the lane axis.
    packed_pad = jnp.pad(packed_sorted, ((0, 0), (0, 1)))
    compact = packed_pad[:, idx.reshape(-1)]  # (FEAT_ROWS, T * k_pad)
    nsteps = (
        (bins.count + jnp.int32(block_g - 1)) // jnp.int32(block_g)
    ).astype(jnp.float32)
    return compact, nsteps, bins, k_pad // block_g


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _compact_blend(
    compact: jax.Array,  # (FEAT_ROWS, T * steps * block_g) compacted features
    pix: jax.Array,  # (T * TILE_PIX, 2) screen-tile-major pixel centers
    bg4: jax.Array,  # (1, 4)
    nsteps: jax.Array,  # (T,) float32 per-tile live-chunk counts
    num_tiles: int,
    steps: int,
    block_g: int,
    interpret: bool,
) -> jax.Array:
    """Forward compact Pallas blend -> (T * TILE_PIX, 4) rgb + transmittance.

    ``nsteps`` travels as float32 so the custom VJP can hand back an
    ordinary zero cotangent (it is cast to int32 for the scalar prefetch).
    """
    call = k.build_compact_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        interpret=interpret,
        dtype=compact.dtype,
    )
    return call(nsteps.astype(jnp.int32), pix, compact, bg4)


def _compact_blend_fwd(compact, pix, bg4, nsteps, num_tiles, steps, block_g, interpret):
    out = _compact_blend(
        compact, pix, bg4, nsteps, num_tiles, steps, block_g, interpret
    )
    # Residuals: the backward kernel replays the compacted lists and needs
    # the forward output (rgb for the rear-term trick, final transmittance).
    return out, (compact, pix, nsteps, out)


def _compact_blend_bwd(num_tiles, steps, block_g, interpret, res, gout):
    compact, pix, nsteps, out = res
    call = k.build_compact_bwd_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        interpret=interpret,
        dtype=compact.dtype,
    )
    dcompact = call(nsteps.astype(jnp.int32), pix, compact, out, gout)
    # Background cotangent: rgb += T_final * bg, so d_bg = sum_p T_N * d_rgb.
    dbg = jnp.sum(out[:, 3:4] * gout[:, 0:3], axis=0)
    dbg4 = jnp.concatenate([dbg, jnp.zeros((1,), dbg.dtype)])[None, :]
    return dcompact, jnp.zeros_like(pix), dbg4, jnp.zeros_like(nsteps)


_compact_blend.defvjp(_compact_blend_fwd, _compact_blend_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "height", "width", "tile_size", "capacity", "block_g", "tile_chunk",
        "interpret",
    ),
)
def tile_rasterize_compact(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    background: jax.Array,
    *,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
    interpret: bool | None = None,
) -> jax.Array:
    """Compact kernel: every lane blends a live Gaussian. Differentiable.

    Pipeline: bin the packed record's AABBs into per-tile index lists
    (``binning.bin_gaussians``), gather-to-compact them into a dense
    (FEAT_ROWS, T * K) tensor (sentinel index -> appended all-zero column),
    and stream K/block_g chunks per tile through the compact Pallas kernel.
    The gather is plain jnp, so its VJP scatter-adds the kernel's per-tile
    feature gradients back to per-Gaussian packed rows — combined with the
    kernel's custom VJP the whole path trains, matching the jnp binned path.

    ``capacity`` mirrors ``RenderConfig.tile_capacity`` (front-most K kept on
    overflow); it is rounded up to whole ``block_g`` chunks.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"pallas raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    bg4 = jnp.concatenate([background, jnp.zeros((1,), background.dtype)])[None, :]

    compact, nsteps, bins, steps = build_compact_operands(
        packed_sorted,
        height,
        width,
        tile_size=tile_size,
        capacity=capacity,
        block_g=block_g,
        tile_chunk=tile_chunk,
    )

    tiles_y, tiles_x = bins.tiles_y, bins.tiles_x
    num_tiles = bins.num_tiles
    h_pad, w_pad = tiles_y * tile_size, tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)

    out = _compact_blend(
        compact, pix, bg4, nsteps, num_tiles, steps, block_g, interpret
    )
    img = out[:, 0:3].reshape(tiles_y, tiles_x, tile_size, tile_size, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(h_pad, w_pad, 3)
    return img[:height, :width]
