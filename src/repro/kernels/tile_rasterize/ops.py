"""Jitted public wrappers: full-image Pallas rasterization from packed features.

``tile_rasterize`` is the dense on-device oracle (every tile visits every
block). ``tile_rasterize_binned`` is the production path: screen tiles visit
only the blocks on their per-tile list (``repro.core.binning``), which the
kernel consumes through a scalar-prefetched BlockSpec index map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binning as bin_lib
from repro.core import rasterize as rast_lib
from repro.kernels.gaussian_features.ref import unpack_features
from repro.kernels.tile_rasterize import kernel as k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("height", "width", "block_g", "interpret"))
def tile_rasterize(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    background: jax.Array,
    *,
    block_g: int = k.DEFAULT_BLOCK_G,
    interpret: bool | None = None,
) -> jax.Array:
    """Dense kernel: rasterize packed depth-sorted features to (H, W, 3).

    Pads pixels to full tiles and Gaussians to full blocks (mask row zeroed on
    the padding so blending is unaffected).
    """
    if interpret is None:
        interpret = _default_interpret()
    num_g = packed_sorted.shape[1]
    bg4 = jnp.concatenate([background, jnp.zeros((1,), background.dtype)])[None, :]

    block_g = min(block_g, max(128, num_g))
    pad_g = (-num_g) % block_g
    packed = jnp.pad(packed_sorted, ((0, 0), (0, pad_g)))
    # Zero out the mask row for padding lanes (pad writes zeros already).

    pix = rast_lib.pixel_grid(height, width)
    num_pix = height * width
    pad_p = (-num_pix) % k.TILE_PIX
    pix = jnp.pad(pix, ((0, pad_p), (0, 0)), constant_values=-1e6)

    call = k.build_pallas_call(
        num_pix + pad_p,
        num_g + pad_g,
        block_g=block_g,
        interpret=interpret,
        dtype=packed.dtype,
    )
    out = call(pix, packed, bg4)  # (P, 4)
    return out[:num_pix, 0:3].reshape(height, width, 3)


def _tile_order_pixels(height_pad: int, width_pad: int, tile: int) -> jax.Array:
    """Pixel centers of an H_pad x W_pad image in screen-tile-major order."""
    pix = rast_lib.pixel_grid(height_pad, width_pad)
    pix = pix.reshape(height_pad // tile, tile, width_pad // tile, tile, 2)
    return pix.transpose(0, 2, 1, 3, 4).reshape(-1, 2)


@functools.partial(
    jax.jit,
    static_argnames=(
        "height", "width", "tile_size", "block_g", "max_blocks", "interpret"
    ),
)
def tile_rasterize_binned(
    packed_sorted: jax.Array,
    height: int,
    width: int,
    background: jax.Array,
    *,
    tile_size: int = 16,
    block_g: int = k.DEFAULT_BLOCK_G,
    max_blocks: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Binned kernel: each screen tile blends only its live feature blocks.

    The per-tile block lists are built in JAX (``binning.tile_block_lists``)
    from the packed record's uv/radius/mask rows and handed to the kernel as
    a scalar-prefetch operand; sentinel entries point at one extra all-zero
    block appended past the real features.

    ``max_blocks`` statically caps each tile's list length — and with it the
    kernel's inner grid dimension, the actual compute saving. None keeps the
    worst-case bound (exact everywhere, but every tile pays the full trip
    count; only DMA of repeated sentinel blocks is saved). On overflow the
    front-most blocks win, mirroring ``tile_capacity``.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"pallas raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    num_g = packed_sorted.shape[1]
    bg4 = jnp.concatenate([background, jnp.zeros((1,), background.dtype)])[None, :]

    feats = unpack_features(packed_sorted)
    block_ids, num_blocks, max_blocks = bin_lib.tile_block_lists(
        feats,
        height,
        width,
        tile_size=tile_size,
        block_g=block_g,
        max_blocks=max_blocks,
    )

    # Features: pad the real lanes to whole blocks, then append the all-zero
    # sentinel block (index num_blocks).
    pad_g = num_blocks * block_g - num_g
    packed = jnp.pad(packed_sorted, ((0, 0), (0, pad_g + block_g)))

    tiles_y, tiles_x = bin_lib.tile_grid_shape(height, width, tile_size)
    num_tiles = tiles_y * tiles_x
    h_pad, w_pad = tiles_y * tile_size, tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)

    call = k.build_binned_pallas_call(
        num_tiles * k.TILE_PIX,
        (num_blocks + 1) * block_g,
        num_tiles,
        max_blocks,
        block_g=block_g,
        interpret=interpret,
        dtype=packed.dtype,
    )
    out = call(block_ids, pix, packed, bg4)  # (T*TILE_PIX, 4)
    img = out[:, 0:3].reshape(tiles_y, tiles_x, tile_size, tile_size, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(h_pad, w_pad, 3)
    return img[:height, :width]
