"""Tile-based alpha-blending Pallas TPU kernel (3DGS rasterization).

Completes the paper's pipeline on-device (the paper generated images on the
PS). Tiles of pixels stream depth-sorted Gaussian feature blocks through
VMEM; the order-dependent front-to-back transmittance is carried in VMEM
scratch across the sequentially-iterated innermost grid dimension.

Grid: (num_pixel_tiles, num_gaussian_blocks)
  pixel tile  = TILE_PIX flattened pixels (e.g. a 16x16 screen tile),
  gaussian block = BG depth-consecutive Gaussians (lane dimension).

Within a block the exclusive cumulative product of (1 - alpha) along the
lane axis resolves intra-block ordering; the running transmittance scratch
resolves inter-block ordering. This is the dense variant (every tile visits
every block, invisible Gaussians masked): a production splat would add the
per-tile index lists of the reference CUDA rasterizer (`sort_in_loop`), which
on TPU would become a gather of per-tile block lists — kept out of scope;
the pure-JAX oracle `repro.core.rasterize` remains the correctness anchor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.rasterize import ALPHA_EPS, ALPHA_MAX

TILE_PIX = 256  # pixels per tile (flattened 16x16)
DEFAULT_BLOCK_G = 128  # gaussians per block (lane dim)
FEAT_ROWS = 12  # packed feature record rows (see gaussian_features kernel)


def _raster_kernel(
    pix_ref,  # (TILE_PIX, 2) pixel centers
    feat_ref,  # (FEAT_ROWS, BG) packed, depth-sorted
    bg_ref,  # (1, 4) background rgb + pad
    out_ref,  # (TILE_PIX, 4) rgb + final transmittance
    t_scr,  # (TILE_PIX, 1) running transmittance
    acc_scr,  # (TILE_PIX, 4) rgb accumulator
    *,
    num_blocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    px = pix_ref[:, 0:1]  # (TP, 1)
    py = pix_ref[:, 1:2]
    u = feat_ref[0:1, :]  # (1, BG)
    v = feat_ref[1:2, :]
    con_a = feat_ref[2:3, :]
    con_b = feat_ref[3:4, :]
    con_c = feat_ref[4:5, :]
    opac = feat_ref[10:11, :]
    mask = feat_ref[11:12, :]

    dx = px - u  # (TP, BG)
    dy = py - v
    power = -0.5 * (con_a * dx * dx + con_c * dy * dy) - con_b * dx * dy
    power = jnp.minimum(power, 0.0)
    alpha = opac * jnp.exp(power) * mask
    alpha = jnp.minimum(alpha, ALPHA_MAX)
    alpha = jnp.where(alpha < ALPHA_EPS, 0.0, alpha)

    one_minus = 1.0 - alpha
    cum = jnp.cumprod(one_minus, axis=1)  # (TP, BG)
    excl = jnp.concatenate([jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1)
    w = alpha * excl * t_scr[...]  # (TP, BG)

    colors = feat_ref[5:8, :]  # (3, BG)
    rgb = jax.lax.dot_general(
        w, colors, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TP, 3)
    acc_scr[:, 0:3] = acc_scr[:, 0:3] + rgb
    t_scr[...] = t_scr[...] * cum[:, -1:]

    @pl.when(j == num_blocks - 1)
    def _finalize():
        t = t_scr[...]
        out = acc_scr[:, 0:3] + t * bg_ref[0, 0:3]
        out_ref[:, 0:3] = out.astype(out_ref.dtype)
        out_ref[:, 3:4] = t.astype(out_ref.dtype)


def build_pallas_call(
    num_pix: int,
    num_gaussians: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    if num_pix % TILE_PIX:
        raise ValueError(f"{num_pix=} must divide TILE_PIX={TILE_PIX}")
    if num_gaussians % block_g:
        raise ValueError(f"{num_gaussians=} must divide {block_g=}")
    num_tiles = num_pix // TILE_PIX
    num_blocks = num_gaussians // block_g
    grid = (num_tiles, num_blocks)

    return pl.pallas_call(
        functools.partial(_raster_kernel, num_blocks=num_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j: (t, 0)),
            pl.BlockSpec((FEAT_ROWS, block_g), lambda t, j: (0, j)),
            pl.BlockSpec((1, 4), lambda t, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_PIX, 4), lambda t, j: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_pix, 4), dtype),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 4), jnp.float32),
        ],
        interpret=interpret,
    )
