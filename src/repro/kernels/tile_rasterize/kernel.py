"""Tile-based alpha-blending Pallas TPU kernels (3DGS rasterization).

Completes the paper's pipeline on-device (the paper generated images on the
PS). Tiles of pixels stream depth-sorted Gaussian feature blocks through
VMEM; the order-dependent front-to-back transmittance is carried in VMEM
scratch across the sequentially-iterated innermost grid dimension.

Three variants share one blending body:

* **dense** — grid (num_pixel_tiles, num_gaussian_blocks): every tile visits
  every block (invisible Gaussians masked). The original kernel; retained as
  the on-device oracle.
* **binned** — grid (num_screen_tiles, max_blocks_per_tile): each 16x16
  screen tile visits only the feature blocks on its per-tile block list
  (built by ``repro.core.binning.tile_block_lists``). The list rides in as a
  scalar-prefetch operand and drives the feature BlockSpec's ``index_map``.
  Sparsity granularity is the 128-wide block of depth-consecutive
  Gaussians, so non-uniform scenes still blend mostly masked lanes.
* **compact** — grid (num_screen_tiles, chunks_per_tile): tile ``t``, step
  ``j`` DMAs chunk ``j`` of tile ``t``'s *gather-to-compact* feature tensor
  (``repro.core.binning`` compaction over ``TileBins.indices``) via a
  static BlockSpec index map. Every lane holds a Gaussian whose AABB
  actually overlaps the tile — the paper's "every cycle processes a live
  Gaussian" property. A scalar-prefetched per-tile chunk count skips the
  all-sentinel tail. The compact variant also has a **backward kernel**
  (`_compact_bwd_kernel`) that replays the compacted lists front-to-back,
  recomputes per-step transmittance, and emits per-lane gradients for
  uv/conic/color/opacity — the Pallas raster path trains through it (see
  the custom VJP in ``ops.py``).

Within a block the exclusive cumulative product of (1 - alpha) along the
lane axis resolves intra-block ordering; the running transmittance scratch
resolves inter-block ordering. The pure-JAX oracle ``repro.core.rasterize``
remains the correctness anchor.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.constants import ALPHA_EPS, ALPHA_MAX

TILE_PIX = 256  # pixels per tile (flattened 16x16)
DEFAULT_BLOCK_G = 128  # gaussians per block (lane dim)
FEAT_ROWS = 12  # packed feature record rows (see gaussian_features kernel)


class _LaneAlpha(NamedTuple):
    """Per-lane alpha model intermediates (each (TILE_PIX, BG) or (1, BG)).

    The backward kernel replays the forward model and chain-rules through
    it, so both consume the SAME evaluation — this helper is the single
    definition of the blending kernels' alpha math (mirroring the jnp
    oracle ``rasterize._pixel_alphas``).
    """

    dx: jnp.ndarray
    dy: jnp.ndarray
    con_a: jnp.ndarray
    con_b: jnp.ndarray
    con_c: jnp.ndarray
    power_raw: jnp.ndarray
    expw: jnp.ndarray
    alpha_raw: jnp.ndarray
    gate: jnp.ndarray
    alpha: jnp.ndarray
    opac: jnp.ndarray
    mask: jnp.ndarray


def _lane_alpha(pix_ref, feat_ref) -> _LaneAlpha:
    """Gated alpha of one (TILE_PIX, BG) feature block at the tile's pixels.

    Same support as the oracle: alpha floor + 3-sigma box (|d| <= radius),
    alpha capped at ALPHA_MAX.
    """
    px = pix_ref[:, 0:1]  # (TP, 1)
    py = pix_ref[:, 1:2]
    u = feat_ref[0:1, :]  # (1, BG)
    v = feat_ref[1:2, :]
    con_a = feat_ref[2:3, :]
    con_b = feat_ref[3:4, :]
    con_c = feat_ref[4:5, :]
    radius = feat_ref[9:10, :]
    opac = feat_ref[10:11, :]
    mask = feat_ref[11:12, :]

    dx = px - u  # (TP, BG)
    dy = py - v
    power_raw = -0.5 * (con_a * dx * dx + con_c * dy * dy) - con_b * dx * dy
    expw = jnp.exp(jnp.minimum(power_raw, 0.0))
    alpha_raw = opac * expw * mask
    alpha_capped = jnp.minimum(alpha_raw, ALPHA_MAX)
    inside = (jnp.abs(dx) <= radius) & (jnp.abs(dy) <= radius)
    gate = inside & (alpha_capped >= ALPHA_EPS)
    alpha = jnp.where(gate, alpha_capped, 0.0)
    return _LaneAlpha(
        dx, dy, con_a, con_b, con_c, power_raw, expw, alpha_raw, gate,
        alpha, opac, mask,
    )


def _blend_block(pix_ref, feat_ref, t_scr, acc_scr) -> None:
    """Blend one (TILE_PIX, BG) feature block into the running scratch."""
    alpha = _lane_alpha(pix_ref, feat_ref).alpha

    one_minus = 1.0 - alpha
    cum = jnp.cumprod(one_minus, axis=1)  # (TP, BG)
    excl = jnp.concatenate([jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1)
    w = alpha * excl * t_scr[...]  # (TP, BG)

    colors = feat_ref[5:8, :]  # (3, BG)
    rgb = jax.lax.dot_general(
        w, colors, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TP, 3)
    acc_scr[:, 0:3] = acc_scr[:, 0:3] + rgb
    t_scr[...] = t_scr[...] * cum[:, -1:]


def _finalize_out(bg_ref, out_ref, t_scr, acc_scr) -> None:
    t = t_scr[...]
    out = acc_scr[:, 0:3] + t * bg_ref[0, 0:3]
    out_ref[:, 0:3] = out.astype(out_ref.dtype)
    out_ref[:, 3:4] = t.astype(out_ref.dtype)


def _raster_kernel(
    pix_ref,  # (TILE_PIX, 2) pixel centers
    feat_ref,  # (FEAT_ROWS, BG) packed, depth-sorted
    bg_ref,  # (1, 4) background rgb + pad
    out_ref,  # (TILE_PIX, 4) rgb + final transmittance
    t_scr,  # (TILE_PIX, 1) running transmittance
    acc_scr,  # (TILE_PIX, 4) rgb accumulator
    *,
    num_blocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _blend_block(pix_ref, feat_ref, t_scr, acc_scr)

    @pl.when(j == num_blocks - 1)
    def _fin():
        _finalize_out(bg_ref, out_ref, t_scr, acc_scr)


def build_pallas_call(
    num_pix: int,
    num_gaussians: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Dense variant: every pixel tile visits every Gaussian block."""
    if num_pix % TILE_PIX:
        raise ValueError(f"{num_pix=} must divide TILE_PIX={TILE_PIX}")
    if num_gaussians % block_g:
        raise ValueError(f"{num_gaussians=} must divide {block_g=}")
    num_tiles = num_pix // TILE_PIX
    num_blocks = num_gaussians // block_g
    grid = (num_tiles, num_blocks)

    return pl.pallas_call(
        functools.partial(_raster_kernel, num_blocks=num_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j: (t, 0)),
            pl.BlockSpec((FEAT_ROWS, block_g), lambda t, j: (0, j)),
            pl.BlockSpec((1, 4), lambda t, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_PIX, 4), lambda t, j: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_pix, 4), dtype),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 4), jnp.float32),
        ],
        interpret=interpret,
    )


def _binned_raster_kernel(
    blist_ref,  # (num_tiles, max_blocks) int32 scalar-prefetch block list
    pix_ref,  # (TILE_PIX, 2) pixel centers (screen-tile order)
    feat_ref,  # (FEAT_ROWS, BG) block selected by the tile's list
    bg_ref,  # (1, 4)
    out_ref,  # (TILE_PIX, 4)
    t_scr,
    acc_scr,
    *,
    max_blocks: int,
):
    del blist_ref  # consumed by the BlockSpec index_map, not the body
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _blend_block(pix_ref, feat_ref, t_scr, acc_scr)

    @pl.when(j == max_blocks - 1)
    def _fin():
        _finalize_out(bg_ref, out_ref, t_scr, acc_scr)


def build_binned_pallas_call(
    num_pix: int,
    num_gaussians_padded: int,
    num_tiles: int,
    max_blocks: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Binned variant: per-tile block lists drive the feature index_map.

    Expects the packed feature operand to carry ``num_gaussians_padded``
    lanes = (num_blocks + 1) * block_g, where the LAST block is all zeros —
    the target of sentinel list entries.
    """
    if num_pix != num_tiles * TILE_PIX:
        raise ValueError(f"{num_pix=} must equal {num_tiles=} * {TILE_PIX}")
    if num_gaussians_padded % block_g:
        raise ValueError(f"{num_gaussians_padded=} must divide {block_g=}")
    grid = (num_tiles, max_blocks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j, blist: (t, 0)),
            # The per-tile block list picks which feature block lands in VMEM.
            pl.BlockSpec(
                (FEAT_ROWS, block_g), lambda t, j, blist: (0, blist[t, j])
            ),
            pl.BlockSpec((1, 4), lambda t, j, blist: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_PIX, 4), lambda t, j, blist: (t, 0)),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 4), jnp.float32),
        ],
    )

    return pl.pallas_call(
        functools.partial(_binned_raster_kernel, max_blocks=max_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_pix, 4), dtype),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Compact variant: gather-to-compact per-tile Gaussian lists + backward pass
# ---------------------------------------------------------------------------


def _compact_raster_kernel(
    nsteps_ref,  # (num_tiles,) int32 scalar-prefetch live-chunk counts
    pix_ref,  # (TILE_PIX, 2) pixel centers (screen-tile order)
    feat_ref,  # (FEAT_ROWS, BG) compacted chunk j of tile t
    bg_ref,  # (1, 4)
    out_ref,  # (TILE_PIX, 4)
    t_scr,
    acc_scr,
    *,
    steps: int,
):
    t = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Past the tile's live chunks every lane is a sentinel (alpha 0): skip
    # the blend math entirely. The DMA still lands, but compaction already
    # bounds dead steps to < 1 per tile on average (the partial last chunk).
    @pl.when(j < nsteps_ref[t])
    def _blend():
        _blend_block(pix_ref, feat_ref, t_scr, acc_scr)

    @pl.when(j == steps - 1)
    def _fin():
        _finalize_out(bg_ref, out_ref, t_scr, acc_scr)


def build_compact_pallas_call(
    num_tiles: int,
    steps: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Compact variant: tile t, step j reads compacted chunk t*steps + j.

    The feature operand is the (FEAT_ROWS, num_tiles * steps * block_g)
    gather-to-compact tensor — per-tile lists flattened along the lane axis.
    The chunk address is a *static* function of the grid position, so unlike
    the block-list kernel no scalar-prefetch indirection is needed for the
    DMA; the prefetched per-tile chunk counts only gate the blend compute.
    """
    grid = (num_tiles, steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j, ns: (t, 0)),
            pl.BlockSpec(
                (FEAT_ROWS, block_g), lambda t, j, ns: (0, t * steps + j)
            ),
            pl.BlockSpec((1, 4), lambda t, j, ns: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_PIX, 4), lambda t, j, ns: (t, 0)),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 4), jnp.float32),
        ],
    )

    return pl.pallas_call(
        functools.partial(_compact_raster_kernel, steps=steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_tiles * TILE_PIX, 4), dtype),
        interpret=interpret,
    )


def _compact_bwd_kernel(
    nsteps_ref,  # (num_tiles,) int32 scalar-prefetch live-chunk counts
    pix_ref,  # (TILE_PIX, 2)
    feat_ref,  # (FEAT_ROWS, BG) compacted chunk (same layout as forward)
    out_ref,  # (TILE_PIX, 4) forward output: rgb + final transmittance
    gout_ref,  # (TILE_PIX, 4) cotangent of the forward output
    dfeat_ref,  # (FEAT_ROWS, BG) gradient w.r.t. this compacted chunk
    t_scr,  # (TILE_PIX, 1) running transmittance (replayed)
    cum_scr,  # (TILE_PIX, 1) running sum of w_i * (c_i . d_rgb)
    *,
    steps: int,
):
    """Backward blend: replay the compacted list, emit per-lane grads.

    Writing ``rgb = sum_i c_i a_i T_i + B T_N`` with ``T_i`` the exclusive
    front-to-back transmittance, the alpha cotangent of lane ``i`` is

        d_alpha_i = T_i (c_i . d_rgb) - (D - S_i) / (1 - a_i)
                    - d_tout * T_N / (1 - a_i)

    where ``D = rgb_out . d_rgb`` (everything the tile rendered, background
    included) and ``S_i = sum_{j<=i} a_j T_j (c_j . d_rgb)`` is the running
    front side — so the rear term ``sum_{j>i} ... + B T_N (B . d_rgb)``
    never needs a back-to-front pass: one front-to-back replay with two
    scalars of per-pixel scratch covers it. From ``d_alpha`` the chain rule
    through ``alpha = min(opacity * exp(power) * mask, ALPHA_MAX)`` (with
    the oracle's support gate) yields uv/conic/color/opacity/mask grads,
    reduced over the tile's pixels into this chunk's gradient block. Each
    (tile, chunk) grid cell owns its output block exclusively — per-Gaussian
    accumulation across tiles happens in the gather's scatter-add VJP
    outside the kernel.
    """
    t = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        cum_scr[...] = jnp.zeros_like(cum_scr)

    @pl.when(j < nsteps_ref[t])
    def _bwd():
        colors = feat_ref[5:8, :]  # (3, BG)

        # --- replay the forward alpha model exactly (shared helper) -------
        la = _lane_alpha(pix_ref, feat_ref)
        dx, dy = la.dx, la.dy
        alpha = la.alpha

        one_minus = 1.0 - alpha
        cum = jnp.cumprod(one_minus, axis=1)  # (TP, BG)
        excl = jnp.concatenate([jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1)
        t_i = t_scr[...] * excl  # global exclusive transmittance
        w = alpha * t_i

        # --- alpha cotangent ----------------------------------------------
        drgb = gout_ref[:, 0:3]  # (TP, 3)
        dtout = gout_ref[:, 3:4]  # (TP, 1)
        s = jax.lax.dot_general(
            drgb, colors, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (TP, BG): c_i . d_rgb per lane
        d_total = jnp.sum(
            out_ref[:, 0:3] * drgb, axis=1, keepdims=True
        )  # (TP, 1)
        t_n = out_ref[:, 3:4]
        cums = cum_scr[...] + jnp.cumsum(w * s, axis=1)  # inclusive S_i
        dalpha = (
            t_i * s
            - (d_total - cums) / one_minus
            - dtout * t_n / one_minus
        )

        # --- chain through the gated alpha model --------------------------
        # alpha = where(gate, min(alpha_raw, ALPHA_MAX), 0): zero cotangent
        # outside the support gate and on the ALPHA_MAX-capped branch —
        # identical a.e. to jnp autodiff through the oracle.
        d_araw = jnp.where(la.gate & (la.alpha_raw < ALPHA_MAX), dalpha, 0.0)
        dopac = d_araw * la.expw * la.mask
        dmask = d_araw * la.opac * la.expw
        dpower = d_araw * la.alpha_raw
        dpraw = jnp.where(la.power_raw < 0.0, dpower, 0.0)
        ddx = dpraw * -(la.con_a * dx + la.con_b * dy)
        ddy = dpraw * -(la.con_c * dy + la.con_b * dx)

        def rsum(x):  # reduce over the tile's pixels -> (1, BG) grad row
            return jnp.sum(x, axis=0, keepdims=True)

        dfeat_ref[0:1, :] = rsum(-ddx)  # du (dx = px - u)
        dfeat_ref[1:2, :] = rsum(-ddy)
        dfeat_ref[2:3, :] = rsum(dpraw * (-0.5 * dx * dx))  # dconic a
        dfeat_ref[3:4, :] = rsum(dpraw * (-dx * dy))  # dconic b
        dfeat_ref[4:5, :] = rsum(dpraw * (-0.5 * dy * dy))  # dconic c
        dfeat_ref[5:6, :] = rsum(w * drgb[:, 0:1])  # dcolor r
        dfeat_ref[6:7, :] = rsum(w * drgb[:, 1:2])
        dfeat_ref[7:8, :] = rsum(w * drgb[:, 2:3])
        dfeat_ref[8:9, :] = jnp.zeros_like(la.opac)  # depth: sort key only
        dfeat_ref[9:10, :] = jnp.zeros_like(la.opac)  # radius: discrete gate
        dfeat_ref[10:11, :] = rsum(dopac)
        dfeat_ref[11:12, :] = rsum(dmask)

        t_scr[...] = t_scr[...] * cum[:, -1:]
        cum_scr[...] = cums[:, -1:]

    @pl.when(j >= nsteps_ref[t])
    def _dead():
        dfeat_ref[...] = jnp.zeros_like(dfeat_ref)


def build_compact_bwd_pallas_call(
    num_tiles: int,
    steps: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Backward pass over the compacted layout: one grad block per grid cell."""
    grid = (num_tiles, steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j, ns: (t, 0)),
            pl.BlockSpec(
                (FEAT_ROWS, block_g), lambda t, j, ns: (0, t * steps + j)
            ),
            pl.BlockSpec((TILE_PIX, 4), lambda t, j, ns: (t, 0)),
            pl.BlockSpec((TILE_PIX, 4), lambda t, j, ns: (t, 0)),
        ],
        out_specs=pl.BlockSpec(
            (FEAT_ROWS, block_g), lambda t, j, ns: (0, t * steps + j)
        ),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
        ],
    )

    return pl.pallas_call(
        functools.partial(_compact_bwd_kernel, steps=steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (FEAT_ROWS, num_tiles * steps * block_g), dtype
        ),
        interpret=interpret,
    )
