"""Tile-based alpha-blending Pallas TPU kernels (3DGS rasterization).

Completes the paper's pipeline on-device (the paper generated images on the
PS). Tiles of pixels stream depth-sorted Gaussian feature blocks through
VMEM; the order-dependent front-to-back transmittance is carried in VMEM
scratch across the sequentially-iterated innermost grid dimension.

Two variants share one blending body:

* **dense** — grid (num_pixel_tiles, num_gaussian_blocks): every tile visits
  every block (invisible Gaussians masked). The original kernel; retained as
  the on-device oracle.
* **binned** — grid (num_screen_tiles, max_blocks_per_tile): each 16x16
  screen tile visits only the feature blocks on its per-tile block list
  (built by ``repro.core.binning.tile_block_lists``). The list rides in as a
  scalar-prefetch operand and drives the feature BlockSpec's ``index_map`` —
  the TPU analogue of the reference CUDA rasterizer's per-tile ranges.
  Padding entries index one extra all-zero block (mask row 0), so short
  lists blend correctly without dynamic control flow.

Within a block the exclusive cumulative product of (1 - alpha) along the
lane axis resolves intra-block ordering; the running transmittance scratch
resolves inter-block ordering. The pure-JAX oracle ``repro.core.rasterize``
remains the correctness anchor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.rasterize import ALPHA_EPS, ALPHA_MAX

TILE_PIX = 256  # pixels per tile (flattened 16x16)
DEFAULT_BLOCK_G = 128  # gaussians per block (lane dim)
FEAT_ROWS = 12  # packed feature record rows (see gaussian_features kernel)


def _blend_block(pix_ref, feat_ref, t_scr, acc_scr) -> None:
    """Blend one (TILE_PIX, BG) feature block into the running scratch."""
    px = pix_ref[:, 0:1]  # (TP, 1)
    py = pix_ref[:, 1:2]
    u = feat_ref[0:1, :]  # (1, BG)
    v = feat_ref[1:2, :]
    con_a = feat_ref[2:3, :]
    con_b = feat_ref[3:4, :]
    con_c = feat_ref[4:5, :]
    radius = feat_ref[9:10, :]
    opac = feat_ref[10:11, :]
    mask = feat_ref[11:12, :]

    dx = px - u  # (TP, BG)
    dy = py - v
    power = -0.5 * (con_a * dx * dx + con_c * dy * dy) - con_b * dx * dy
    power = jnp.minimum(power, 0.0)
    alpha = opac * jnp.exp(power) * mask
    alpha = jnp.minimum(alpha, ALPHA_MAX)
    # Same support as the oracle: alpha floor + 3-sigma box (|d| <= radius).
    inside = (jnp.abs(dx) <= radius) & (jnp.abs(dy) <= radius)
    alpha = jnp.where(inside & (alpha >= ALPHA_EPS), alpha, 0.0)

    one_minus = 1.0 - alpha
    cum = jnp.cumprod(one_minus, axis=1)  # (TP, BG)
    excl = jnp.concatenate([jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1)
    w = alpha * excl * t_scr[...]  # (TP, BG)

    colors = feat_ref[5:8, :]  # (3, BG)
    rgb = jax.lax.dot_general(
        w, colors, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TP, 3)
    acc_scr[:, 0:3] = acc_scr[:, 0:3] + rgb
    t_scr[...] = t_scr[...] * cum[:, -1:]


def _finalize_out(bg_ref, out_ref, t_scr, acc_scr) -> None:
    t = t_scr[...]
    out = acc_scr[:, 0:3] + t * bg_ref[0, 0:3]
    out_ref[:, 0:3] = out.astype(out_ref.dtype)
    out_ref[:, 3:4] = t.astype(out_ref.dtype)


def _raster_kernel(
    pix_ref,  # (TILE_PIX, 2) pixel centers
    feat_ref,  # (FEAT_ROWS, BG) packed, depth-sorted
    bg_ref,  # (1, 4) background rgb + pad
    out_ref,  # (TILE_PIX, 4) rgb + final transmittance
    t_scr,  # (TILE_PIX, 1) running transmittance
    acc_scr,  # (TILE_PIX, 4) rgb accumulator
    *,
    num_blocks: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _blend_block(pix_ref, feat_ref, t_scr, acc_scr)

    @pl.when(j == num_blocks - 1)
    def _fin():
        _finalize_out(bg_ref, out_ref, t_scr, acc_scr)


def build_pallas_call(
    num_pix: int,
    num_gaussians: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Dense variant: every pixel tile visits every Gaussian block."""
    if num_pix % TILE_PIX:
        raise ValueError(f"{num_pix=} must divide TILE_PIX={TILE_PIX}")
    if num_gaussians % block_g:
        raise ValueError(f"{num_gaussians=} must divide {block_g=}")
    num_tiles = num_pix // TILE_PIX
    num_blocks = num_gaussians // block_g
    grid = (num_tiles, num_blocks)

    return pl.pallas_call(
        functools.partial(_raster_kernel, num_blocks=num_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j: (t, 0)),
            pl.BlockSpec((FEAT_ROWS, block_g), lambda t, j: (0, j)),
            pl.BlockSpec((1, 4), lambda t, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_PIX, 4), lambda t, j: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_pix, 4), dtype),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 4), jnp.float32),
        ],
        interpret=interpret,
    )


def _binned_raster_kernel(
    blist_ref,  # (num_tiles, max_blocks) int32 scalar-prefetch block list
    pix_ref,  # (TILE_PIX, 2) pixel centers (screen-tile order)
    feat_ref,  # (FEAT_ROWS, BG) block selected by the tile's list
    bg_ref,  # (1, 4)
    out_ref,  # (TILE_PIX, 4)
    t_scr,
    acc_scr,
    *,
    max_blocks: int,
):
    del blist_ref  # consumed by the BlockSpec index_map, not the body
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        t_scr[...] = jnp.ones_like(t_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    _blend_block(pix_ref, feat_ref, t_scr, acc_scr)

    @pl.when(j == max_blocks - 1)
    def _fin():
        _finalize_out(bg_ref, out_ref, t_scr, acc_scr)


def build_binned_pallas_call(
    num_pix: int,
    num_gaussians_padded: int,
    num_tiles: int,
    max_blocks: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Binned variant: per-tile block lists drive the feature index_map.

    Expects the packed feature operand to carry ``num_gaussians_padded``
    lanes = (num_blocks + 1) * block_g, where the LAST block is all zeros —
    the target of sentinel list entries.
    """
    if num_pix != num_tiles * TILE_PIX:
        raise ValueError(f"{num_pix=} must equal {num_tiles=} * {TILE_PIX}")
    if num_gaussians_padded % block_g:
        raise ValueError(f"{num_gaussians_padded=} must divide {block_g=}")
    grid = (num_tiles, max_blocks)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_PIX, 2), lambda t, j, blist: (t, 0)),
            # The per-tile block list picks which feature block lands in VMEM.
            pl.BlockSpec(
                (FEAT_ROWS, block_g), lambda t, j, blist: (0, blist[t, j])
            ),
            pl.BlockSpec((1, 4), lambda t, j, blist: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_PIX, 4), lambda t, j, blist: (t, 0)),
        scratch_shapes=[
            pltpu.VMEM((TILE_PIX, 1), jnp.float32),
            pltpu.VMEM((TILE_PIX, 4), jnp.float32),
        ],
    )

    return pl.pallas_call(
        functools.partial(_binned_raster_kernel, max_blocks=max_blocks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_pix, 4), dtype),
        interpret=interpret,
    )
