"""Pure-jnp oracle for the tile rasterizer kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import rasterize as rast_lib
from repro.kernels.gaussian_features.ref import unpack_features


def tile_rasterize_ref(
    pix: jnp.ndarray,
    packed_sorted: jnp.ndarray,
    background: jnp.ndarray,
) -> jnp.ndarray:
    """Blend packed depth-sorted features at given pixels.

    Args:
      pix: (P, 2) pixel centers.
      packed_sorted: (12, G) packed features, already depth-sorted.
      background: (3,) rgb.

    Returns: (P, 4) rgb + final transmittance.
    """
    feats = unpack_features(packed_sorted)
    alpha = rast_lib._pixel_alphas(pix, feats)  # (P, G)
    trans = jnp.cumprod(1.0 - alpha, axis=-1)
    t_prev = jnp.concatenate([jnp.ones_like(trans[:, :1]), trans[:, :-1]], axis=-1)
    weights = alpha * t_prev
    rgb = weights @ feats.color
    t_final = trans[:, -1:]
    return jnp.concatenate([rgb + t_final * background[None, :], t_final], axis=-1)
