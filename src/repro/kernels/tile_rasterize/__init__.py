from repro.kernels.tile_rasterize.ops import tile_rasterize

__all__ = ["tile_rasterize"]
