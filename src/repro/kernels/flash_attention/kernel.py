"""Flash attention Pallas TPU kernel (causal / GQA / sliding-window).

IO-aware attention (FlashAttention, arXiv:2205.14135) re-tiled for TPU:
Q/K/V blocks stream HBM->VMEM; the online-softmax state (m, l, acc) lives in
VMEM scratch and persists across the innermost grid dimension (KV blocks),
which Pallas-TPU iterates sequentially. MXU-aligned block sizes default to
(BT, BS, D) = (128, 128, d_head) with d_head in {64, 128}.

Grid: (batch, kv_heads, q_per_kv, T/BT, S/BS)  — GQA folds query-head groups
into the grid so K/V blocks are reused across the G query heads that share
them (the VMEM-residency analogue of GQA's HBM savings).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    num_kv_blocks: int,
):
    si = pl.program_id(4)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (BT, D)
    k = k_ref[0, 0, :, :].astype(jnp.float32)  # (BS, D)
    v = v_ref[0, 0, :, :].astype(jnp.float32)  # (BS, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (BT, BS)

    qi = pl.program_id(3)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = si * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (BT, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Rows with no valid key yet keep m = NEG_INF; guard the exp.
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(si == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


def build_pallas_call(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    q_len: int,
    kv_len: int,
    d_head: int,
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
    dtype=jnp.float32,
):
    if q_len % block_q or kv_len % block_k:
        raise ValueError(
            f"q_len={q_len} / kv_len={kv_len} must divide blocks ({block_q},{block_k})"
        )
    if num_q_heads % num_kv_heads:
        raise ValueError("GQA requires num_q_heads % num_kv_heads == 0")
    g = num_q_heads // num_kv_heads
    num_kv_blocks = kv_len // block_k
    grid = (batch, num_kv_heads, g, q_len // block_q, num_kv_blocks)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        num_kv_blocks=num_kv_blocks,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, d_head),
                lambda b, hk, gg, qi, si, g=g: (b, hk * g + gg, qi, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d_head), lambda b, hk, gg, qi, si: (b, hk, si, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d_head), lambda b, hk, gg, qi, si: (b, hk, si, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d_head),
            lambda b, hk, gg, qi, si, g=g: (b, hk * g + gg, qi, 0),
        ),
        out_shape=jax.ShapeDtypeStruct((batch, num_q_heads, q_len, d_head), dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, d_head), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )
