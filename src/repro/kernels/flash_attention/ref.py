"""Pure-jnp oracle for flash attention (causal / GQA / sliding window)."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jnp.ndarray:
    """Dense-softmax attention.

    Args:
      q: (B, H, T, D); k, v: (B, Hk, S, D) with H % Hk == 0 (GQA).
      causal: apply causal mask (positions aligned at the end: query i attends
        keys j with j <= i + (S - T); for self-attention T == S this is j <= i).
      window: sliding-window size (attend to the last `window` keys).

    Returns: (B, H, T, D) in q.dtype; softmax computed in fp32.
    """
    b, h, t, d = q.shape
    hk, s = k.shape[1], k.shape[2]
    g = h // hk
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", qf, kf)

    q_pos = jnp.arange(t, dtype=jnp.int32)[:, None] + (s - t)
    k_pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    mask = jnp.ones((t, s), dtype=bool)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = probs / jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, vf)
    return out.astype(q.dtype)
