"""Jitted public wrapper for the flash attention Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    kk: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = k.DEFAULT_BLOCK_Q,
    block_k: int = k.DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    """Flash attention. q: (B, H, T, D); kk, v: (B, Hk, S, D). Self-attention
    lengths only (T == S) when causal — cache-offset decode uses the XLA path.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, h, t, d = q.shape
    hk, s = kk.shape[1], kk.shape[2]
    if scale is None:
        scale = d ** -0.5
    bq = min(block_q, t)
    bk = min(block_k, s)
    call = k.build_pallas_call(
        b,
        h,
        hk,
        t,
        s,
        d,
        scale=scale,
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
        dtype=q.dtype,
    )
    return call(q, kk, v)
