"""Fused feature->blend Pallas TPU kernel (streaming 3DGS rasterization).

The unfused production path (``raster_path="pallas_binned"``) materializes a
12-row feature record for *every* Gaussian — full-degree SH evaluated for the
whole cloud — then streams compacted per-tile chunks of those features through
the blend kernel. This kernel collapses the two stages: each screen tile
streams its compacted **raw Gaussian parameters** (means, quats, log-scales,
SH coefficients, opacity logit — the 59-float training record) through the
full feature pipeline (projection, 2D covariance, SH color) *directly into*
front-to-back alpha blending, chunk by chunk, inside one kernel:

* **Chunk streaming.** Grid = (num_tiles,); tile ``t``'s whole compacted raw
  block (RAW_ROWS x steps*block_g) lands in VMEM and an in-kernel loop
  carries (transmittance, rgb accumulator) across its ``block_g``-wide
  chunks. Pallas's automatic grid pipelining double-buffers the per-tile
  block fetch — tile ``t+1``'s gather DMA overlaps tile ``t``'s
  feature+blend compute — so the raw stream behaves like the paper's
  AIE window interface: parameters flow through the math without a
  full-cloud feature tensor ever hitting HBM.
* **In-kernel early exit.** The chunk loop is a ``lax.while_loop`` whose
  condition requires both a live chunk (``j < nsteps[t]``) and an
  unsaturated tile (``max_pixel T >= EARLY_EXIT_EPS``). Once every lane of
  the tile saturates below 1/255, the remaining chunks are *not executed* —
  unlike a ``pl.when``-gated inner grid dimension, the trip itself
  disappears, which is where the fused speedup comes from on scenes with
  opaque front layers.
* **Banded SH (LOD).** A scalar-prefetched per-(tile, chunk) SH band — the
  max LOD degree of the chunk's live Gaussians, from the scene tree's
  distance LOD — selects via ``lax.switch`` how many SH basis functions are
  evaluated. Coefficients above a Gaussian's band are already zeroed by
  ``scene.apply_sh_lod``, so skipping their basis terms is exact: the band
  turns PR 5's zero-multiplies into a real basis-FLOP cut.
* **Backward.** ``_fused_bwd_kernel`` replays the compacted lists with the
  same saturation gate and emits per-lane gradients for the 12 *feature*
  rows using the D-minus-running-front-sum trick (see
  ``tile_rasterize._compact_bwd_kernel``, whose math it shares through
  ``_lane_alpha``). The feature values it consumes are recomputed from the
  raw records in plain jnp by the SAME ``lane_features`` below — elementwise
  per lane, hence bitwise-identical to the in-kernel evaluation — and the
  custom VJP in ``ops.py`` chains the kernel's feature cotangents through
  ``jax.vjp`` of that recompute back to raw parameters and camera.

``lane_features`` is the single source of truth for the raw->feature math:
the kernel body, the backward replay, and the jnp reference (``ref.py``) all
call it, so forward, backward and oracle agree exactly on alpha/gate
evaluation (the per-stage formulas mirror the ``gaussian_features`` kernel).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import features as feat_lib
from repro.core import sh as sh_lib
from repro.core.constants import ALPHA_EPS, ALPHA_MAX, EARLY_EXIT_EPS
from repro.kernels.gaussian_features.kernel import CAM_VEC_LEN
from repro.kernels.tile_rasterize.kernel import (
    FEAT_ROWS,
    TILE_PIX,
    _lane_alpha,
)

# Raw training-record rows (matches core.gaussians.pack_records):
# [0:3] position, [3:7] quaternion, [7:10] log scales, [10:58] SH (16*3),
# [58] opacity logit.
RAW_ROWS = 59
DEFAULT_BLOCK_G = 128

# Per-tile diagnostics plane columns (collect_stats=True side output):
# [0] chunks processed before exit, [1] lanes blended (sum of live-lane
# masks over processed chunks), [2] max SH band decoded, [3] pad.
STAT_COLS = 4

# Quantized-record operand rows (matches ops.pack_quant_rows; decode scales
# are the per-chunk table broadcast per lane at compaction time):
#   qf  (f32): [0:3] position, [3:7] quaternion, [7] log-scales scale,
#              [8] opacity scale, [9:12] SH band-1..3 scales.
#   qi (int8): [0:3] log scales, [3] opacity logit, [4:49] SH bands 1-3
#              (basis-major x 3 channels, mirroring raw rows 13:58).
#   qdc (fp16): [0:3] SH band-0 (DC) channels.
QF_ROWS = 12
QI_ROWS = 49
QDC_ROWS = 3

# (start, end) raw SH rows and qi rows of bands 1..3, with their qf scale row.
_QBANDS = (
    ((13, 22), (4, 13), 9),
    ((22, 37), (13, 28), 10),
    ((37, 58), (28, 49), 11),
)


class _LaneGeometry(NamedTuple):
    """Per-lane geometry intermediates, each shaped (L,)."""

    u: jnp.ndarray
    v: jnp.ndarray
    con_a: jnp.ndarray
    con_b: jnp.ndarray
    con_c: jnp.ndarray
    depth: jnp.ndarray
    radius: jnp.ndarray
    opacity: jnp.ndarray
    mask: jnp.ndarray
    dirx: jnp.ndarray
    diry: jnp.ndarray
    dirz: jnp.ndarray


class _LaneCamera(NamedTuple):
    """Duck-typed in-kernel stand-in for ``core.camera.Camera``.

    Carries exactly the attributes the staged stage functions touch,
    rebuilt from the packed camera operand (``pack_camera`` layout) —
    width/height ride as f32 scalars (comparisons produce the same bits as
    the Camera's static ints) and tan_fov/cam_pos reuse the packed values,
    which ``pack_camera`` computed with the same Camera properties the
    staged path reads.
    """

    r_cw: jnp.ndarray
    t_cw: jnp.ndarray
    fx: jnp.ndarray
    fy: jnp.ndarray
    cx: jnp.ndarray
    cy: jnp.ndarray
    tanx: jnp.ndarray
    tany: jnp.ndarray
    width: jnp.ndarray
    height: jnp.ndarray
    cam_pos: jnp.ndarray

    def tan_fov(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        return self.tanx, self.tany


def _lane_camera(cam: jax.Array) -> _LaneCamera:
    row = cam[0, :]
    return _LaneCamera(
        r_cw=row[0:9].reshape(3, 3),
        t_cw=row[9:12],
        fx=row[12],
        fy=row[13],
        cx=row[14],
        cy=row[15],
        tanx=row[16],
        tany=row[17],
        width=row[18],
        height=row[19],
        cam_pos=row[20:23],
    )


def lane_geometry(raw: jax.Array, cam: jax.Array) -> _LaneGeometry:
    """Screen-space geometry of raw records — (RAW_ROWS, L) -> per-lane rows.

    Calls the *actual* staged stage functions
    (``core.features.stage_cov3d`` ... ``stage_ray_dir``) on AoS views of
    the raw rows, with a ``_LaneCamera`` rebuilt from the packed camera
    operand. Two exactness properties follow by construction:

    * fused == unfused: the unfused ``pallas_binned`` production path (jnp
      feature paths) computes features with these same primitives, so the
      fused image differs only by blend-order reassociation (~1e-7), not
      formula drift.
    * forward == backward replay: every op is per-lane (the small matmuls
      and einsums contract over fixed camera axes only), so evaluating a
      (RAW_ROWS, block_g) kernel chunk or the full compacted tensor gives
      bitwise-identical values — the backward's recomputed alphas/gates
      walk the exact forward trajectory.

    The AoS reshapes and tiny dots are fine under interpret mode (this
    repo's deployment target); a real Mosaic TPU port would scalar-expand
    them as ``gaussian_features.kernel`` does.
    """
    c = _lane_camera(cam)
    positions = raw[0:3, :].T  # (L, 3)
    quats = raw[3:7, :].T  # (L, 4)
    scales = jnp.exp(raw[7:10, :].T)  # (L, 3) — GaussianParams.scales()

    cov3d = feat_lib.stage_cov3d(quats, scales)
    p_cam, uv, depth = feat_lib.stage_projection(positions, c)
    jac = feat_lib.stage_jacobian(p_cam, c)
    cov2d = feat_lib.stage_cov2d(cov3d, jac, c)
    conic, radius = feat_lib.stage_cov2d_inv(cov2d)
    rdir = feat_lib.stage_ray_dir(positions, c)

    u, v = uv[:, 0], uv[:, 1]
    opacity = jax.nn.sigmoid(raw[58, :])  # GaussianParams.opacities()
    # features._finalize's mask, with f32 width/height (same compare bits).
    onscreen = (
        (u > -radius)
        & (u < c.width + radius)
        & (v > -radius)
        & (v < c.height + radius)
    )
    mask = (
        (depth > feat_lib.NEAR_PLANE)
        & (radius > 0.0)
        & onscreen
        & (opacity >= ALPHA_EPS)
    ).astype(u.dtype)

    return _LaneGeometry(
        u,
        v,
        conic[:, 0],
        conic[:, 1],
        conic[:, 2],
        depth,
        radius,
        opacity,
        mask,
        rdir[:, 0],
        rdir[:, 1],
        rdir[:, 2],
    )


def lane_color(
    sh: jax.Array,
    dirx: jax.Array,
    diry: jax.Array,
    dirz: jax.Array,
    degree: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """SH color of (48, L) coefficient rows at a *static* degree.

    Defers to ``sh.eval_sh_color`` (the staged path's color stage) on the
    AoS view, evaluating only the ``(degree+1)^2`` basis functions of that
    degree — this is the function the banded kernel switches between, and
    (at the full static degree) the backward replay evaluates. Exact under
    banding because ``apply_sh_lod`` zeroes above-band coefficients: the
    skipped terms would each add ``0 * basis``.
    """
    sh_aos = sh.T.reshape(-1, 16, 3)  # inverts pack_records' sh.reshape(n, 48)
    dirs = jnp.stack([dirx, diry, dirz], axis=-1)
    rgb = sh_lib.eval_sh_color(sh_aos, dirs, degree=degree)
    return rgb[:, 0], rgb[:, 1], rgb[:, 2]


def lane_features(
    raw: jax.Array,
    cam: jax.Array,
    *,
    sh_degree: int,
    band: jax.Array | None = None,
) -> jax.Array:
    """(RAW_ROWS, L) raw records -> (FEAT_ROWS, L) packed features.

    ``band`` (a traced int32 scalar) selects the evaluated SH degree via
    ``lax.switch`` — only that branch's basis functions execute. ``None``
    evaluates the full static ``sh_degree`` (the backward-replay mode).
    """
    geo = lane_geometry(raw, cam)
    sh = raw[10:58, :]
    if band is None:
        col_r, col_g, col_b = lane_color(
            sh, geo.dirx, geo.diry, geo.dirz, sh_degree
        )
    else:
        branches = [
            functools.partial(
                lane_color, sh, geo.dirx, geo.diry, geo.dirz, d
            )
            for d in range(sh_degree + 1)
        ]
        col_r, col_g, col_b = jax.lax.switch(
            jnp.clip(band, 0, sh_degree), branches
        )
    return jnp.stack(
        [
            geo.u,
            geo.v,
            geo.con_a,
            geo.con_b,
            geo.con_c,
            col_r,
            col_g,
            col_b,
            geo.depth,
            geo.radius,
            geo.opacity,
            geo.mask,
        ],
        axis=0,
    )


def decode_lanes(
    qf: jax.Array,
    qi: jax.Array,
    qdc: jax.Array,
    *,
    max_band: int,
) -> jax.Array:
    """Decode quantized lanes to (RAW_ROWS, L) f32 raw records.

    ``q.astype(f32) * scale`` per field/band — the same elementwise ops as
    ``core.quant.dequantize_gaussians``, so the in-kernel decode is bitwise
    identical to the jnp dequantize of the resident scene (the lever behind
    the fused-quantized == fused-f32-on-dequantized exactness contract).

    ``max_band`` (static) is the highest SH band decoded; rows above it are
    exact zeros, so the degree-``max_band`` color evaluator never touches
    the above-band codes. Per-*lane* banding needs no mask here: the
    compaction (``ops.compact_fused_operands_q``) zeroes each lane's int8
    codes above its own band, and zero codes decode to exact zeros.
    """
    f32 = lambda a: a.astype(jnp.float32)  # noqa: E731
    rows = [
        qf[0:3, :],  # positions
        qf[3:7, :],  # quats
        f32(qi[0:3, :]) * qf[7:8, :],  # log scales
        f32(qdc),  # SH DC
    ]
    for b, ((lo, hi), (qlo, qhi), srow) in enumerate(_QBANDS, start=1):
        if b > max_band:
            rows.append(jnp.zeros((hi - lo, qf.shape[1]), jnp.float32))
            continue
        rows.append(f32(qi[qlo:qhi, :]) * qf[srow : srow + 1, :])
    rows.append(f32(qi[3:4, :]) * qf[8:9, :])  # opacity logit
    return jnp.concatenate(rows, axis=0)


def lane_features_q(
    qf: jax.Array,
    qi: jax.Array,
    qdc: jax.Array,
    cam: jax.Array,
    *,
    sh_degree: int,
    band: jax.Array | None = None,
) -> jax.Array:
    """Quantized lanes -> (FEAT_ROWS, L) features: decode *then* the exact
    ``lane_features`` math.

    With ``band`` (traced per-chunk SH LOD degree) the ``lax.switch`` picks
    decode *and* evaluation jointly: branch ``d`` decodes only bands <= d
    and evaluates the degree-``d`` basis — above-band coefficients are
    neither decoded nor multiplied, composing the compression with PR 6's
    banded-SH FLOP cut. Geometry decode is band-independent, so every
    branch walks bitwise-identical alphas/gates.
    """
    if band is None:
        raw = decode_lanes(qf, qi, qdc, max_band=sh_degree)
        return lane_features(raw, cam, sh_degree=sh_degree)

    def at_degree(d: int) -> jax.Array:
        raw = decode_lanes(qf, qi, qdc, max_band=d)
        return lane_features(raw, cam, sh_degree=d)

    return jax.lax.switch(
        jnp.clip(band, 0, sh_degree),
        [functools.partial(at_degree, d) for d in range(sh_degree + 1)],
    )


def _blend_chunk(
    pix: jax.Array,
    feat: jax.Array,
    t_pix: jax.Array,
    acc: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Functional blend of one (FEAT_ROWS, BG) chunk (loop-carried state).

    The in-kernel twin of ``tile_rasterize._blend_block``, with the
    transmittance/accumulator carried as ``while_loop`` state instead of
    VMEM scratch (the whole tile lives in one grid step here).
    """
    la = _lane_alpha(pix, feat)
    one_minus = 1.0 - la.alpha
    cum = jnp.cumprod(one_minus, axis=1)  # (TP, BG)
    excl = jnp.concatenate([jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1)
    w = la.alpha * excl * t_pix  # (TP, BG)
    colors = feat[5:8, :]  # (3, BG)
    rgb = jax.lax.dot_general(
        w, colors, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (TP, 3)
    return t_pix * cum[:, -1:], acc + rgb


def _stream_supertile(
    nsteps_ref,
    pix_all,
    bg,
    out_ref,
    chunk_features,
    *,
    early_exit: bool,
    tiles_per_step: int,
):
    """Shared forward supertile loop (f32 and quantized kernels).

    ``chunk_features(t, tt, j)`` produces chunk ``j``'s (FEAT_ROWS, block_g)
    features for supertile-local tile ``tt`` (global tile ``t``); everything
    else — the per-tile early-exiting chunk ``while_loop`` carrying
    (transmittance, rgb) and the supertile ``fori_loop`` — is identical, so
    the two record formats cannot drift in blend semantics.
    """
    g0 = pl.program_id(0)

    def tile_body(tt, out_acc):
        t = g0 * tiles_per_step + tt
        n = nsteps_ref[t]
        pix = jax.lax.dynamic_slice(
            pix_all, (tt * TILE_PIX, 0), (TILE_PIX, 2)
        )

        def cond(carry):
            j, t_pix, _ = carry
            live = j < n
            if early_exit:
                live = live & (jnp.max(t_pix) >= EARLY_EXIT_EPS)
            return live

        def body(carry):
            j, t_pix, acc = carry
            feat = chunk_features(t, tt, j)
            t_pix, acc = _blend_chunk(pix, feat, t_pix, acc)
            return j + jnp.int32(1), t_pix, acc

        t0 = jnp.ones((TILE_PIX, 1), jnp.float32)
        acc0 = jnp.zeros((TILE_PIX, 3), jnp.float32)
        _, t_pix, acc = jax.lax.while_loop(
            cond, body, (jnp.int32(0), t0, acc0)
        )
        tile_out = jnp.concatenate([acc + t_pix * bg, t_pix], axis=1)
        return jax.lax.dynamic_update_slice(
            out_acc, tile_out, (tt * TILE_PIX, 0)
        )

    out0 = jnp.zeros((tiles_per_step * TILE_PIX, 4), jnp.float32)
    out = jax.lax.fori_loop(0, tiles_per_step, tile_body, out0)
    out_ref[...] = out.astype(out_ref.dtype)


def _stream_supertile_stats(
    nsteps_ref,
    pix_all,
    bg,
    out_ref,
    stats_ref,
    chunk_features,
    chunk_band,
    *,
    early_exit: bool,
    tiles_per_step: int,
):
    """Diagnostics twin of :func:`_stream_supertile` (``collect_stats=True``).

    The image computation is the *identical op sequence* — same
    ``chunk_features`` calls, same ``_blend_chunk``, same loop conditions —
    so the rendered tile is bitwise-equal to the uninstrumented kernel's
    (pinned by test). The extended loop carry additionally accumulates,
    per tile, the :data:`STAT_COLS` diagnostics plane:

    * ``chunks_processed``: the final ``j`` — how many compacted chunks
      ran before ``nsteps`` ran out or every lane saturated (the *measured*
      early-exit depth, vs the theoretical ``nsteps`` upper bound);
    * ``lanes_blended``: sum of live-lane masks (feature row 11) over the
      processed chunks — live-lane occupancy as the blend actually saw it
      (mask sums are small integers in f32, so accumulation order cannot
      change the value);
    * ``max_band``: max SH band decoded over processed chunks
      (``chunk_band(t, j)``; the static ``sh_degree`` when unbanded).
    """
    g0 = pl.program_id(0)

    def tile_body(tt, carry):
        out_acc, stats_acc = carry
        t = g0 * tiles_per_step + tt
        n = nsteps_ref[t]
        pix = jax.lax.dynamic_slice(
            pix_all, (tt * TILE_PIX, 0), (TILE_PIX, 2)
        )

        def cond(carry):
            j, t_pix, _, _, _ = carry
            live = j < n
            if early_exit:
                live = live & (jnp.max(t_pix) >= EARLY_EXIT_EPS)
            return live

        def body(carry):
            j, t_pix, acc, lanes, band_max = carry
            feat = chunk_features(t, tt, j)
            lanes = lanes + jnp.sum(feat[11, :])
            band_max = jnp.maximum(band_max, chunk_band(t, j))
            t_pix, acc = _blend_chunk(pix, feat, t_pix, acc)
            return j + jnp.int32(1), t_pix, acc, lanes, band_max

        t0 = jnp.ones((TILE_PIX, 1), jnp.float32)
        acc0 = jnp.zeros((TILE_PIX, 3), jnp.float32)
        j, t_pix, acc, lanes, band_max = jax.lax.while_loop(
            cond,
            body,
            (jnp.int32(0), t0, acc0, jnp.float32(0.0), jnp.int32(0)),
        )
        tile_out = jnp.concatenate([acc + t_pix * bg, t_pix], axis=1)
        out_acc = jax.lax.dynamic_update_slice(
            out_acc, tile_out, (tt * TILE_PIX, 0)
        )
        row = jnp.stack(
            [
                j.astype(jnp.float32),
                lanes,
                band_max.astype(jnp.float32),
                jnp.float32(0.0),
            ]
        )[None, :]
        stats_acc = jax.lax.dynamic_update_slice(stats_acc, row, (tt, 0))
        return out_acc, stats_acc

    out0 = jnp.zeros((tiles_per_step * TILE_PIX, 4), jnp.float32)
    stats0 = jnp.zeros((tiles_per_step, STAT_COLS), jnp.float32)
    out, stats = jax.lax.fori_loop(0, tiles_per_step, tile_body, (out0, stats0))
    out_ref[...] = out.astype(out_ref.dtype)
    stats_ref[...] = stats.astype(stats_ref.dtype)


def _fused_raster_kernel(
    nsteps_ref,  # (num_tiles,) int32 scalar-prefetch live-chunk counts
    band_ref,  # (num_tiles, steps) int32 scalar-prefetch per-chunk SH band
    pix_ref,  # (tiles_per_step * TILE_PIX, 2) pixel centers (tile order)
    raw_ref,  # (RAW_ROWS, tiles_per_step * steps * block_g) raw records
    cam_ref,  # (1, CAM_VEC_LEN) packed camera constants
    bg_ref,  # (1, 4) background rgb + pad
    out_ref,  # (tiles_per_step * TILE_PIX, 4) rgb + final transmittance
    *maybe_stats_ref,  # (tiles_per_step, STAT_COLS) when collect_stats
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
    tiles_per_step: int,
    collect_stats: bool = False,
):
    raw_all = raw_ref[...]  # (RAW_ROWS, tiles_per_step * steps * block_g)
    cam = cam_ref[...]

    def chunk_features(t, tt, j):
        raw = jax.lax.dynamic_slice(
            raw_all, (0, (tt * steps + j) * block_g), (RAW_ROWS, block_g)
        )
        band = band_ref[t, j] if banded else None
        return lane_features(raw, cam, sh_degree=sh_degree, band=band)

    if collect_stats:
        chunk_band = (
            (lambda t, j: band_ref[t, j])
            if banded
            else (lambda t, j: jnp.int32(sh_degree))
        )
        _stream_supertile_stats(
            nsteps_ref,
            pix_ref[...],
            bg_ref[0, 0:3],
            out_ref,
            maybe_stats_ref[0],
            chunk_features,
            chunk_band,
            early_exit=early_exit,
            tiles_per_step=tiles_per_step,
        )
        return
    _stream_supertile(
        nsteps_ref,
        pix_ref[...],
        bg_ref[0, 0:3],
        out_ref,
        chunk_features,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
    )


def _fused_raster_kernel_q(
    nsteps_ref,  # (num_tiles,) int32 scalar-prefetch live-chunk counts
    band_ref,  # (num_tiles, steps) int32 scalar-prefetch per-chunk SH band
    pix_ref,  # (tiles_per_step * TILE_PIX, 2) pixel centers (tile order)
    qf_ref,  # (QF_ROWS, tiles_per_step * steps * block_g) f32 lanes
    qi_ref,  # (QI_ROWS, tiles_per_step * steps * block_g) int8 lanes
    qdc_ref,  # (QDC_ROWS, tiles_per_step * steps * block_g) fp16 DC lanes
    cam_ref,  # (1, CAM_VEC_LEN) packed camera constants
    bg_ref,  # (1, 4) background rgb + pad
    out_ref,  # (tiles_per_step * TILE_PIX, 4) rgb + final transmittance
    *maybe_stats_ref,  # (tiles_per_step, STAT_COLS) when collect_stats
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
    tiles_per_step: int,
    collect_stats: bool = False,
):
    """Decode-in-kernel fused raster: quantized chunks dequantize to f32
    lanes in registers right before the (unchanged) staged feature math.

    The streamed operands are the compressed lanes (~83 bytes/Gaussian vs
    236 raw) — the VMEM block fetch, which grid pipelining overlaps with
    the previous supertile's compute, moves ~2.8x fewer bytes per chunk.
    """
    qf_all = qf_ref[...]
    qi_all = qi_ref[...]
    qdc_all = qdc_ref[...]
    cam = cam_ref[...]

    def chunk_features(t, tt, j):
        col0 = (tt * steps + j) * block_g

        def sl(x, rows):
            return jax.lax.dynamic_slice(x, (0, col0), (rows, block_g))

        band = band_ref[t, j] if banded else None
        return lane_features_q(
            sl(qf_all, QF_ROWS),
            sl(qi_all, QI_ROWS),
            sl(qdc_all, QDC_ROWS),
            cam,
            sh_degree=sh_degree,
            band=band,
        )

    if collect_stats:
        chunk_band = (
            (lambda t, j: band_ref[t, j])
            if banded
            else (lambda t, j: jnp.int32(sh_degree))
        )
        _stream_supertile_stats(
            nsteps_ref,
            pix_ref[...],
            bg_ref[0, 0:3],
            out_ref,
            maybe_stats_ref[0],
            chunk_features,
            chunk_band,
            early_exit=early_exit,
            tiles_per_step=tiles_per_step,
        )
        return
    _stream_supertile(
        nsteps_ref,
        pix_ref[...],
        bg_ref[0, 0:3],
        out_ref,
        chunk_features,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
    )


def build_fused_pallas_call(
    num_tiles: int,
    steps: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    sh_degree: int = 3,
    banded: bool = False,
    early_exit: bool = True,
    tiles_per_step: int = 1,
    interpret: bool = False,
    dtype=jnp.float32,
    collect_stats: bool = False,
):
    """Fused raw->feature->blend call over the compacted raw-record layout.

    Operands: scalar-prefetched per-tile chunk counts and per-chunk SH
    bands, then (pix, raw_compact, camera, background). Each grid step owns
    a *supertile* of ``tiles_per_step`` consecutive screen tiles: their
    (RAW_ROWS, tiles_per_step * steps * block_g) compact raw block is one
    BlockSpec block — the grid pipeline prefetches the next supertile's
    block while this one streams its chunks through the in-kernel loops —
    and an inner ``fori_loop`` walks the supertile's tiles, each with its
    own early-exiting chunk ``while_loop``. The supertile width amortizes
    per-grid-step overhead (dominant in interpret mode) without changing
    per-tile semantics; ``num_tiles`` must divide evenly.

    ``collect_stats=True`` adds a second output: the per-tile
    (num_tiles, :data:`STAT_COLS`) diagnostics plane written by
    ``_stream_supertile_stats`` — the image output is bitwise-unchanged.
    """
    if num_tiles % tiles_per_step != 0:
        raise ValueError(
            f"tiles_per_step={tiles_per_step} must divide num_tiles={num_tiles}"
        )
    grid = (num_tiles // tiles_per_step,)
    out_spec = pl.BlockSpec(
        (tiles_per_step * TILE_PIX, 4), lambda t, ns, bd: (t, 0)
    )
    out_shape = jax.ShapeDtypeStruct((num_tiles * TILE_PIX, 4), dtype)
    if collect_stats:
        out_spec = (
            out_spec,
            pl.BlockSpec((tiles_per_step, STAT_COLS), lambda t, ns, bd: (t, 0)),
        )
        out_shape = (
            out_shape,
            jax.ShapeDtypeStruct((num_tiles, STAT_COLS), jnp.float32),
        )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (tiles_per_step * TILE_PIX, 2), lambda t, ns, bd: (t, 0)
            ),
            pl.BlockSpec(
                (RAW_ROWS, tiles_per_step * steps * block_g),
                lambda t, ns, bd: (0, t),
            ),
            pl.BlockSpec((1, CAM_VEC_LEN), lambda t, ns, bd: (0, 0)),
            pl.BlockSpec((1, 4), lambda t, ns, bd: (0, 0)),
        ],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(
            _fused_raster_kernel,
            steps=steps,
            block_g=block_g,
            sh_degree=sh_degree,
            banded=banded,
            early_exit=early_exit,
            tiles_per_step=tiles_per_step,
            collect_stats=collect_stats,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )


def build_fused_q_pallas_call(
    num_tiles: int,
    steps: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    sh_degree: int = 3,
    banded: bool = False,
    early_exit: bool = True,
    tiles_per_step: int = 1,
    interpret: bool = False,
    dtype=jnp.float32,
    collect_stats: bool = False,
):
    """Quantized twin of :func:`build_fused_pallas_call`.

    Identical grid/prefetch structure; the single raw-record operand is
    replaced by the three quantized planes (qf f32 / qi int8 / qdc fp16 —
    see ``pack_quant_rows``), each blocked per supertile exactly like the
    raw block, so grid pipelining prefetches the compressed stream instead
    of the 59-row f32 one.
    """
    if num_tiles % tiles_per_step != 0:
        raise ValueError(
            f"tiles_per_step={tiles_per_step} must divide num_tiles={num_tiles}"
        )
    grid = (num_tiles // tiles_per_step,)
    lanes = tiles_per_step * steps * block_g
    out_spec = pl.BlockSpec(
        (tiles_per_step * TILE_PIX, 4), lambda t, ns, bd: (t, 0)
    )
    out_shape = jax.ShapeDtypeStruct((num_tiles * TILE_PIX, 4), dtype)
    if collect_stats:
        out_spec = (
            out_spec,
            pl.BlockSpec((tiles_per_step, STAT_COLS), lambda t, ns, bd: (t, 0)),
        )
        out_shape = (
            out_shape,
            jax.ShapeDtypeStruct((num_tiles, STAT_COLS), jnp.float32),
        )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (tiles_per_step * TILE_PIX, 2), lambda t, ns, bd: (t, 0)
            ),
            pl.BlockSpec((QF_ROWS, lanes), lambda t, ns, bd: (0, t)),
            pl.BlockSpec((QI_ROWS, lanes), lambda t, ns, bd: (0, t)),
            pl.BlockSpec((QDC_ROWS, lanes), lambda t, ns, bd: (0, t)),
            pl.BlockSpec((1, CAM_VEC_LEN), lambda t, ns, bd: (0, 0)),
            pl.BlockSpec((1, 4), lambda t, ns, bd: (0, 0)),
        ],
        out_specs=out_spec,
    )
    return pl.pallas_call(
        functools.partial(
            _fused_raster_kernel_q,
            steps=steps,
            block_g=block_g,
            sh_degree=sh_degree,
            banded=banded,
            early_exit=early_exit,
            tiles_per_step=tiles_per_step,
            collect_stats=collect_stats,
        ),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )


def _fused_bwd_kernel(
    nsteps_ref,  # (num_tiles,) int32 scalar-prefetch live-chunk counts
    pix_ref,  # (tiles_per_step * TILE_PIX, 2)
    feat_ref,  # (FEAT_ROWS, tiles_per_step * steps * block_g) features
    out_ref,  # (tiles_per_step * TILE_PIX, 4) forward rgb + transmittance
    gout_ref,  # (tiles_per_step * TILE_PIX, 4) output cotangent
    dfeat_ref,  # (FEAT_ROWS, tiles_per_step * steps * block_g) gradients
    *,
    steps: int,
    block_g: int,
    early_exit: bool,
    tiles_per_step: int,
):
    """Backward blend with forward-identical early-exit replay.

    Same ``d_alpha_i = T_i (c_i . d_rgb) - (D - S_i)/(1 - a_i) - d_tout
    T_N/(1 - a_i)`` front-sum trick as ``tile_rasterize._compact_bwd_kernel``
    (the alpha model is shared via ``_lane_alpha``), restructured as the
    forward's supertile fori_loop over in-kernel chunk loops, each chunk
    loop's condition replaying the forward saturation gate: the replayed
    transmittance evolves bitwise-identically to the forward pass (alphas
    don't depend on color), so chunks the forward skipped contribute
    exactly zero gradient — the VJP differentiates the function the kernel
    actually computed, early exit included.
    """
    g0 = pl.program_id(0)
    feat_all = feat_ref[...]
    pix_all = pix_ref[...]
    out_all = out_ref[...]
    gout_all = gout_ref[...]

    def tile_body(tt, dfeat_acc):
        t = g0 * tiles_per_step + tt
        n = nsteps_ref[t]
        pix = jax.lax.dynamic_slice(
            pix_all, (tt * TILE_PIX, 0), (TILE_PIX, 2)
        )
        out = jax.lax.dynamic_slice(
            out_all, (tt * TILE_PIX, 0), (TILE_PIX, 4)
        )
        gout = jax.lax.dynamic_slice(
            gout_all, (tt * TILE_PIX, 0), (TILE_PIX, 4)
        )
        drgb = gout[:, 0:3]  # (TP, 3)
        dtout = gout[:, 3:4]  # (TP, 1)
        d_total = jnp.sum(out[:, 0:3] * drgb, axis=1, keepdims=True)
        t_n = out[:, 3:4]

        def cond(carry):
            j, t_pix, _, _ = carry
            live = j < n
            if early_exit:
                live = live & (jnp.max(t_pix) >= EARLY_EXIT_EPS)
            return live

        def body(carry):
            j, t_pix, cum_s, dfeat = carry
            feat = jax.lax.dynamic_slice(
                feat_all,
                (0, (tt * steps + j) * block_g),
                (FEAT_ROWS, block_g),
            )
            colors = feat[5:8, :]

            la = _lane_alpha(pix, feat)
            dx, dy = la.dx, la.dy
            alpha = la.alpha

            one_minus = 1.0 - alpha
            cum = jnp.cumprod(one_minus, axis=1)
            excl = jnp.concatenate(
                [jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1
            )
            t_i = t_pix * excl
            w = alpha * t_i

            s = jax.lax.dot_general(
                drgb, colors, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (TP, BG)
            cums = cum_s + jnp.cumsum(w * s, axis=1)
            dalpha = (
                t_i * s
                - (d_total - cums) / one_minus
                - dtout * t_n / one_minus
            )

            d_araw = jnp.where(
                la.gate & (la.alpha_raw < ALPHA_MAX), dalpha, 0.0
            )
            dopac = d_araw * la.expw * la.mask
            dmask = d_araw * la.opac * la.expw
            dpower = d_araw * la.alpha_raw
            dpraw = jnp.where(la.power_raw < 0.0, dpower, 0.0)
            ddx = dpraw * -(la.con_a * dx + la.con_b * dy)
            ddy = dpraw * -(la.con_c * dy + la.con_b * dx)

            def rsum(x):
                return jnp.sum(x, axis=0, keepdims=True)

            zero = jnp.zeros_like(la.opac)
            dblock = jnp.concatenate(
                [
                    rsum(-ddx),  # du (dx = px - u)
                    rsum(-ddy),
                    rsum(dpraw * (-0.5 * dx * dx)),  # dconic a
                    rsum(dpraw * (-dx * dy)),
                    rsum(dpraw * (-0.5 * dy * dy)),
                    rsum(w * drgb[:, 0:1]),  # dcolor
                    rsum(w * drgb[:, 1:2]),
                    rsum(w * drgb[:, 2:3]),
                    zero,  # depth: sort key only
                    zero,  # radius: discrete gate
                    rsum(dopac),
                    rsum(dmask),
                ],
                axis=0,
            )  # (FEAT_ROWS, BG)
            dfeat = jax.lax.dynamic_update_slice(
                dfeat, dblock, (0, (tt * steps + j) * block_g)
            )
            return j + jnp.int32(1), t_pix * cum[:, -1:], cums[:, -1:], dfeat

        t0 = jnp.ones((TILE_PIX, 1), jnp.float32)
        c0 = jnp.zeros((TILE_PIX, 1), jnp.float32)
        _, _, _, dfeat_acc = jax.lax.while_loop(
            cond, body, (jnp.int32(0), t0, c0, dfeat_acc)
        )
        return dfeat_acc

    df0 = jnp.zeros(
        (FEAT_ROWS, tiles_per_step * steps * block_g), jnp.float32
    )
    dfeat = jax.lax.fori_loop(0, tiles_per_step, tile_body, df0)
    dfeat_ref[...] = dfeat.astype(dfeat_ref.dtype)


def build_fused_bwd_pallas_call(
    num_tiles: int,
    steps: int,
    *,
    block_g: int = DEFAULT_BLOCK_G,
    early_exit: bool = True,
    tiles_per_step: int = 1,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Backward over the compacted layout: per-tile feature-gradient blocks."""
    if num_tiles % tiles_per_step != 0:
        raise ValueError(
            f"tiles_per_step={tiles_per_step} must divide num_tiles={num_tiles}"
        )
    grid = (num_tiles // tiles_per_step,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tiles_per_step * TILE_PIX, 2), lambda t, ns: (t, 0)),
            pl.BlockSpec(
                (FEAT_ROWS, tiles_per_step * steps * block_g),
                lambda t, ns: (0, t),
            ),
            pl.BlockSpec((tiles_per_step * TILE_PIX, 4), lambda t, ns: (t, 0)),
            pl.BlockSpec((tiles_per_step * TILE_PIX, 4), lambda t, ns: (t, 0)),
        ],
        out_specs=pl.BlockSpec(
            (FEAT_ROWS, tiles_per_step * steps * block_g),
            lambda t, ns: (0, t),
        ),
    )
    return pl.pallas_call(
        functools.partial(
            _fused_bwd_kernel,
            steps=steps,
            block_g=block_g,
            early_exit=early_exit,
            tiles_per_step=tiles_per_step,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (FEAT_ROWS, num_tiles * steps * block_g), dtype
        ),
        interpret=interpret,
    )
