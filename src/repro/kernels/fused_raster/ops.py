"""Jitted public wrapper for the fused streaming raster pipeline.

``fused_render`` goes straight from raw ``GaussianParams`` + camera to an
image: a cheap geometry-only pre-pass (no SH — the FLOP-dominant stage stays
in the kernel) supplies the depth sort and tile binning, the sorted *raw
records* are gathered to compacted per-tile chunk lists, and the fused
Pallas kernel streams them through feature computation into blending with
in-kernel early exit.

The pre-pass geometry intentionally reuses ``compute_features_staged``
(degree 0 — SH degree only affects color, geometry is bitwise-identical to
any degree): the resulting sort permutation and tile lists are exactly the
ones the unfused ``pallas_binned`` path builds, so the two paths blend the
same Gaussians in the same order and differ only by the in-kernel feature
arithmetic (~1e-7) and, when enabled, the bounded early-exit drop.

Differentiability: the raw-record gather is plain jnp (its VJP scatter-adds
per-tile gradients back per Gaussian), the camera operand flows through the
differentiable ``pack_camera``, and ``_fused_blend`` carries a
``jax.custom_vjp`` — backward recomputes the compacted features from the
residual raw records via ``kernel.lane_features`` under ``jax.vjp``
(bitwise-identical to the forward's in-kernel evaluation), runs the
backward Pallas kernel for per-lane feature cotangents (early-exit replay
included), and chains them back to raw records + camera.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binning as bin_lib
from repro.core import features as feat_lib
from repro.core import quant
from repro.core.camera import Camera
from repro.core.gaussians import (
    GAUSSIAN_RECORD_FLOATS,
    GaussianParams,
    pack_records,
)
from repro.kernels.fused_raster import kernel as k
from repro.kernels.gaussian_features.ops import pack_camera
from repro.kernels.tile_rasterize.ops import _default_interpret, _tile_order_pixels

assert k.RAW_ROWS == GAUSSIAN_RECORD_FLOATS


DEFAULT_TILES_PER_STEP = 16


def pick_tiles_per_step(num_tiles: int, target: int = DEFAULT_TILES_PER_STEP) -> int:
    """Largest divisor of ``num_tiles`` <= ``target`` (supertile width).

    Wider supertiles amortize per-grid-step overhead (the dominant cost in
    interpret mode) across more tiles; the divisor constraint keeps the
    BlockSpec partition exact.
    """
    for d in range(min(target, num_tiles), 0, -1):
        if num_tiles % d == 0:
            return d
    return 1


def _sentinel_column(dtype) -> jax.Array:
    """One raw record no blend path can see (the sentinel gather target).

    Mirrors ``scene._append_invisible`` / ``pad_to_multiple``: identity-ish
    quaternion, tiny scales, and opacity logit -30 (sigmoid ~1e-13, far
    below the 1/255 alpha floor — the in-kernel mask zeroes the lane).
    """
    col = jnp.zeros((k.RAW_ROWS, 1), dtype)
    col = col.at[3, 0].set(1.0)  # quat w
    col = col.at[7:10, 0].set(-10.0)  # log scales
    col = col.at[58, 0].set(-30.0)  # opacity logit
    return col


def _compact_indices(bins, num_g: int, block_g: int):
    """Flattened per-tile gather indices (sentinel ``num_g``), chunk counts.

    Returns ``(idx (T * steps * block_g,), nsteps (T,) float32, steps)`` —
    the tile lists padded to a whole number of ``block_g`` chunks. Shared by
    the raw-record and quantized compactions so both ship identical lane
    orderings to their kernels.
    """
    kk = bins.capacity
    k_pad = max(block_g, -(-kk // block_g) * block_g)
    idx = jnp.pad(
        bins.indices, ((0, 0), (0, k_pad - kk)), constant_values=jnp.int32(num_g)
    ).reshape(-1)
    nsteps = (
        (bins.count + jnp.int32(block_g - 1)) // jnp.int32(block_g)
    ).astype(jnp.float32)
    return idx, nsteps, k_pad // block_g


def _chunk_bands(
    band_sorted: jax.Array | None, idx: jax.Array, bins, steps: int, block_g: int
) -> jax.Array:
    """Per-(tile, chunk) SH band = max LOD degree of the chunk's live lanes."""
    if band_sorted is None:
        return jnp.zeros((bins.num_tiles, steps), jnp.float32)
    band_pad = jnp.concatenate(
        [band_sorted.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
    )
    lane_band = band_pad[idx].reshape(bins.num_tiles, steps, block_g)
    return jnp.max(lane_band, axis=-1).astype(jnp.float32)


def compact_fused_operands(
    raw_sorted: jax.Array,
    bins,
    *,
    band_sorted: jax.Array | None = None,
    block_g: int = k.DEFAULT_BLOCK_G,
):
    """Gather depth-sorted raw records into per-tile chunk lists.

    Args:
      raw_sorted: (RAW_ROWS, N) depth-sorted raw records (lane-major — a
        ``pack_records(g)[order].T``). The gather is differentiable: its VJP
        scatter-adds per-tile lane cotangents back onto the records.
      bins: :class:`repro.core.binning.TileBins` built from the same depth
        order (ascending sorted indices, sentinel ``N``).
      band_sorted: optional (N,) int32 per-Gaussian SH LOD degree in the
        same order.

    Returns ``(raw_compact (RAW_ROWS, T * steps * block_g), nsteps (T,)
    float32, chunk_band (T, steps) float32, steps)``. ``chunk_band`` is the
    band-bucketed compaction: each chunk's SH band is the max LOD degree of
    its live lanes (depth order is preserved — distance LOD is
    depth-coherent, so chunks stay band-homogeneous without reordering).
    """
    num_g = raw_sorted.shape[1]
    idx, nsteps, steps = _compact_indices(bins, num_g, block_g)

    raw_pad = jnp.concatenate(
        [raw_sorted, _sentinel_column(raw_sorted.dtype)], axis=1
    )
    raw_compact = raw_pad[:, idx]  # (RAW_ROWS, T * k_pad)
    chunk_band = _chunk_bands(band_sorted, idx, bins, steps, block_g)
    return raw_compact, nsteps, chunk_band, steps


def pack_quant_rows(
    qg: quant.QuantizedGaussianParams,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantized cloud -> kernel operand planes (qf, qi, qdc), lane-major.

    ``qf`` (QF_ROWS, N) f32 carries positions, quats, and the per-chunk
    decode scales broadcast per lane (so a compacted chunk decodes from its
    own scale rows even after the culled/tile gather reshuffles chunks);
    ``qi`` (QI_ROWS, N) int8 is log-scales + opacity + SH bands 1-3 in raw
    row order; ``qdc`` (QDC_ROWS, N) fp16 is the DC band. Row layout
    documented at ``kernel.QF_ROWS``.
    """
    n = qg.num_gaussians
    lane = jnp.repeat(
        qg.scales, qg.chunk_size, axis=0, total_repeat_length=n
    )  # (N, 5)
    qf = jnp.concatenate(
        [qg.positions, qg.quats, lane], axis=1
    ).T.astype(jnp.float32)
    qi = jnp.concatenate(
        [
            qg.log_scales_q,
            qg.opacity_q[:, None],
            qg.sh_rest_q.reshape(n, 45),  # basis-major x 3ch = raw 13:58
        ],
        axis=1,
    ).T
    qdc = qg.sh_dc.T
    return qf, qi, qdc


def _sentinel_columns_q() -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantized sentinel lane decoding to the invisible raw sentinel.

    Codes are -127 with scale rows 10/127 and 30/127, so the decode lands
    on (~-10 log scales, ~-30 opacity logit) — sigmoid ~1e-13, below the
    alpha floor, lane masked out exactly like :func:`_sentinel_column`.
    """
    qf = jnp.zeros((k.QF_ROWS, 1), jnp.float32)
    qf = qf.at[3, 0].set(1.0)  # quat w
    qf = qf.at[7, 0].set(10.0 / 127.0)  # log-scales decode scale
    qf = qf.at[8, 0].set(30.0 / 127.0)  # opacity decode scale
    qf = qf.at[9:12, 0].set(1.0)  # SH band scales (codes are 0)
    qi = jnp.zeros((k.QI_ROWS, 1), jnp.int8)
    qi = qi.at[0:4, 0].set(-127)  # log scales + opacity logit
    qdc = jnp.zeros((k.QDC_ROWS, 1), jnp.float16)
    return qf, qi, qdc


def compact_fused_operands_q(
    qf_sorted: jax.Array,
    qi_sorted: jax.Array,
    qdc_sorted: jax.Array,
    bins,
    *,
    band_sorted: jax.Array | None = None,
    block_g: int = k.DEFAULT_BLOCK_G,
):
    """Quantized twin of :func:`compact_fused_operands` (same lane order).

    Gathers the three quantized planes through the identical flattened tile
    index list; only the f32/fp16 planes' gathers are differentiable (the
    int8 plane is data, not a tangent carrier).

    Under banding the compacted int8 SH codes are zeroed above each *lane's*
    band: quantized storage keeps full-degree coefficients (band is a
    per-camera distance LOD, not a property of the resident scene), but a
    mixed-band chunk decodes at its max band — without the zeroing, a
    low-band lane's above-band coefficients would leak into the color where
    the f32 path's ``apply_sh_lod`` pre-zeroed them. Zero codes decode to
    exact zeros, so the kernel's chunk-band decode reproduces the pre-zeroed
    f32 path bitwise, and the backward's full-degree decode of the same
    (zeroed) codes replays the forward features without any band mask.

    Returns ``((qf_c, qi_c, qdc_c), nsteps, chunk_band, steps)``.
    """
    num_g = qf_sorted.shape[1]
    idx, nsteps, steps = _compact_indices(bins, num_g, block_g)
    sf, si, sdc = _sentinel_columns_q()
    qf_c = jnp.concatenate([qf_sorted, sf], axis=1)[:, idx]
    qi_c = jnp.concatenate([qi_sorted, si], axis=1)[:, idx]
    qdc_c = jnp.concatenate([qdc_sorted, sdc], axis=1)[:, idx]
    chunk_band = _chunk_bands(band_sorted, idx, bins, steps, block_g)
    if band_sorted is not None:
        band_pad = jnp.concatenate(
            [band_sorted.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
        )
        lane_band = band_pad[idx]  # (T * steps * block_g,)
        row_band = np.zeros((k.QI_ROWS,), np.int32)  # min band per qi row
        for b, (_, (qlo, qhi), _) in enumerate(k._QBANDS, start=1):
            row_band[qlo:qhi] = b
        keep = jnp.asarray(row_band)[:, None] <= lane_band[None, :]
        qi_c = jnp.where(keep, qi_c, jnp.int8(0))
    return (qf_c, qi_c, qdc_c), nsteps, chunk_band, steps


def build_fused_operands(
    g: GaussianParams,
    cam: Camera,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
):
    """Sort + bin on pre-pass geometry, compact the *raw records* per tile.

    Returns ``(raw_compact (RAW_ROWS, T * steps * block_g), nsteps (T,)
    float32, chunk_band (T, steps) float32, bins, steps)``; see
    :func:`compact_fused_operands` for the compaction contract.
    """
    height, width = cam.height, cam.width

    # Geometry-only pre-pass (discrete outputs: sort order + tile lists).
    geo = jax.tree.map(
        jax.lax.stop_gradient,
        feat_lib.compute_features_staged(g, cam, sh_degree=0),
    )
    key = jnp.where(geo.mask > 0.5, geo.depth, jnp.inf)
    order = jnp.argsort(key)
    geo_sorted = jax.tree.map(lambda x: x[order], geo)
    bins = bin_lib.bin_gaussians(
        geo_sorted,
        height,
        width,
        tile_size=tile_size,
        capacity=capacity,
        tile_chunk=tile_chunk,
    )

    # Depth-sorted raw records (differentiable gather), sentinel appended.
    raw_sorted = pack_records(g)[order].T  # (RAW_ROWS, N)
    band_sorted = None if band is None else band[order]
    raw_compact, nsteps, chunk_band, steps = compact_fused_operands(
        raw_sorted, bins, band_sorted=band_sorted, block_g=block_g
    )
    return raw_compact, nsteps, chunk_band, bins, steps


def build_fused_operands_q(
    qg: quant.QuantizedGaussianParams,
    cam: Camera,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
):
    """Quantized twin of :func:`build_fused_operands`.

    Geometry-only pre-pass on the decoded fields (zero SH — degree-0
    geometry never reads it; decode is the same elementwise ``q * scale``
    the kernel performs, so sort order and tile lists match the f32 path on
    the dequantized cloud exactly). Discrete outputs only, hence
    stop_gradient. Returns ``((qf_c, qi_c, qdc_c), nsteps, chunk_band,
    bins, steps)``.
    """
    log_scales, opacity = quant.dequantize_geometry(qg)
    n = qg.num_gaussians
    g_geo = GaussianParams(
        positions=qg.positions,
        quats=qg.quats,
        log_scales=log_scales,
        sh=jnp.zeros((n, 16, 3), jnp.float32),
        opacity_logit=opacity,
    )
    geo = jax.tree.map(
        jax.lax.stop_gradient,
        feat_lib.compute_features_staged(g_geo, cam, sh_degree=0),
    )
    key = jnp.where(geo.mask > 0.5, geo.depth, jnp.inf)
    order = jnp.argsort(key)
    geo_sorted = jax.tree.map(lambda x: x[order], geo)
    bins = bin_lib.bin_gaussians(
        geo_sorted,
        cam.height,
        cam.width,
        tile_size=tile_size,
        capacity=capacity,
        tile_chunk=tile_chunk,
    )

    qf, qi, qdc = pack_quant_rows(qg)
    band_sorted = None if band is None else band[order]
    planes, nsteps, chunk_band, steps = compact_fused_operands_q(
        qf[:, order],
        qi[:, order],
        qdc[:, order],
        bins,
        band_sorted=band_sorted,
        block_g=block_g,
    )
    return planes, nsteps, chunk_band, bins, steps


def _untile_image(out: jax.Array, bins, tile_size: int, cam: Camera) -> jax.Array:
    """(T * TILE_PIX, 4) kernel output -> (H, W, 3) cropped image."""
    tiles_y, tiles_x = bins.tiles_y, bins.tiles_x
    h_pad, w_pad = tiles_y * tile_size, tiles_x * tile_size
    img = out[:, 0:3].reshape(tiles_y, tiles_x, tile_size, tile_size, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(h_pad, w_pad, 3)
    return img[: cam.height, : cam.width]


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12, 13))
def _fused_blend(
    raw_compact: jax.Array,  # (RAW_ROWS, T * steps * block_g)
    cam_vec: jax.Array,  # (1, CAM_VEC_LEN)
    pix: jax.Array,  # (T * TILE_PIX, 2) screen-tile-major pixel centers
    bg4: jax.Array,  # (1, 4)
    nsteps: jax.Array,  # (T,) float32 per-tile live-chunk counts
    chunk_band: jax.Array,  # (T, steps) float32 per-chunk SH bands
    num_tiles: int,
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
    tiles_per_step: int,
    interpret: bool,
) -> jax.Array:
    """Fused Pallas blend -> (T * TILE_PIX, 4) rgb + final transmittance.

    ``nsteps``/``chunk_band`` travel as float32 so the custom VJP can hand
    back ordinary zero cotangents (cast to int32 for the scalar prefetch).
    """
    call = k.build_fused_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        sh_degree=sh_degree,
        banded=banded,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=raw_compact.dtype,
    )
    return call(
        nsteps.astype(jnp.int32),
        chunk_band.astype(jnp.int32),
        pix,
        raw_compact,
        cam_vec,
        bg4,
    )


def _fused_blend_fwd(
    raw_compact, cam_vec, pix, bg4, nsteps, chunk_band,
    num_tiles, steps, block_g, sh_degree, banded, early_exit,
    tiles_per_step, interpret,
):
    out = _fused_blend(
        raw_compact, cam_vec, pix, bg4, nsteps, chunk_band,
        num_tiles, steps, block_g, sh_degree, banded, early_exit,
        tiles_per_step, interpret,
    )
    return out, (raw_compact, cam_vec, pix, nsteps, out)


def _fused_blend_bwd(
    num_tiles, steps, block_g, sh_degree, banded, early_exit,
    tiles_per_step, interpret,
    res, gout,
):
    raw_compact, cam_vec, pix, nsteps, out = res

    # Replay the per-chunk feature computation at the full static degree
    # (exact under banding: above-band coefficients are zero, and
    # apply_sh_lod's own VJP masks their gradients upstream). Elementwise
    # per lane, so alphas/gates match the forward kernel bitwise — the
    # backward kernel's transmittance replay (and early-exit gate) walks
    # the exact forward trajectory.
    def feat_fn(raw, cam):
        return k.lane_features(raw, cam, sh_degree=sh_degree)

    feats, vjp_fn = jax.vjp(feat_fn, raw_compact, cam_vec)
    call = k.build_fused_bwd_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=feats.dtype,
    )
    dfeat = call(nsteps.astype(jnp.int32), pix, feats, out, gout)
    draw, dcam = vjp_fn(dfeat)
    # Background cotangent: rgb += T_final * bg, so d_bg = sum_p T_N * d_rgb.
    dbg = jnp.sum(out[:, 3:4] * gout[:, 0:3], axis=0)
    dbg4 = jnp.concatenate([dbg, jnp.zeros((1,), dbg.dtype)])[None, :]
    dband = jnp.zeros((num_tiles, steps), nsteps.dtype)
    return draw, dcam, jnp.zeros_like(pix), dbg4, jnp.zeros_like(nsteps), dband


_fused_blend.defvjp(_fused_blend_fwd, _fused_blend_bwd)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11, 12, 13, 14, 15)
)
def _fused_blend_q(
    qf: jax.Array,  # (QF_ROWS, T * steps * block_g) f32
    qi: jax.Array,  # (QI_ROWS, T * steps * block_g) int8
    qdc: jax.Array,  # (QDC_ROWS, T * steps * block_g) fp16
    cam_vec: jax.Array,  # (1, CAM_VEC_LEN)
    pix: jax.Array,  # (T * TILE_PIX, 2)
    bg4: jax.Array,  # (1, 4)
    nsteps: jax.Array,  # (T,) float32
    chunk_band: jax.Array,  # (T, steps) float32
    num_tiles: int,
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
    tiles_per_step: int,
    interpret: bool,
) -> jax.Array:
    """Quantized fused blend -> (T * TILE_PIX, 4) rgb + transmittance.

    Decode-then-VJP backward: gradients flow to the f32/fp16 planes (and
    through them to the resident positions/quats/DC/scales) while the int8
    plane gets a symbolic-zero (float0) cotangent — training against f32
    master weights goes through ``quant.quantize_dequantize`` instead.
    """
    call = k.build_fused_q_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        sh_degree=sh_degree,
        banded=banded,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=qf.dtype,
    )
    return call(
        nsteps.astype(jnp.int32),
        chunk_band.astype(jnp.int32),
        pix,
        qf,
        qi,
        qdc,
        cam_vec,
        bg4,
    )


def _fused_blend_q_fwd(
    qf, qi, qdc, cam_vec, pix, bg4, nsteps, chunk_band,
    num_tiles, steps, block_g, sh_degree, banded, early_exit,
    tiles_per_step, interpret,
):
    out = _fused_blend_q(
        qf, qi, qdc, cam_vec, pix, bg4, nsteps, chunk_band,
        num_tiles, steps, block_g, sh_degree, banded, early_exit,
        tiles_per_step, interpret,
    )
    return out, (qf, qi, qdc, cam_vec, pix, nsteps, chunk_band, out)


def _fused_blend_q_bwd(
    num_tiles, steps, block_g, sh_degree, banded, early_exit,
    tiles_per_step, interpret,
    res, gout,
):
    qf, qi, qdc, cam_vec, pix, nsteps, chunk_band, out = res

    # Replay decode+features at the full static degree. Exact under
    # banding: the compacted int8 codes above each lane's band were zeroed
    # at compaction, so the full-degree decode reproduces the forward
    # kernel's chunk-band decode bitwise (the extra basis terms multiply
    # exact zeros) — alphas/transmittance and the feature cotangent chain
    # walk the forward trajectory.
    def feat_fn(qf_, qdc_, cam_):
        raw = k.decode_lanes(qf_, qi, qdc_, max_band=sh_degree)
        return k.lane_features(raw, cam_, sh_degree=sh_degree)

    feats, vjp_fn = jax.vjp(feat_fn, qf, qdc, cam_vec)
    call = k.build_fused_bwd_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=feats.dtype,
    )
    dfeat = call(nsteps.astype(jnp.int32), pix, feats, out, gout)
    dqf, dqdc, dcam = vjp_fn(dfeat)
    dbg = jnp.sum(out[:, 3:4] * gout[:, 0:3], axis=0)
    dbg4 = jnp.concatenate([dbg, jnp.zeros((1,), dbg.dtype)])[None, :]
    dqi = np.zeros(qi.shape, jax.dtypes.float0)  # int8: symbolic zero
    return (
        dqf, dqi, dqdc, dcam, jnp.zeros_like(pix), dbg4,
        jnp.zeros_like(nsteps), jnp.zeros_like(chunk_band),
    )


_fused_blend_q.defvjp(_fused_blend_q_fwd, _fused_blend_q_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_size", "capacity", "block_g", "tile_chunk", "sh_degree",
        "early_exit", "tiles_per_step", "interpret",
    ),
)
def fused_render(
    g: GaussianParams,
    cam: Camera,
    background: jax.Array,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
    sh_degree: int = 3,
    early_exit: bool = True,
    tiles_per_step: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused raw-params -> image render. Returns (H, W, 3). Differentiable.

    Args:
      g: Gaussian cloud (already scene-resolved; see ``render`` for the
        SceneTree entry point).
      cam: camera (height/width are static ints on the camera).
      background: (3,) background color.
      band: optional (N,) int32 per-Gaussian SH LOD degree (from
        ``scene.resolve_scene_banded``). ``g.sh`` must already be banded by
        ``apply_sh_lod`` — the kernel then skips the above-band basis
        evaluation outright. None = full ``sh_degree`` everywhere.
      capacity: per-tile list capacity (mirrors ``tile_capacity``).
      early_exit: in-kernel transmittance-saturation exit (error bounded by
        the 1/255 blending floor; exact on fully-opaque front layers).
      tiles_per_step: supertile width (tiles per grid step); None picks the
        largest divisor of the tile count <= DEFAULT_TILES_PER_STEP.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"fused raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    bg = jnp.asarray(background, jnp.float32)
    bg4 = jnp.concatenate([bg, jnp.zeros((1,), bg.dtype)])[None, :]

    raw_compact, nsteps, chunk_band, bins, steps = build_fused_operands(
        g,
        cam,
        band=band,
        tile_size=tile_size,
        capacity=capacity,
        block_g=block_g,
        tile_chunk=tile_chunk,
    )
    cam_vec = pack_camera(cam)

    tiles_y, tiles_x = bins.tiles_y, bins.tiles_x
    h_pad, w_pad = tiles_y * tile_size, tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)
    if tiles_per_step is None:
        tiles_per_step = pick_tiles_per_step(bins.num_tiles)

    out = _fused_blend(
        raw_compact, cam_vec, pix, bg4, nsteps, chunk_band,
        bins.num_tiles, steps, block_g, sh_degree,
        band is not None, early_exit, tiles_per_step, interpret,
    )
    return _untile_image(out, bins, tile_size, cam)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_size", "capacity", "block_g", "tile_chunk", "sh_degree",
        "early_exit", "tiles_per_step", "interpret",
    ),
)
def fused_render_q(
    qg: quant.QuantizedGaussianParams,
    cam: Camera,
    background: jax.Array,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
    sh_degree: int = 3,
    early_exit: bool = True,
    tiles_per_step: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused render of a *quantized resident* cloud. Returns (H, W, 3).

    Bitwise-equal to ``fused_render(quant.dequantize_gaussians(qg), ...)``:
    the geometry pre-pass runs on the decoded geometry (decode is the same
    elementwise ``q * scale`` the kernel performs, and SH never enters
    degree-0 geometry), so sort order and tile lists match the f32 path on
    the dequantized cloud exactly; the kernel then decodes the compacted
    quantized chunks in-register before the identical feature/blend math.
    Padding lanes (``qg.num_gaussians > num_real``) decode invisible and
    sort behind every live Gaussian, leaving the tile lists unchanged.

    ``band`` is a (num_gaussians,) per-lane SH LOD degree. Unlike the f32
    path, quantized SH storage is *not* pre-zeroed above band — banding here
    gates the decode itself (above-band coefficients are neither fetched
    into f32 nor multiplied), which is the compose point with PR 5/6 LOD.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"fused raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    bg = jnp.asarray(background, jnp.float32)
    bg4 = jnp.concatenate([bg, jnp.zeros((1,), bg.dtype)])[None, :]

    (qf_c, qi_c, qdc_c), nsteps, chunk_band, bins, steps = (
        build_fused_operands_q(
            qg,
            cam,
            band=band,
            tile_size=tile_size,
            capacity=capacity,
            block_g=block_g,
            tile_chunk=tile_chunk,
        )
    )
    cam_vec = pack_camera(cam)

    h_pad, w_pad = bins.tiles_y * tile_size, bins.tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)
    if tiles_per_step is None:
        tiles_per_step = pick_tiles_per_step(bins.num_tiles)

    out = _fused_blend_q(
        qf_c, qi_c, qdc_c, cam_vec, pix, bg4, nsteps, chunk_band,
        bins.num_tiles, steps, block_g, sh_degree,
        band is not None, early_exit, tiles_per_step, interpret,
    )
    return _untile_image(out, bins, tile_size, cam)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_size", "capacity", "block_g", "tile_chunk", "sh_degree",
        "early_exit", "tiles_per_step", "interpret",
    ),
)
def fused_render_stats(
    g: GaussianParams,
    cam: Camera,
    background: jax.Array,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
    sh_degree: int = 3,
    early_exit: bool = True,
    tiles_per_step: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, dict]:
    """``fused_render`` with the in-kernel diagnostics plane.

    Returns ``(image, stats)`` where ``stats`` holds per-tile arrays:
    ``chunks_processed`` / ``lanes_blended`` / ``max_sh_band`` (the
    :data:`kernel.STAT_COLS` plane measured *inside* the streaming loop)
    plus ``chunks_assigned`` (``nsteps`` — the theoretical upper bound the
    early exit cuts below). The image is bitwise-identical to
    ``fused_render`` — identical operand prep, identical in-kernel op
    sequence (pinned by test). Inference-only: no custom VJP.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"fused raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    bg = jnp.asarray(background, jnp.float32)
    bg4 = jnp.concatenate([bg, jnp.zeros((1,), bg.dtype)])[None, :]

    raw_compact, nsteps, chunk_band, bins, steps = build_fused_operands(
        g,
        cam,
        band=band,
        tile_size=tile_size,
        capacity=capacity,
        block_g=block_g,
        tile_chunk=tile_chunk,
    )
    cam_vec = pack_camera(cam)

    h_pad, w_pad = bins.tiles_y * tile_size, bins.tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)
    if tiles_per_step is None:
        tiles_per_step = pick_tiles_per_step(bins.num_tiles)

    call = k.build_fused_pallas_call(
        bins.num_tiles,
        steps,
        block_g=block_g,
        sh_degree=sh_degree,
        banded=band is not None,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=raw_compact.dtype,
        collect_stats=True,
    )
    out, tile_stats = call(
        nsteps.astype(jnp.int32),
        chunk_band.astype(jnp.int32),
        pix,
        raw_compact,
        cam_vec,
        bg4,
    )
    stats = {
        "chunks_processed": tile_stats[:, 0],
        "lanes_blended": tile_stats[:, 1],
        "max_sh_band": tile_stats[:, 2],
        "chunks_assigned": nsteps,
    }
    return _untile_image(out, bins, tile_size, cam), stats


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_size", "capacity", "block_g", "tile_chunk", "sh_degree",
        "early_exit", "tiles_per_step", "interpret",
    ),
)
def fused_render_q_stats(
    qg: quant.QuantizedGaussianParams,
    cam: Camera,
    background: jax.Array,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
    sh_degree: int = 3,
    early_exit: bool = True,
    tiles_per_step: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, dict]:
    """``fused_render_q`` with the in-kernel diagnostics plane.

    Same ``(image, stats)`` contract as :func:`fused_render_stats`;
    the image is bitwise-identical to ``fused_render_q``.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"fused raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    bg = jnp.asarray(background, jnp.float32)
    bg4 = jnp.concatenate([bg, jnp.zeros((1,), bg.dtype)])[None, :]

    (qf_c, qi_c, qdc_c), nsteps, chunk_band, bins, steps = (
        build_fused_operands_q(
            qg,
            cam,
            band=band,
            tile_size=tile_size,
            capacity=capacity,
            block_g=block_g,
            tile_chunk=tile_chunk,
        )
    )
    cam_vec = pack_camera(cam)

    h_pad, w_pad = bins.tiles_y * tile_size, bins.tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)
    if tiles_per_step is None:
        tiles_per_step = pick_tiles_per_step(bins.num_tiles)

    call = k.build_fused_q_pallas_call(
        bins.num_tiles,
        steps,
        block_g=block_g,
        sh_degree=sh_degree,
        banded=band is not None,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=qf_c.dtype,
        collect_stats=True,
    )
    out, tile_stats = call(
        nsteps.astype(jnp.int32),
        chunk_band.astype(jnp.int32),
        pix,
        qf_c,
        qi_c,
        qdc_c,
        cam_vec,
        bg4,
    )
    stats = {
        "chunks_processed": tile_stats[:, 0],
        "lanes_blended": tile_stats[:, 1],
        "max_sh_band": tile_stats[:, 2],
        "chunks_assigned": nsteps,
    }
    return _untile_image(out, bins, tile_size, cam), stats
