"""Jitted public wrapper for the fused streaming raster pipeline.

``fused_render`` goes straight from raw ``GaussianParams`` + camera to an
image: a cheap geometry-only pre-pass (no SH — the FLOP-dominant stage stays
in the kernel) supplies the depth sort and tile binning, the sorted *raw
records* are gathered to compacted per-tile chunk lists, and the fused
Pallas kernel streams them through feature computation into blending with
in-kernel early exit.

The pre-pass geometry intentionally reuses ``compute_features_staged``
(degree 0 — SH degree only affects color, geometry is bitwise-identical to
any degree): the resulting sort permutation and tile lists are exactly the
ones the unfused ``pallas_binned`` path builds, so the two paths blend the
same Gaussians in the same order and differ only by the in-kernel feature
arithmetic (~1e-7) and, when enabled, the bounded early-exit drop.

Differentiability: the raw-record gather is plain jnp (its VJP scatter-adds
per-tile gradients back per Gaussian), the camera operand flows through the
differentiable ``pack_camera``, and ``_fused_blend`` carries a
``jax.custom_vjp`` — backward recomputes the compacted features from the
residual raw records via ``kernel.lane_features`` under ``jax.vjp``
(bitwise-identical to the forward's in-kernel evaluation), runs the
backward Pallas kernel for per-lane feature cotangents (early-exit replay
included), and chains them back to raw records + camera.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import binning as bin_lib
from repro.core import features as feat_lib
from repro.core.camera import Camera
from repro.core.gaussians import (
    GAUSSIAN_RECORD_FLOATS,
    GaussianParams,
    pack_records,
)
from repro.kernels.fused_raster import kernel as k
from repro.kernels.gaussian_features.ops import pack_camera
from repro.kernels.tile_rasterize.ops import _default_interpret, _tile_order_pixels

assert k.RAW_ROWS == GAUSSIAN_RECORD_FLOATS


DEFAULT_TILES_PER_STEP = 16


def pick_tiles_per_step(num_tiles: int, target: int = DEFAULT_TILES_PER_STEP) -> int:
    """Largest divisor of ``num_tiles`` <= ``target`` (supertile width).

    Wider supertiles amortize per-grid-step overhead (the dominant cost in
    interpret mode) across more tiles; the divisor constraint keeps the
    BlockSpec partition exact.
    """
    for d in range(min(target, num_tiles), 0, -1):
        if num_tiles % d == 0:
            return d
    return 1


def _sentinel_column(dtype) -> jax.Array:
    """One raw record no blend path can see (the sentinel gather target).

    Mirrors ``scene._append_invisible`` / ``pad_to_multiple``: identity-ish
    quaternion, tiny scales, and opacity logit -30 (sigmoid ~1e-13, far
    below the 1/255 alpha floor — the in-kernel mask zeroes the lane).
    """
    col = jnp.zeros((k.RAW_ROWS, 1), dtype)
    col = col.at[3, 0].set(1.0)  # quat w
    col = col.at[7:10, 0].set(-10.0)  # log scales
    col = col.at[58, 0].set(-30.0)  # opacity logit
    return col


def compact_fused_operands(
    raw_sorted: jax.Array,
    bins,
    *,
    band_sorted: jax.Array | None = None,
    block_g: int = k.DEFAULT_BLOCK_G,
):
    """Gather depth-sorted raw records into per-tile chunk lists.

    Args:
      raw_sorted: (RAW_ROWS, N) depth-sorted raw records (lane-major — a
        ``pack_records(g)[order].T``). The gather is differentiable: its VJP
        scatter-adds per-tile lane cotangents back onto the records.
      bins: :class:`repro.core.binning.TileBins` built from the same depth
        order (ascending sorted indices, sentinel ``N``).
      band_sorted: optional (N,) int32 per-Gaussian SH LOD degree in the
        same order.

    Returns ``(raw_compact (RAW_ROWS, T * steps * block_g), nsteps (T,)
    float32, chunk_band (T, steps) float32, steps)``. ``chunk_band`` is the
    band-bucketed compaction: each chunk's SH band is the max LOD degree of
    its live lanes (depth order is preserved — distance LOD is
    depth-coherent, so chunks stay band-homogeneous without reordering).
    """
    num_g = raw_sorted.shape[1]
    kk = bins.capacity
    k_pad = max(block_g, -(-kk // block_g) * block_g)
    idx = jnp.pad(
        bins.indices, ((0, 0), (0, k_pad - kk)), constant_values=jnp.int32(num_g)
    ).reshape(-1)

    raw_pad = jnp.concatenate(
        [raw_sorted, _sentinel_column(raw_sorted.dtype)], axis=1
    )
    raw_compact = raw_pad[:, idx]  # (RAW_ROWS, T * k_pad)

    nsteps = (
        (bins.count + jnp.int32(block_g - 1)) // jnp.int32(block_g)
    ).astype(jnp.float32)
    steps = k_pad // block_g

    if band_sorted is None:
        chunk_band = jnp.zeros((bins.num_tiles, steps), jnp.float32)
    else:
        band_pad = jnp.concatenate(
            [band_sorted.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
        )
        lane_band = band_pad[idx].reshape(bins.num_tiles, steps, block_g)
        chunk_band = jnp.max(lane_band, axis=-1).astype(jnp.float32)
    return raw_compact, nsteps, chunk_band, steps


def build_fused_operands(
    g: GaussianParams,
    cam: Camera,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
):
    """Sort + bin on pre-pass geometry, compact the *raw records* per tile.

    Returns ``(raw_compact (RAW_ROWS, T * steps * block_g), nsteps (T,)
    float32, chunk_band (T, steps) float32, bins, steps)``; see
    :func:`compact_fused_operands` for the compaction contract.
    """
    height, width = cam.height, cam.width

    # Geometry-only pre-pass (discrete outputs: sort order + tile lists).
    geo = jax.tree.map(
        jax.lax.stop_gradient,
        feat_lib.compute_features_staged(g, cam, sh_degree=0),
    )
    key = jnp.where(geo.mask > 0.5, geo.depth, jnp.inf)
    order = jnp.argsort(key)
    geo_sorted = jax.tree.map(lambda x: x[order], geo)
    bins = bin_lib.bin_gaussians(
        geo_sorted,
        height,
        width,
        tile_size=tile_size,
        capacity=capacity,
        tile_chunk=tile_chunk,
    )

    # Depth-sorted raw records (differentiable gather), sentinel appended.
    raw_sorted = pack_records(g)[order].T  # (RAW_ROWS, N)
    band_sorted = None if band is None else band[order]
    raw_compact, nsteps, chunk_band, steps = compact_fused_operands(
        raw_sorted, bins, band_sorted=band_sorted, block_g=block_g
    )
    return raw_compact, nsteps, chunk_band, bins, steps


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12, 13))
def _fused_blend(
    raw_compact: jax.Array,  # (RAW_ROWS, T * steps * block_g)
    cam_vec: jax.Array,  # (1, CAM_VEC_LEN)
    pix: jax.Array,  # (T * TILE_PIX, 2) screen-tile-major pixel centers
    bg4: jax.Array,  # (1, 4)
    nsteps: jax.Array,  # (T,) float32 per-tile live-chunk counts
    chunk_band: jax.Array,  # (T, steps) float32 per-chunk SH bands
    num_tiles: int,
    steps: int,
    block_g: int,
    sh_degree: int,
    banded: bool,
    early_exit: bool,
    tiles_per_step: int,
    interpret: bool,
) -> jax.Array:
    """Fused Pallas blend -> (T * TILE_PIX, 4) rgb + final transmittance.

    ``nsteps``/``chunk_band`` travel as float32 so the custom VJP can hand
    back ordinary zero cotangents (cast to int32 for the scalar prefetch).
    """
    call = k.build_fused_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        sh_degree=sh_degree,
        banded=banded,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=raw_compact.dtype,
    )
    return call(
        nsteps.astype(jnp.int32),
        chunk_band.astype(jnp.int32),
        pix,
        raw_compact,
        cam_vec,
        bg4,
    )


def _fused_blend_fwd(
    raw_compact, cam_vec, pix, bg4, nsteps, chunk_band,
    num_tiles, steps, block_g, sh_degree, banded, early_exit,
    tiles_per_step, interpret,
):
    out = _fused_blend(
        raw_compact, cam_vec, pix, bg4, nsteps, chunk_band,
        num_tiles, steps, block_g, sh_degree, banded, early_exit,
        tiles_per_step, interpret,
    )
    return out, (raw_compact, cam_vec, pix, nsteps, out)


def _fused_blend_bwd(
    num_tiles, steps, block_g, sh_degree, banded, early_exit,
    tiles_per_step, interpret,
    res, gout,
):
    raw_compact, cam_vec, pix, nsteps, out = res

    # Replay the per-chunk feature computation at the full static degree
    # (exact under banding: above-band coefficients are zero, and
    # apply_sh_lod's own VJP masks their gradients upstream). Elementwise
    # per lane, so alphas/gates match the forward kernel bitwise — the
    # backward kernel's transmittance replay (and early-exit gate) walks
    # the exact forward trajectory.
    def feat_fn(raw, cam):
        return k.lane_features(raw, cam, sh_degree=sh_degree)

    feats, vjp_fn = jax.vjp(feat_fn, raw_compact, cam_vec)
    call = k.build_fused_bwd_pallas_call(
        num_tiles,
        steps,
        block_g=block_g,
        early_exit=early_exit,
        tiles_per_step=tiles_per_step,
        interpret=interpret,
        dtype=feats.dtype,
    )
    dfeat = call(nsteps.astype(jnp.int32), pix, feats, out, gout)
    draw, dcam = vjp_fn(dfeat)
    # Background cotangent: rgb += T_final * bg, so d_bg = sum_p T_N * d_rgb.
    dbg = jnp.sum(out[:, 3:4] * gout[:, 0:3], axis=0)
    dbg4 = jnp.concatenate([dbg, jnp.zeros((1,), dbg.dtype)])[None, :]
    dband = jnp.zeros((num_tiles, steps), nsteps.dtype)
    return draw, dcam, jnp.zeros_like(pix), dbg4, jnp.zeros_like(nsteps), dband


_fused_blend.defvjp(_fused_blend_fwd, _fused_blend_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_size", "capacity", "block_g", "tile_chunk", "sh_degree",
        "early_exit", "tiles_per_step", "interpret",
    ),
)
def fused_render(
    g: GaussianParams,
    cam: Camera,
    background: jax.Array,
    *,
    band: jax.Array | None = None,
    tile_size: int = 16,
    capacity: int = bin_lib.DEFAULT_CAPACITY,
    block_g: int = k.DEFAULT_BLOCK_G,
    tile_chunk: int | None = 64,
    sh_degree: int = 3,
    early_exit: bool = True,
    tiles_per_step: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused raw-params -> image render. Returns (H, W, 3). Differentiable.

    Args:
      g: Gaussian cloud (already scene-resolved; see ``render`` for the
        SceneTree entry point).
      cam: camera (height/width are static ints on the camera).
      background: (3,) background color.
      band: optional (N,) int32 per-Gaussian SH LOD degree (from
        ``scene.resolve_scene_banded``). ``g.sh`` must already be banded by
        ``apply_sh_lod`` — the kernel then skips the above-band basis
        evaluation outright. None = full ``sh_degree`` everywhere.
      capacity: per-tile list capacity (mirrors ``tile_capacity``).
      early_exit: in-kernel transmittance-saturation exit (error bounded by
        the 1/255 blending floor; exact on fully-opaque front layers).
      tiles_per_step: supertile width (tiles per grid step); None picks the
        largest divisor of the tile count <= DEFAULT_TILES_PER_STEP.
    """
    if tile_size * tile_size != k.TILE_PIX:
        raise ValueError(
            f"fused raster path requires tile_size^2 == {k.TILE_PIX}, "
            f"got tile_size={tile_size}"
        )
    if interpret is None:
        interpret = _default_interpret()
    bg = jnp.asarray(background, jnp.float32)
    bg4 = jnp.concatenate([bg, jnp.zeros((1,), bg.dtype)])[None, :]

    raw_compact, nsteps, chunk_band, bins, steps = build_fused_operands(
        g,
        cam,
        band=band,
        tile_size=tile_size,
        capacity=capacity,
        block_g=block_g,
        tile_chunk=tile_chunk,
    )
    cam_vec = pack_camera(cam)

    tiles_y, tiles_x = bins.tiles_y, bins.tiles_x
    h_pad, w_pad = tiles_y * tile_size, tiles_x * tile_size
    pix = _tile_order_pixels(h_pad, w_pad, tile_size)
    if tiles_per_step is None:
        tiles_per_step = pick_tiles_per_step(bins.num_tiles)

    out = _fused_blend(
        raw_compact, cam_vec, pix, bg4, nsteps, chunk_band,
        bins.num_tiles, steps, block_g, sh_degree,
        band is not None, early_exit, tiles_per_step, interpret,
    )
    img = out[:, 0:3].reshape(tiles_y, tiles_x, tile_size, tile_size, 3)
    img = img.transpose(0, 2, 1, 3, 4).reshape(h_pad, w_pad, 3)
    return img[: cam.height, : cam.width]
