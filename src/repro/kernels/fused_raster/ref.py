"""jnp references for the fused raster path.

``lane_feature_cloud`` runs the kernel's shared raw->feature math
(``kernel.lane_features``) over a whole cloud in plain jnp — by
construction bitwise-identical to the in-kernel per-chunk evaluation, so
``fused_reference`` (dense-oracle blending of those features) anchors the
fused kernel tightly (~1e-6), while comparisons against the staged feature
paths absorb only ordinary float reassociation noise.
"""

from __future__ import annotations

import jax

from repro.core.camera import Camera
from repro.core.features import GaussianFeatures
from repro.core.gaussians import GaussianParams, pack_records
from repro.core.rasterize import rasterize
from repro.kernels.fused_raster.kernel import lane_features
from repro.kernels.gaussian_features.ops import pack_camera
from repro.kernels.gaussian_features.ref import unpack_features


def lane_feature_cloud(
    g: GaussianParams, cam: Camera, *, sh_degree: int = 3
) -> GaussianFeatures:
    """Whole-cloud features via the fused kernel's lane math."""
    raw = pack_records(g).T  # (RAW_ROWS, N)
    packed = lane_features(raw, pack_camera(cam), sh_degree=sh_degree)
    return unpack_features(packed)


def fused_reference(
    g: GaussianParams,
    cam: Camera,
    background,
    *,
    sh_degree: int = 3,
    pixel_chunk: int | None = 4096,
) -> jax.Array:
    """Dense-oracle blend of the lane-math features — the fused path's anchor."""
    feats = lane_feature_cloud(g, cam, sh_degree=sh_degree)
    return rasterize(
        feats,
        cam.height,
        cam.width,
        background=background,
        pixel_chunk=pixel_chunk,
    )
