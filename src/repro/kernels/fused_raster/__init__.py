"""Fused streaming raster pipeline: raw params -> features -> blend in one
Pallas kernel, with chunk streaming, in-kernel early exit, and banded SH."""

from repro.kernels.fused_raster.kernel import (
    DEFAULT_BLOCK_G,
    QDC_ROWS,
    QF_ROWS,
    QI_ROWS,
    RAW_ROWS,
    STAT_COLS,
    build_fused_bwd_pallas_call,
    build_fused_pallas_call,
    build_fused_q_pallas_call,
    decode_lanes,
    lane_features,
    lane_features_q,
)
from repro.kernels.fused_raster.ops import (
    build_fused_operands,
    build_fused_operands_q,
    compact_fused_operands,
    compact_fused_operands_q,
    fused_render,
    fused_render_q,
    fused_render_q_stats,
    fused_render_stats,
    pack_quant_rows,
    pick_tiles_per_step,
)
from repro.kernels.fused_raster.ref import fused_reference, lane_feature_cloud

__all__ = [
    "DEFAULT_BLOCK_G",
    "QDC_ROWS",
    "QF_ROWS",
    "QI_ROWS",
    "RAW_ROWS",
    "STAT_COLS",
    "build_fused_bwd_pallas_call",
    "build_fused_pallas_call",
    "build_fused_q_pallas_call",
    "decode_lanes",
    "lane_features",
    "lane_features_q",
    "build_fused_operands",
    "build_fused_operands_q",
    "compact_fused_operands",
    "compact_fused_operands_q",
    "fused_render",
    "fused_render_q",
    "fused_render_q_stats",
    "fused_render_stats",
    "pack_quant_rows",
    "pick_tiles_per_step",
    "fused_reference",
    "lane_feature_cloud",
]
