"""Jitted public wrapper for the fused RMSNorm Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm import kernel as k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = k.DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused RMSNorm over the last dim. x: (..., D); scale: (D,)."""
    if interpret is None:
        interpret = _default_interpret()
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    call = k.build_pallas_call(
        rows + pad, d, eps=eps, block_rows=br, interpret=interpret, dtype=x.dtype
    )
    out = call(x2, scale[None, :])
    return out[:rows].reshape(shape)
