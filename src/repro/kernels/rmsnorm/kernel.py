"""Fused RMSNorm Pallas TPU kernel.

Single pass over (rows, d) blocks resident in VMEM: mean-of-squares
reduction, rsqrt, scale — the unfused XLA path reads the activation twice
(reduction + normalize). Rows = flattened (batch, seq); d = model dim on
the lane axis (multiples of 128 for all assigned archs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (R, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (out * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def build_pallas_call(
    rows: int,
    d: int,
    *,
    eps: float,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
    dtype=jnp.float32,
):
    if rows % block_rows:
        raise ValueError(f"{rows=} must divide {block_rows=}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), dtype),
        interpret=interpret,
    )
