"""Pure-jnp oracle for the fused RMSNorm kernel (shared with models.layers)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import rmsnorm as rmsnorm_ref  # canonical implementation

__all__ = ["rmsnorm_ref"]
