"""Pallas TPU kernels (each: kernel.py + ops.py wrapper + ref.py oracle).

gaussian_features — the paper's fused 7-stage feature pipeline (core contribution)
tile_rasterize   — depth-sorted alpha blending (completes the 3DGS pipeline)
flash_attention  — causal/GQA/SWA attention (LM-substrate hot-spot)
ssd_scan         — Mamba-2 SSD chunked scan
rmsnorm          — fused RMSNorm

All validated against their pure-jnp oracles with interpret=True on CPU;
compiled Mosaic on a real TPU backend.
"""
