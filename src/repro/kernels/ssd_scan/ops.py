"""Jitted public wrapper for the SSD chunked-scan Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as k


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    b: jax.Array,
    c: jax.Array,
    a: jax.Array,
    *,
    chunk: int = k.DEFAULT_CHUNK,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Shapes as in ref.py. Returns (y, final_state)."""
    if interpret is None:
        interpret = _default_interpret()
    bsz, h, t, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    if t % chunk:
        raise ValueError(f"seq {t} must divide chunk {chunk}")
    call = k.build_pallas_call(
        bsz, h, t, p, n, chunk=chunk, interpret=interpret, dtype=x.dtype
    )
    y, hfin = call(x, dt[..., None], b, c, a[:, None].astype(jnp.float32))
    return y, hfin
