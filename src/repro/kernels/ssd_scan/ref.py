"""Pure-jnp oracle for the SSD scan: the literal sequential recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    b: jnp.ndarray,
    c: jnp.ndarray,
    a: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential SSD recurrence.

    Args:
      x:  (B, H, T, P) inputs.
      dt: (B, H, T) positive step sizes (post-softplus).
      b:  (B, H, T, N) input projections.
      c:  (B, H, T, N) output projections.
      a:  (H,) negative per-head decay coefficients.

    Returns:
      y: (B, H, T, P), final state h: (B, H, N, P).

      h_t = exp(dt_t * a) * h_{t-1} + dt_t * (b_t  x_t^T)
      y_t = c_t @ h_t
    """
    bsz, h, t, p = x.shape
    n = b.shape[-1]

    def per_head(xh, dth, bh, ch, ah):
        # xh (T,P), dth (T,), bh/ch (T,N), ah scalar
        def step(hstate, inp):
            xt, dtt, bt, ct = inp
            da = jnp.exp(dtt * ah)
            hstate = da * hstate + dtt * (bt[:, None] * xt[None, :])  # (N, P)
            yt = ct @ hstate  # (P,)
            return hstate, yt

        h0 = jnp.zeros((n, p), jnp.float32)
        hfin, ys = jax.lax.scan(step, h0, (xh, dth, bh, ch))
        return ys, hfin

    f = jax.vmap(  # over batch
        jax.vmap(per_head, in_axes=(0, 0, 0, 0, 0)), in_axes=(0, 0, 0, 0, None)
    )
    y, hfin = f(
        x.astype(jnp.float32),
        dt.astype(jnp.float32),
        b.astype(jnp.float32),
        c.astype(jnp.float32),
        a.astype(jnp.float32),
    )
    return y.astype(x.dtype), hfin
