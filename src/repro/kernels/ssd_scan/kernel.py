"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

Implements the SSD block decomposition (arXiv:2405.21060): a chunk of the
linear recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T,  y_t = C_t h_t
is evaluated as a small "attention" problem (intra-chunk, MXU matmuls) plus a
rank-1-corrected carry of the inter-chunk state, which lives in VMEM scratch
across the sequentially-iterated chunk grid dimension.

Grid: (batch, heads, T / chunk). Per-step blocks:
  x (chunk, P) | dt (chunk, 1) | B (chunk, N) | C (chunk, N) | A (1, 1)
  out y (chunk, P); final state (N, P) written on the last chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128
NEG_INF = -1e30


def _ssd_kernel(
    x_ref,  # (1, 1, L, P)
    dt_ref,  # (1, 1, L, 1)
    b_ref,  # (1, 1, L, N)
    c_ref,  # (1, 1, L, N)
    a_ref,  # (1, 1) per-head log-decay coefficient (negative)
    y_ref,  # (1, 1, L, P)
    hfin_ref,  # (1, 1, N, P)
    h_scr,  # (N, P) carried state
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0, :, :].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, 0, :, :].astype(jnp.float32)  # (L, 1)
    b = b_ref[0, 0, :, :].astype(jnp.float32)  # (L, N)
    c = c_ref[0, 0, :, :].astype(jnp.float32)  # (L, N)
    a = a_ref[0, 0]  # scalar

    loga = dt * a  # (L, 1) per-step log decay (negative)
    s = jnp.cumsum(loga, axis=0)  # (L, 1) inclusive
    s_total = s[chunk - 1, 0]

    # ---- intra-chunk: masked decay "attention" --------------------------
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L): C_i . B_j
    expo = s - s.T  # (L, L): s_i - s_j
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = row >= col
    expo = jnp.where(causal, expo, NEG_INF)
    m = cb * jnp.exp(expo) * dt.T  # (L, L) * dt_j
    y_intra = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, P)

    # ---- inter-chunk: contribution of the carried state ------------------
    c_decay = c * jnp.exp(s)  # (L, N)
    y_inter = jax.lax.dot_general(
        c_decay, h_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (L, P)

    y_ref[0, 0, :, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # ---- state carry ------------------------------------------------------
    w = jnp.exp(s_total - s) * dt  # (L, 1)
    s_new = jax.lax.dot_general(
        b, x * w, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N, P)
    h_scr[...] = jnp.exp(s_total) * h_scr[...] + s_new

    @pl.when(ci == num_chunks - 1)
    def _fin():
        hfin_ref[0, 0, :, :] = h_scr[...].astype(hfin_ref.dtype)


def build_pallas_call(
    batch: int,
    heads: int,
    seq: int,
    d_head: int,
    d_state: int,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
    dtype=jnp.float32,
):
    if seq % chunk:
        raise ValueError(f"{seq=} must divide {chunk=}")
    num_chunks = seq // chunk
    grid = (batch, heads, num_chunks)

    def tspec(d):
        return pl.BlockSpec((1, 1, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0))

    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, num_chunks=num_chunks),
        grid=grid,
        in_specs=[
            tspec(d_head),  # x
            tspec(1),  # dt
            tspec(d_state),  # B
            tspec(d_state),  # C
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),  # A per head
        ],
        out_specs=[
            tspec(d_head),
            pl.BlockSpec(
                (1, 1, d_state, d_head), lambda bi, hi, ci: (bi, hi, 0, 0)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, heads, seq, d_head), dtype),
            jax.ShapeDtypeStruct((batch, heads, d_state, d_head), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_state, d_head), jnp.float32)],
        interpret=interpret,
    )
