"""Pure-jnp oracle for the fused gaussian_features kernel.

Delegates to the staged reference pipeline (`repro.core.features`) — which is
itself validated against the paper's naive path — and packs the result into
the kernel's (12, N) record layout.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import features as feat_lib
from repro.core.camera import Camera
from repro.core.features import GaussianFeatures
from repro.core.gaussians import GaussianParams


def pack_features(f: GaussianFeatures) -> jnp.ndarray:
    """GaussianFeatures -> (12, N) packed record (kernel output layout)."""
    return jnp.stack(
        [
            f.uv[:, 0],
            f.uv[:, 1],
            f.conic[:, 0],
            f.conic[:, 1],
            f.conic[:, 2],
            f.color[:, 0],
            f.color[:, 1],
            f.color[:, 2],
            f.depth,
            f.radius,
            f.opacity,
            f.mask,
        ],
        axis=0,
    )


def unpack_features(packed: jnp.ndarray) -> GaussianFeatures:
    """(12, N) packed record -> GaussianFeatures."""
    return GaussianFeatures(
        uv=packed[0:2].T,
        conic=packed[2:5].T,
        color=packed[5:8].T,
        depth=packed[8],
        radius=packed[9],
        opacity=packed[10],
        mask=packed[11],
    )


def gaussian_features_ref(
    g: GaussianParams, cam: Camera, *, sh_degree: int = 3
) -> jnp.ndarray:
    """Oracle: staged pipeline, packed to the kernel output layout."""
    feats = feat_lib.compute_features_staged(g, cam, sh_degree=sh_degree)
    return pack_features(feats)
