from repro.kernels.gaussian_features.ops import gaussian_features, gaussian_features_packed

__all__ = ["gaussian_features", "gaussian_features_packed"]
