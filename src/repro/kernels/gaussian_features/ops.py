"""Jitted public wrapper for the fused gaussian_features Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.camera import Camera
from repro.core.features import GaussianFeatures
from repro.core.gaussians import GaussianParams
from repro.kernels.gaussian_features import kernel as k
from repro.kernels.gaussian_features import ref as ref_lib


def _default_interpret() -> bool:
    # Pallas TPU kernels execute via the interpreter on CPU containers; on a
    # real TPU backend the compiled Mosaic path is used.
    return jax.default_backend() != "tpu"


def pack_camera(cam: Camera) -> jax.Array:
    """Camera -> (1, CAM_VEC_LEN) constant operand (see kernel.py layout)."""
    vals = jnp.concatenate(
        [
            cam.r_cw.reshape(-1),
            cam.t_cw.reshape(-1),
            jnp.stack(
                [
                    cam.fx,
                    cam.fy,
                    cam.cx,
                    cam.cy,
                    cam.tan_fov()[0],
                    cam.tan_fov()[1],
                    jnp.asarray(float(cam.width), cam.fx.dtype),
                    jnp.asarray(float(cam.height), cam.fx.dtype),
                ]
            ),
            cam.cam_pos,
        ]
    )
    pad = k.CAM_VEC_LEN - vals.shape[0]
    return jnp.pad(vals, (0, pad))[None, :]


@functools.partial(
    jax.jit, static_argnames=("sh_degree", "block", "interpret")
)
def gaussian_features_packed(
    g: GaussianParams,
    cam: Camera,
    *,
    sh_degree: int = 3,
    block: int = k.DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Run the fused kernel. Returns the packed (12, N) feature record.

    Pads N up to the block size (padding lanes carry opacity logit -30 and a
    degenerate geometry that fails the frustum mask) and slices back.
    """
    if interpret is None:
        interpret = _default_interpret()
    n = g.num_gaussians
    block = min(block, max(128, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    npad = n + pad

    def padit(x, fill=0.0):
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    pos = padit(g.positions).T  # (3, Np)
    quat = padit(g.quats, 1.0).T  # (4, Np)
    lsc = padit(g.log_scales, -10.0).T  # (3, Np)
    sh = padit(g.sh).reshape(npad, 48).T  # (48, Np) — (basis, channel) minor
    opa = padit(g.opacity_logit, -30.0)[None, :]  # (1, Np)
    cam_vec = pack_camera(cam)

    call = k.build_pallas_call(
        npad,
        block=block,
        sh_degree=sh_degree,
        interpret=interpret,
        dtype=pos.dtype,
    )
    packed = call(pos, quat, lsc, sh, opa, cam_vec)
    return packed[:, :n]


def gaussian_features(
    g: GaussianParams,
    cam: Camera,
    *,
    sh_degree: int = 3,
    block: int = k.DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> GaussianFeatures:
    """Kernel path returning the structured GaussianFeatures record."""
    packed = gaussian_features_packed(
        g, cam, sh_degree=sh_degree, block=block, interpret=interpret
    )
    return ref_lib.unpack_features(packed)
