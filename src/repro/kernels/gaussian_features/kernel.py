"""Fused Gaussian-feature Pallas TPU kernel — the paper's 7-stage pipeline.

Versal -> TPU mapping (DESIGN.md section 2):

* The paper assigns one pipeline *stage* per AIE tile and streams records
  between tiles (Window interface, 256 b/cycle). On TPU, VMEM locality beats
  streaming: all seven stages run fused over a block of Gaussians resident in
  VMEM, so zero inter-stage HBM/ICI traffic remains.
* The paper vectorizes *within* one Gaussian's 3-vectors (aie::mul over rows
  of R). A TPU VPU is 8x128 lanes, so we transpose the parallelism:
  **one lane = one Gaussian**. Every input is laid out SoA-transposed
  ``(attribute, N)`` and each 3x3-algebra scalar becomes an (8,128)-shaped
  elementwise op over a 1024-Gaussian block.
* The paper's Eq. 4 precompute ``K = J R_cw`` hoists the camera-only factor;
  here the camera constants live in a tiny replicated operand (the analogue
  of AIE local-memory constants) and K is formed in registers per lane.
* Symmetry tricks carry over verbatim: 6 cov3D terms, 3 cov2D terms.

Block layout (per grid step, BN = block size in Gaussians):
  inputs   pos (3, BN) | quat (4, BN) | log_scale (3, BN) | sh (48, BN)
           opacity (1, BN) | camera (1, 32) broadcast
  output   packed features (12, BN):
           [u, v, conic_a, conic_b, conic_c, r, g, b, depth, radius,
            opacity, mask]

VMEM footprint at BN=1024: inputs 59 rows x 1024 x 4 B ~= 242 KB, output
48 KB — comfortably inside one core's VMEM with double buffering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.constants import ALPHA_EPS
from repro.core.features import COV2D_BLUR, FOV_GUARD, NEAR_PLANE
from repro.core.sh import SH_C0, SH_C1, SH_C2, SH_C3

# Camera constant-vector layout (packed into a (1, 32) f32 operand).
# [0:9]  r_cw row-major, [9:12] t_cw, [12] fx, [13] fy, [14] cx, [15] cy,
# [16] tan_fovx, [17] tan_fovy, [18] width, [19] height, [20:23] cam_pos.
CAM_VEC_LEN = 32

NUM_OUT_ROWS = 12
DEFAULT_BLOCK = 1024


def _camera_scalars(cam_ref):
    cam = cam_ref[0, :]
    r = [cam[i] for i in range(9)]
    t = [cam[9], cam[10], cam[11]]
    fx, fy, cx, cy = cam[12], cam[13], cam[14], cam[15]
    tanx, tany = cam[16], cam[17]
    width, height = cam[18], cam[19]
    cpos = [cam[20], cam[21], cam[22]]
    return r, t, fx, fy, cx, cy, tanx, tany, width, height, cpos


def gaussian_features_kernel(
    pos_ref,
    quat_ref,
    lsc_ref,
    sh_ref,
    opa_ref,
    cam_ref,
    out_ref,
    *,
    sh_degree: int,
):
    (r, t, fx, fy, cx, cy, tanx, tany, width, height, cpos) = _camera_scalars(cam_ref)
    r00, r01, r02, r10, r11, r12, r20, r21, r22 = r

    px = pos_ref[0, :]
    py = pos_ref[1, :]
    pz = pos_ref[2, :]

    # ---- stage cov3D: quaternion -> R, Sigma = R diag(s^2) R^T (6 terms) ----
    qw = quat_ref[0, :]
    qx = quat_ref[1, :]
    qy = quat_ref[2, :]
    qz = quat_ref[3, :]
    qn = jax.lax.rsqrt(qw * qw + qx * qx + qy * qy + qz * qz + 1e-24)
    qw, qx, qy, qz = qw * qn, qx * qn, qy * qn, qz * qn

    g00 = 1.0 - 2.0 * (qy * qy + qz * qz)
    g01 = 2.0 * (qx * qy - qw * qz)
    g02 = 2.0 * (qx * qz + qw * qy)
    g10 = 2.0 * (qx * qy + qw * qz)
    g11 = 1.0 - 2.0 * (qx * qx + qz * qz)
    g12 = 2.0 * (qy * qz - qw * qx)
    g20 = 2.0 * (qx * qz - qw * qy)
    g21 = 2.0 * (qy * qz + qw * qx)
    g22 = 1.0 - 2.0 * (qx * qx + qy * qy)

    sx2 = jnp.exp(2.0 * lsc_ref[0, :])
    sy2 = jnp.exp(2.0 * lsc_ref[1, :])
    sz2 = jnp.exp(2.0 * lsc_ref[2, :])

    # sigma[i,j] = sum_k g[i,k] g[j,k] s2[k]  — upper triangle only.
    sxx = g00 * g00 * sx2 + g01 * g01 * sy2 + g02 * g02 * sz2
    sxy = g00 * g10 * sx2 + g01 * g11 * sy2 + g02 * g12 * sz2
    sxz = g00 * g20 * sx2 + g01 * g21 * sy2 + g02 * g22 * sz2
    syy = g10 * g10 * sx2 + g11 * g11 * sy2 + g12 * g12 * sz2
    syz = g10 * g20 * sx2 + g11 * g21 * sy2 + g12 * g22 * sz2
    szz = g20 * g20 * sx2 + g21 * g21 * sy2 + g22 * g22 * sz2

    # ---- stage projection ------------------------------------------------
    pcx = r00 * px + r01 * py + r02 * pz + t[0]
    pcy = r10 * px + r11 * py + r12 * pz + t[1]
    pcz = r20 * px + r21 * py + r22 * pz + t[2]
    safe_z = jnp.where(jnp.abs(pcz) < 1e-6, 1e-6, pcz)
    inv_z = 1.0 / safe_z
    u = fx * pcx * inv_z + cx
    v = fy * pcy * inv_z + cy

    # ---- stage Jacobian (FOV guard band) --------------------------------
    txc = jnp.clip(pcx * inv_z, -FOV_GUARD * tanx, FOV_GUARD * tanx) * safe_z
    tyc = jnp.clip(pcy * inv_z, -FOV_GUARD * tany, FOV_GUARD * tany) * safe_z
    inv_z2 = inv_z * inv_z
    j00 = fx * inv_z
    j02 = -fx * txc * inv_z2
    j11 = fy * inv_z
    j12 = -fy * tyc * inv_z2

    # ---- stage cov2D: K = J R_cw (Eq. 4), Sigma' = K Sigma K^T ----------
    k00 = j00 * r00 + j02 * r20
    k01 = j00 * r01 + j02 * r21
    k02 = j00 * r02 + j02 * r22
    k10 = j11 * r10 + j12 * r20
    k11 = j11 * r11 + j12 * r21
    k12 = j11 * r12 + j12 * r22

    # w_i = Sigma @ k_row_i (using the 6 symmetric terms).
    w0x = sxx * k00 + sxy * k01 + sxz * k02
    w0y = sxy * k00 + syy * k01 + syz * k02
    w0z = sxz * k00 + syz * k01 + szz * k02
    w1x = sxx * k10 + sxy * k11 + sxz * k12
    w1y = sxy * k10 + syy * k11 + syz * k12
    w1z = sxz * k10 + syz * k11 + szz * k12

    cov_a = k00 * w0x + k01 * w0y + k02 * w0z + COV2D_BLUR
    cov_b = k10 * w0x + k11 * w0y + k12 * w0z
    cov_c = k10 * w1x + k11 * w1y + k12 * w1z + COV2D_BLUR

    # ---- stage cov2D_inv (conic + 3-sigma radius) ------------------------
    det = cov_a * cov_c - cov_b * cov_b
    safe_det = jnp.where(det <= 0.0, 1.0, det)
    inv_det = 1.0 / safe_det
    con_a = cov_c * inv_det
    con_b = -cov_b * inv_det
    con_c = cov_a * inv_det
    mid = 0.5 * (cov_a + cov_c)
    disc = jnp.sqrt(jnp.maximum(mid * mid - det, 0.1))
    lam1 = mid + disc
    radius = jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam1, 0.0)))
    radius = jnp.where(det <= 0.0, 0.0, radius)

    # ---- stage ray_dir ----------------------------------------------------
    dx = px - cpos[0]
    dy = py - cpos[1]
    dz = pz - cpos[2]
    dn = jax.lax.rsqrt(dx * dx + dy * dy + dz * dz + 1e-24)
    dx, dy, dz = dx * dn, dy * dn, dz * dn

    # ---- stage color: SH eval (Eq. 3), coefficients laid out (16*3, BN) ---
    xx, yy, zz = dx * dx, dy * dy, dz * dz
    xy, yz, xz = dx * dy, dy * dz, dx * dz
    basis = [jnp.full_like(dx, SH_C0)]
    if sh_degree >= 1:
        basis += [-SH_C1 * dy, SH_C1 * dz, -SH_C1 * dx]
    if sh_degree >= 2:
        basis += [
            SH_C2[0] * xy,
            SH_C2[1] * yz,
            SH_C2[2] * (2.0 * zz - xx - yy),
            SH_C2[3] * xz,
            SH_C2[4] * (xx - yy),
        ]
    if sh_degree >= 3:
        basis += [
            SH_C3[0] * dy * (3.0 * xx - yy),
            SH_C3[1] * xy * dz,
            SH_C3[2] * dy * (4.0 * zz - xx - yy),
            SH_C3[3] * dz * (2.0 * zz - 3.0 * xx - 3.0 * yy),
            SH_C3[4] * dx * (4.0 * zz - xx - yy),
            SH_C3[5] * dz * (xx - yy),
            SH_C3[6] * dx * (xx - 3.0 * yy),
        ]
    col_r = jnp.zeros_like(dx)
    col_g = jnp.zeros_like(dx)
    col_b = jnp.zeros_like(dx)
    for k_idx, bas in enumerate(basis):
        col_r = col_r + sh_ref[3 * k_idx + 0, :] * bas
        col_g = col_g + sh_ref[3 * k_idx + 1, :] * bas
        col_b = col_b + sh_ref[3 * k_idx + 2, :] * bas
    col_r = jnp.maximum(col_r + 0.5, 0.0)
    col_g = jnp.maximum(col_g + 0.5, 0.0)
    col_b = jnp.maximum(col_b + 0.5, 0.0)

    # ---- finalize: opacity + in-frustum mask ------------------------------
    opacity = jax.nn.sigmoid(opa_ref[0, :])
    onscreen = (
        (u > -radius) & (u < width + radius) & (v > -radius) & (v < height + radius)
    )
    mask = (
        (pcz > NEAR_PLANE)
        & (radius > 0.0)
        & onscreen
        & (opacity >= ALPHA_EPS)
    ).astype(u.dtype)

    out_ref[0, :] = u
    out_ref[1, :] = v
    out_ref[2, :] = con_a
    out_ref[3, :] = con_b
    out_ref[4, :] = con_c
    out_ref[5, :] = col_r
    out_ref[6, :] = col_g
    out_ref[7, :] = col_b
    out_ref[8, :] = pcz
    out_ref[9, :] = radius
    out_ref[10, :] = opacity
    out_ref[11, :] = mask


def build_pallas_call(
    num_gaussians: int,
    *,
    block: int = DEFAULT_BLOCK,
    sh_degree: int = 3,
    interpret: bool = False,
    dtype=jnp.float32,
):
    """Construct the pallas_call for a padded SoA-transposed Gaussian stream."""
    if num_gaussians % block != 0:
        raise ValueError(f"{num_gaussians=} must be a multiple of {block=}")
    grid = (num_gaussians // block,)

    def attr_spec(rows):
        return pl.BlockSpec((rows, block), lambda i: (0, i))

    import functools

    return pl.pallas_call(
        functools.partial(gaussian_features_kernel, sh_degree=sh_degree),
        grid=grid,
        in_specs=[
            attr_spec(3),  # positions
            attr_spec(4),  # quaternions
            attr_spec(3),  # log scales
            attr_spec(48),  # sh coefficients
            attr_spec(1),  # opacity logits
            pl.BlockSpec((1, CAM_VEC_LEN), lambda i: (0, 0)),  # camera consts
        ],
        out_specs=attr_spec(NUM_OUT_ROWS),
        out_shape=jax.ShapeDtypeStruct((NUM_OUT_ROWS, num_gaussians), dtype),
        interpret=interpret,
    )
