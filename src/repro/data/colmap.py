"""COLMAP text-format dataset loader (tandt_db layout).

Parses the three sparse-reconstruction text files COLMAP writes next to a
real capture (``cameras.txt``, ``images.txt``, ``points3D.txt``) into the
repo's native types:

* each registered image becomes a :class:`repro.core.camera.Camera`
  (COLMAP stores the world->camera rotation as a wxyz quaternion and the
  translation with the same ``p_c = R p_w + t`` convention we use, so the
  pose maps over directly);
* the sparse point cloud seeds a :class:`GaussianParams` the standard 3DGS
  way: one Gaussian per point, DC spherical-harmonic term from the point
  color (``(rgb - 0.5) / SH_C0``, higher bands zero), isotropic scale from
  the mean distance to the 3 nearest neighbours, identity rotation, and a
  uniform starting opacity.

Only the text export is supported (``colmap model_converter
--output_type TXT``); camera models PINHOLE, SIMPLE_PINHOLE and
SIMPLE_RADIAL (distortion ignored with a warning) cover the tandt_db
scenes. A tiny fixture lives in ``tests/data/colmap/``.
"""

from __future__ import annotations

import dataclasses
import math
import pathlib
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core.camera import Camera
from repro.core.gaussians import NUM_SH_BASES, GaussianParams
from repro.core.sh import SH_C0

# Starting opacity of point-seeded Gaussians (reference 3DGS value).
INIT_OPACITY = 0.1


@dataclasses.dataclass
class ColmapScene:
    """One parsed COLMAP reconstruction.

    Attributes:
      cameras: one :class:`Camera` per registered image, ordered by
        COLMAP image id.
      image_names: the image file names, aligned with ``cameras`` (targets
        live outside the sparse model; callers that have the ``images/``
        directory can pair them up by name).
      points: (P, 3) sparse point positions.
      colors: (P, 3) float RGB in [0, 1].
      gaussians: point-seeded cloud (see :func:`gaussians_from_points`).
    """

    cameras: list[Camera]
    image_names: list[str]
    points: np.ndarray
    colors: np.ndarray
    gaussians: GaussianParams


def _data_lines(path: pathlib.Path) -> list[list[str]]:
    """Non-comment, non-empty lines of a COLMAP text file, tokenized."""
    if not path.exists():
        raise FileNotFoundError(f"COLMAP file missing: {path}")
    out = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.append(line.split())
    return out


def _intrinsics(model: str, params: list[float]) -> tuple[float, float, float, float]:
    """(fx, fy, cx, cy) from a COLMAP camera model's parameter list."""
    if model == "PINHOLE":
        fx, fy, cx, cy = params[:4]
    elif model == "SIMPLE_PINHOLE":
        f, cx, cy = params[:3]
        fx = fy = f
    elif model in ("SIMPLE_RADIAL", "RADIAL"):
        f, cx, cy = params[:3]
        fx = fy = f
        if any(abs(k) > 1e-12 for k in params[3:]):
            warnings.warn(
                f"COLMAP model {model} has nonzero distortion; the pinhole "
                "render stack ignores it",
                stacklevel=3,
            )
    else:
        raise ValueError(
            f"unsupported COLMAP camera model {model!r} (supported: "
            "PINHOLE, SIMPLE_PINHOLE, SIMPLE_RADIAL, RADIAL)"
        )
    return float(fx), float(fy), float(cx), float(cy)


def read_cameras_txt(path: pathlib.Path) -> dict[int, dict]:
    """cameras.txt -> {camera_id: {width, height, fx, fy, cx, cy}}."""
    cams = {}
    for tok in _data_lines(path):
        cam_id, model = int(tok[0]), tok[1]
        width, height = int(tok[2]), int(tok[3])
        fx, fy, cx, cy = _intrinsics(model, [float(t) for t in tok[4:]])
        cams[cam_id] = dict(
            width=width, height=height, fx=fx, fy=fy, cx=cx, cy=cy
        )
    if not cams:
        raise ValueError(f"no cameras parsed from {path}")
    return cams


def _quat_to_rotmat_np(q: np.ndarray) -> np.ndarray:
    """wxyz quaternion -> 3x3 rotation (normalizing), host-side."""
    w, x, y, z = q / (np.linalg.norm(q) + 1e-12)
    return np.array(
        [
            [1 - 2 * (y * y + z * z), 2 * (x * y - w * z), 2 * (x * z + w * y)],
            [2 * (x * y + w * z), 1 - 2 * (x * x + z * z), 2 * (y * z - w * x)],
            [2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x * x + y * y)],
        ]
    )


def read_images_txt(
    path: pathlib.Path, cameras: dict[int, dict]
) -> tuple[list[Camera], list[str]]:
    """images.txt -> (list[Camera], image names), ordered by image id.

    COLMAP interleaves each image's pose line with a 2D-observation line;
    pose lines are recognized by *structure* (integer image/camera ids
    around seven floats, >= 10 tokens) rather than by position or exact
    token count, so empty observation lines are tolerated and image names
    containing spaces survive (the name is everything past token 8).
    """
    entries = []
    for tok in _data_lines(path):
        if len(tok) < 10:
            continue  # a POINTS2D observation line (or empty)
        try:
            image_id, cam_id = int(tok[0]), int(tok[8])
            q = np.array([float(t) for t in tok[1:5]])
            t = np.array([float(t) for t in tok[5:8]])
        except ValueError:
            continue  # observation line (floats where ids must be ints)
        if cam_id not in cameras:
            raise ValueError(
                f"images.txt references camera id {cam_id} missing from "
                "cameras.txt"
            )
        entries.append((image_id, q, t, cam_id, " ".join(tok[9:])))
    if not entries:
        raise ValueError(f"no registered images parsed from {path}")
    entries.sort(key=lambda e: e[0])

    cams, names = [], []
    for _, q, t, cam_id, name in entries:
        intr = cameras[cam_id]
        cams.append(
            Camera(
                r_cw=jnp.asarray(_quat_to_rotmat_np(q), dtype=jnp.float32),
                t_cw=jnp.asarray(t, dtype=jnp.float32),
                fx=jnp.asarray(intr["fx"], dtype=jnp.float32),
                fy=jnp.asarray(intr["fy"], dtype=jnp.float32),
                cx=jnp.asarray(intr["cx"], dtype=jnp.float32),
                cy=jnp.asarray(intr["cy"], dtype=jnp.float32),
                width=intr["width"],
                height=intr["height"],
            )
        )
        names.append(name)
    return cams, names


def read_points3d_txt(path: pathlib.Path) -> tuple[np.ndarray, np.ndarray]:
    """points3D.txt -> ((P, 3) xyz, (P, 3) rgb in [0, 1])."""
    xyz, rgb = [], []
    for tok in _data_lines(path):
        xyz.append([float(t) for t in tok[1:4]])
        rgb.append([float(t) / 255.0 for t in tok[4:7]])
    if not xyz:
        raise ValueError(f"no points parsed from {path}")
    return np.asarray(xyz, np.float32), np.asarray(rgb, np.float32)


def _knn_mean_dist(points: np.ndarray, k: int = 3, chunk: int = 1024) -> np.ndarray:
    """Mean distance to the k nearest neighbours of each point.

    Sparse COLMAP models run 1e4–1e6 points, so the primary path is a
    KD-tree (scipy, O(P log P), exact). The numpy fallback (scipy absent)
    is chunked |a|^2 + |b|^2 - 2ab^T with ``np.partition`` — one
    (chunk, P) float64 scratch, no (chunk, P, 3) broadcast temporary —
    and stays exact but O(P^2): fine to ~1e5 points.
    """
    p = points.astype(np.float64)
    n = p.shape[0]
    k = min(k, max(n - 1, 1))
    try:
        from scipy.spatial import cKDTree

        # k+1 because each point's nearest neighbour is itself.
        dist, _ = cKDTree(p).query(p, k=k + 1)
        return np.maximum(dist[:, 1:], 1e-8).mean(axis=1).astype(np.float32)
    except ImportError:
        pass
    sq = (p * p).sum(axis=1)
    out = np.empty(n)
    for s in range(0, n, chunk):
        d2 = sq[s : s + chunk, None] + sq[None, :] - 2.0 * (p[s : s + chunk] @ p.T)
        np.fill_diagonal(d2[:, s : s + chunk], np.inf)
        nearest = np.partition(d2, k - 1, axis=1)[:, :k]
        out[s : s + chunk] = np.sqrt(np.maximum(nearest, 1e-16)).mean(axis=1)
    return out.astype(np.float32)


def gaussians_from_points(
    points: np.ndarray,
    colors: np.ndarray,
    *,
    init_opacity: float = INIT_OPACITY,
) -> GaussianParams:
    """Seed a Gaussian cloud from a colored point cloud (3DGS init).

    DC SH term ``(rgb - 0.5) / SH_C0`` makes the degree-0 color reproduce
    the point color exactly (the evaluator adds the +0.5 shift back);
    higher bands start at zero. Scales are isotropic at the mean 3-NN
    distance (clamped away from zero), rotations identity, opacity
    uniform at ``init_opacity``.
    """
    n = points.shape[0]
    sh = np.zeros((n, NUM_SH_BASES, 3), np.float32)
    sh[:, 0, :] = (colors - 0.5) / SH_C0
    dist = np.maximum(_knn_mean_dist(points), 1e-4)
    logit = math.log(init_opacity / (1.0 - init_opacity))
    return GaussianParams(
        positions=jnp.asarray(points, dtype=jnp.float32),
        quats=jnp.asarray(
            np.tile(np.array([1.0, 0, 0, 0], np.float32), (n, 1))
        ),
        log_scales=jnp.asarray(np.log(dist)[:, None].repeat(3, axis=1)),
        sh=jnp.asarray(sh),
        opacity_logit=jnp.full((n,), logit, dtype=jnp.float32),
    )


def load_colmap_scene(path: str | pathlib.Path) -> ColmapScene:
    """Load a COLMAP text model directory into a :class:`ColmapScene`.

    ``path`` is the directory holding ``cameras.txt`` / ``images.txt`` /
    ``points3D.txt`` (tandt_db keeps them under ``<scene>/sparse/0`` after
    conversion to text; pass that directory).
    """
    root = pathlib.Path(path)
    intrinsics = read_cameras_txt(root / "cameras.txt")
    cameras, names = read_images_txt(root / "images.txt", intrinsics)
    points, colors = read_points3d_txt(root / "points3D.txt")
    return ColmapScene(
        cameras=cameras,
        image_names=names,
        points=points,
        colors=colors,
        gaussians=gaussians_from_points(points, colors),
    )


def scale_camera(cam: Camera, factor: float) -> Camera:
    """Rescale a camera's image plane by ``factor`` (pose unchanged).

    Real captures are multi-megapixel; the laptop-scale examples render
    them at a fraction of the native resolution. Intrinsics scale with the
    image size.
    """
    return Camera(
        r_cw=cam.r_cw,
        t_cw=cam.t_cw,
        fx=cam.fx * factor,
        fy=cam.fy * factor,
        cx=cam.cx * factor,
        cy=cam.cy * factor,
        width=max(1, int(round(cam.width * factor))),
        height=max(1, int(round(cam.height * factor))),
    )
