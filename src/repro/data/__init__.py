from repro.data.synthetic import SyntheticLMData, SyntheticMultiView

__all__ = ["SyntheticLMData", "SyntheticMultiView"]
