from repro.data.colmap import ColmapScene, load_colmap_scene
from repro.data.synthetic import SyntheticLMData, SyntheticMultiView

__all__ = [
    "ColmapScene",
    "SyntheticLMData",
    "SyntheticMultiView",
    "load_colmap_scene",
]
