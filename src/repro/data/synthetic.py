"""Deterministic synthetic data pipelines.

Determinism is the fault-tolerance contract: batch contents are a pure
function of (seed, step), so a restarted/elastically-resized job replays the
exact token stream with no coordinator state. ``sharded_batch`` materializes
each device's shard locally (``jax.make_array_from_callback``) — the analogue
of per-host data loading on a real pod.

The LM stream is a structured Markov-ish sequence (not uniform noise) so tiny
models have signal to learn in the integration tests / examples.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.camera import Camera, orbit_cameras
from repro.core.gaussians import GaussianParams, random_gaussians


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _tokens(self, step: int) -> np.ndarray:
        """(B, T+1) deterministic pseudo-corpus for a step."""
        rng = np.random.default_rng((self.seed, step))
        b, t = self.global_batch, self.seq_len + 1
        # Markov chain with a shared transition structure: next ~ cur*a+noise.
        base = rng.integers(0, self.vocab_size, size=(b, 1))
        steps = rng.integers(1, 7, size=(b, t - 1))
        toks = np.concatenate([base, steps], axis=1).cumsum(axis=1)
        return (toks % self.vocab_size).astype(np.int32)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        toks = self._tokens(step)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def sharded_batch(
        self, mesh: Mesh, step: int, batch_axes: Sequence[str] = ("data",)
    ) -> dict[str, jax.Array]:
        host = self.batch_at(step)
        axes = tuple(a for a in batch_axes if a in mesh.shape)
        spec = P(axes if len(axes) > 1 else (axes[0] if axes else None))
        out = {}
        for k, arr in host.items():
            sharding = NamedSharding(mesh, spec)
            out[k] = jax.make_array_from_callback(
                arr.shape, sharding, lambda idx, a=arr: a[idx]
            )
        return out


@dataclasses.dataclass
class SyntheticMultiView:
    """Multi-view 3DGS training set: ground-truth Gaussians rendered from an
    orbit of cameras (the stand-in for the paper's tandt_db train split)."""

    num_gaussians: int = 512
    num_views: int = 16
    image_size: int = 64
    seed: int = 0
    render_config: Any = None  # repro.core.config.RenderConfig | None

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        self.gt = random_gaussians(key, self.num_gaussians, extent=1.5)
        self.cameras = orbit_cameras(
            self.num_views,
            radius=5.0,
            width=self.image_size,
            height=self.image_size,
        )

    def targets(self) -> list[jax.Array]:
        from repro.core.render import render

        return [render(self.gt, cam, self.render_config) for cam in self.cameras]

    def view_at(self, step: int) -> int:
        return step % self.num_views
