"""Version-compat shims for the jax APIs this repo straddles.

The container pins jax 0.4.37, where ``shard_map`` still lives under
``jax.experimental`` and ``jax.sharding.AxisType`` / the ``axis_types``
kwarg of ``jax.make_mesh`` do not exist yet. Newer jax promotes both to the
top level. Import from here instead of feature-detecting at every call site.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    import inspect

    _raw_shard_map = jax.shard_map
    # Newer jax renamed check_rep -> check_vma; callers here use the old
    # spelling, normalized to whichever kwarg this jax accepts.
    _CHECK_KW = (
        "check_rep"
        if "check_rep" in inspect.signature(_raw_shard_map).parameters
        else "check_vma"
    )

    def shard_map(*args, check_rep=None, **kw):
        if check_rep is not None:
            kw[_CHECK_KW] = check_rep
        return _raw_shard_map(*args, **kw)
else:  # jax <= 0.4.x: check_rep is the native kwarg
    from jax.experimental.shard_map import shard_map  # noqa: F401

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if HAS_AXIS_TYPES:
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(tuple(shape), tuple(axes), axis_types=types)
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape, axes) -> "jax.sharding.AbstractMesh":
    """``AbstractMesh`` across the 0.4.x (pair-tuple) and newer
    (sizes, names) constructor signatures."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:  # jax <= 0.4.x: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
