"""repro — production-grade JAX reproduction of "Exploring the Versal AI
Engine for 3D Gaussian Splatting" (Shimamura et al., 2025) plus the
multi-pod LM substrate for the assigned architecture pool. See DESIGN.md."""

__version__ = "1.0.0"
