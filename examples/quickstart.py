"""Quickstart: build a synthetic Gaussian cloud, verify the feature paths
agree (staged reference, fused, Pallas kernel), then render through the
dense oracle, the tile-binned path, and the binned Pallas kernel — all
configured via RenderConfig.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RenderConfig, look_at_camera, random_gaussians, render
from repro.core.features import compute_features_fused, compute_features_naive
from repro.kernels.gaussian_features.ops import gaussian_features
from repro.kernels.gaussian_features.ref import pack_features


def main() -> None:
    key = jax.random.PRNGKey(7)
    g = random_gaussians(key, 2000, extent=1.5)
    cam = look_at_camera((0.0, 1.5, -5.0), (0, 0, 0), width=128, height=128)

    print("== feature computation: naive vs fused vs pallas kernel ==")
    t0 = time.perf_counter()
    f_naive = jax.block_until_ready(compute_features_naive(g, cam))
    print(f"naive   path: {time.perf_counter() - t0:.3f}s")
    t0 = time.perf_counter()
    f_fused = jax.block_until_ready(compute_features_fused(g, cam))
    print(f"fused   path: {time.perf_counter() - t0:.3f}s")
    t0 = time.perf_counter()
    # Pallas (interpret mode on CPU)
    f_kernel = jax.block_until_ready(gaussian_features(g, cam))
    print(f"pallas  path: {time.perf_counter() - t0:.3f}s")

    err_nf = float(jnp.max(jnp.abs(pack_features(f_naive) - pack_features(f_fused))))
    err_fk = float(jnp.max(jnp.abs(pack_features(f_fused) - pack_features(f_kernel))))
    print(f"max |naive - fused|  = {err_nf:.2e}")
    print(f"max |fused - pallas| = {err_fk:.2e}")
    assert err_nf < 1e-4 and err_fk < 1e-4

    print("\n== full render: dense oracle vs tile-binned vs pallas kernels ==")
    # Exactness: with ample list capacity the binned and pallas paths equal
    # the dense oracle (shared 3-sigma support contract, see DESIGN.md 3.1).
    base = RenderConfig(background=(0.05, 0.05, 0.08))
    imgs = {}
    for path in ("dense", "binned", "pallas", "pallas_binned"):
        cfg = base.replace(raster_path=path, tile_capacity=g.num_gaussians)
        imgs[path] = render(g, cam, cfg)
    err_db = float(jnp.max(jnp.abs(imgs["dense"] - imgs["binned"])))
    err_dp = float(jnp.max(jnp.abs(imgs["dense"] - imgs["pallas"])))
    err_dc = float(jnp.max(jnp.abs(imgs["dense"] - imgs["pallas_binned"])))
    print(f"max |dense - binned|        = {err_db:.2e}")
    print(f"max |dense - pallas|        = {err_dp:.2e}")
    print(f"max |dense - pallas_binned| = {err_dc:.2e}")
    assert err_db < 1e-5 and err_dp < 1e-4 and err_dc < 1e-4

    # Throughput: production capacity (overflow drops back-most Gaussians).
    for path in ("dense", "binned"):
        cfg = base.replace(raster_path=path)
        # reprolint: disable=retrace-hazard -- one executable per raster
        # path, compiled then timed; the loop IS the sweep.
        fn = jax.jit(lambda gg, c=cfg: render(gg, cam, c))
        jax.block_until_ready(fn(g))  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(fn(g))
        print(f"{path:7s} raster: {time.perf_counter() - t0:.3f}s/frame")

    img = imgs["binned"]
    img8 = np.asarray(jnp.clip(img, 0, 1) * 255).astype(np.uint8)
    out = "/tmp/quickstart_render.npy"
    np.save(out, img8)
    print(f"rendered {img.shape}, mean={float(img.mean()):.3f}, saved to {out}")

    visible = int(f_fused.mask.sum())
    print(f"{visible}/{g.num_gaussians} Gaussians in frustum")


if __name__ == "__main__":
    main()
