"""LM-substrate end-to-end driver: train a ~100M-parameter dense transformer
for a few hundred steps through the full production path (sharded trainer,
checkpointing, deterministic data, AdamW + schedule).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import shutil

from repro.data import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models.api import ModelConfig
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def config_100m() -> ModelConfig:
    """~100M params: 8L x 512 wide, tinyllama-style GQA."""
    return ModelConfig(
        name="dense-100m",
        family="dense",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_head=64,
        d_ff=1536,
        vocab_size=32000,
        remat="none",
        compute_dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    from repro.models import params as P
    from repro.models.api import family_module

    n_params = P.param_count(family_module(cfg).param_defs(cfg))
    print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    mesh = make_host_mesh()
    print(f"mesh: {dict(mesh.shape)}")

    data = SyntheticLMData(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch
    )
    opt_cfg = AdamWConfig(
        learning_rate=1e-3, warmup_steps=20, total_steps=args.steps
    )
    tcfg = TrainerConfig(
        steps=args.steps,
        checkpoint_every=max(50, args.steps // 4),
        checkpoint_dir=args.ckpt_dir,
        log_every=20,
    )
    trainer = Trainer(cfg, opt_cfg, tcfg, data, mesh)
    result = trainer.run()

    print("\nstep  loss    grad_norm  ms/step")
    for m in result["metrics"]:
        print(
            f"{m['step']:5d} {m['loss']:.4f}  {m['grad_norm']:.3f}   "
            f"{1000*m['sec_per_step']:.0f}"
        )
    first, last = result["metrics"][0]["loss"], result["metrics"][-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} over {result['final_step']} steps")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
