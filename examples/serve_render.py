"""Render serving under bursty (and mixed-size) request streams — the
paper's deployment shape: a trained Gaussian model served against a stream
of camera requests, with throughput (req/s) as the headline metric.

Drives the continuous-batching :class:`repro.serve.RenderServer` (persistent
slot table, immediate refill, pipelined dispatch) against two baselines
under the *same* arrival schedule:

* the sequential per-request path (one ``render_jit`` dispatch per camera —
  the pre-batching serving path);
* the micro-batching window scheduler (``mode="microbatch"`` — PR 3's
  collect-then-drain server).

The stream is **bursty** Poisson by default (bursts of ``--burst`` requests
at exponential gaps): exactly the shape where draining whole windows hurts,
because a straggler behind a just-freed slot waits out ``max_wait_ms`` that
the continuous scheduler never charges. ``--mixed-sizes`` adds a second
image-size bucket (continuous mode only — the bucketed-executable contract),
round-robining requests across sizes.

    PYTHONPATH=src python examples/serve_render.py [--requests 32]
        [--arrival-rate 8] [--burst 3] [--mixed-sizes]
        [--metrics-port 9100] [--trace-out trace.json]

Observability (``repro.obs``): every server in the comparison reports into
one shared metrics registry. ``--metrics-port`` serves it as Prometheus
text at ``/metrics`` for the duration of the run (port 0 picks a free
one); ``--trace-out`` writes a Chrome trace-event JSON with per-slot
request spans — drag it into https://ui.perfetto.dev to see admission
waits, step packing, and the dispatch-ahead-of-harvest overlap. Compile
times are printed from the registry's ``render_server_compile_ms`` gauge,
the same series the endpoint exports.

Live SLOs (``repro.obs.slo``): ``--slo-p95-ms`` / ``--slo-max-queue``
declare targets for the continuous server; one
:class:`~repro.obs.slo.SLOMonitor` is shared between the server (which
feeds it request events) and the metrics endpoint (which then also serves
``/healthz`` — 503 once overloaded — and ``/slo``, the full state/window
snapshot). The run prints the final health state and any overload
transitions the burst pattern caused.
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RenderConfig, orbit_cameras, random_gaussians
from repro.core.render import render_jit
from repro.obs.metrics import Registry, serve_metrics
from repro.obs.slo import SLOMonitor, SLOTargets
from repro.obs.tracing import Tracer, span
from repro.serve import RenderServer, replay_schedule


def percentiles(lat_ms: np.ndarray) -> str:
    return (
        f"p50={np.percentile(lat_ms, 50):.1f} ms "
        f"p95={np.percentile(lat_ms, 95):.1f} ms"
    )


def bursty_gaps(args, rng: np.random.Generator) -> np.ndarray:
    """Per-request inter-arrival gaps: bursts of --burst at Poisson times."""
    if args.arrival_rate <= 0:
        return np.zeros(args.requests)  # one big burst (closed loop)
    gaps = np.zeros(args.requests)
    # Burst heads arrive at exponential gaps scaled so the *mean request*
    # rate stays --arrival-rate; followers arrive immediately behind.
    head_gap = args.burst / args.arrival_rate
    for i in range(0, args.requests, args.burst):
        gaps[i] = rng.exponential(head_gap)
    return gaps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--gaussians", type=int, default=4096)
    ap.add_argument("--image-size", type=int, default=96)
    ap.add_argument(
        "--raster-path",
        choices=("dense", "binned", "pallas", "pallas_binned", "pallas_fused"),
        default="binned",
    )
    ap.add_argument("--tile-capacity", type=int, default=512)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument(
        "--arrival-rate",
        type=float,
        default=8.0,
        help="mean Poisson arrivals per second; 0 = offered load arrives "
        "all at once (closed-loop throughput test)",
    )
    ap.add_argument(
        "--burst",
        type=int,
        default=3,
        help="requests per arrival burst (1 = plain Poisson)",
    )
    ap.add_argument(
        "--mixed-sizes",
        action="store_true",
        help="alternate requests between --image-size and half of it "
        "(continuous server only: bucketed executables)",
    )
    ap.add_argument(
        "--cull",
        action="store_true",
        help="serve against a frustum-culled SceneTree (the server builds "
        "the hierarchy once at startup; every request then renders only "
        "its visible chunks)",
    )
    ap.add_argument(
        "--compress",
        choices=("none", "int8"),
        default="none",
        help="resident-scene storage: int8 promotes the model to a "
        "quantized SceneTree (decode-in-kernel on pallas_fused; ~0.35x "
        "f32 resident bytes — the server reports the exact footprint)",
    )
    ap.add_argument(
        "--metrics-port",
        type=int,
        default=None,
        help="serve the shared metrics registry as Prometheus text at "
        "/metrics on this port for the duration of the run (0 = pick a "
        "free port)",
    )
    ap.add_argument(
        "--slo-p95-ms",
        type=float,
        default=None,
        help="declare a windowed p95 latency target for the continuous "
        "server; enables the live SLO monitor (state printed at the end, "
        "/healthz + /slo served when --metrics-port is set)",
    )
    ap.add_argument(
        "--slo-max-queue",
        type=float,
        default=None,
        help="declare a queue-depth ceiling for the continuous server "
        "(same monitor as --slo-p95-ms)",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        help="write a Chrome trace-event JSON (Perfetto-loadable) with "
        "per-slot request spans to this path",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    args.burst = max(1, args.burst)

    registry = Registry()
    tracer = Tracer() if args.trace_out else None
    # One monitor shared by the continuous server (event source) and the
    # metrics endpoint (/healthz + /slo) — repro.obs.slo.
    slo_monitor = None
    if args.slo_p95_ms is not None or args.slo_max_queue is not None:
        slo_monitor = SLOMonitor(
            SLOTargets(
                p95_ms=args.slo_p95_ms,
                max_queue_depth=args.slo_max_queue,
            ),
            registry=registry,
            mode="continuous",
        )
    metrics_server = None
    if args.metrics_port is not None:
        metrics_server = serve_metrics(
            registry, port=args.metrics_port, slo=slo_monitor
        )
        port = metrics_server.port
        print(f"metrics: http://127.0.0.1:{port}/metrics")
        if slo_monitor is not None:
            print(
                f"slo:     http://127.0.0.1:{port}/slo  "
                f"(health: http://127.0.0.1:{port}/healthz)"
            )

    model = random_gaussians(jax.random.PRNGKey(0), args.gaussians, extent=1.5)
    config = RenderConfig(
        raster_path=args.raster_path,
        tile_capacity=args.tile_capacity,
        compress=args.compress,
    )
    if args.cull:
        # Conservative capacity: the orbit cameras see most of the compact
        # synthetic scene, so this demonstrates the plumbing (resident
        # hierarchy, per-camera culling inside the serving executables)
        # rather than a speedup — bench_culling measures that on
        # inside-the-cloud cameras.
        config = config.replace(cull=True, leaf_size=256)
    size = args.image_size
    print(
        f"serving a {args.gaussians}-Gaussian model "
        f"({args.raster_path} raster, {size}x{size}, "
        f"bursts of {args.burst} at {args.arrival_rate:g} req/s"
        + (", frustum-culled SceneTree" if args.cull else "")
        + (", int8-quantized resident scene" if args.compress != "none" else "")
        + ")"
    )

    # Request stream: cameras orbiting the scene (static image sizes ->
    # every request hits a pre-compiled bucket executable).
    cams = orbit_cameras(args.requests, radius=5.0, width=size, height=size)
    rng = np.random.default_rng(args.seed)
    gaps = bursty_gaps(args, rng)

    # --- sequential baseline (the pre-batching serving path) --------------
    # Explicit warmup: compile time is reported on its own line, never
    # folded into request 0's latency. The measurement lands in the shared
    # registry (same gauge the servers report into) and is printed from
    # there — one source of truth for the /metrics endpoint and stdout.
    compile_gauge = registry.gauge(
        "render_server_compile_ms",
        "Warmup compile time per image-size bucket (ms)",
    )
    t0 = time.perf_counter()
    with span("warmup_compile", tracer=tracer, mode="sequential"):
        render_jit(model, cams[0], config).block_until_ready()
    compile_gauge.set(
        (time.perf_counter() - t0) * 1e3, bucket="total", mode="sequential"
    )
    print(
        "sequential compile: "
        f"{compile_gauge.value(bucket='total', mode='sequential'):.0f} ms"
    )

    seq_lat = []

    def seq_submit(cam):
        t_req = time.perf_counter()
        render_jit(model, cam, config).block_until_ready()
        lat = (time.perf_counter() - t_req) * 1e3
        seq_lat.append(lat)
        return lat

    _, seq_wall = replay_schedule(seq_submit, cams, gaps)
    print(
        f"sequential:  {args.requests} requests in {seq_wall:.2f}s "
        f"({args.requests / seq_wall:.2f} req/s), "
        f"{percentiles(np.asarray(seq_lat))}"
    )

    # --- micro-batching baseline vs continuous batching -------------------
    walls = {}
    for mode in ("microbatch", "continuous"):
        server = RenderServer(
            model,
            config,
            width=size,
            height=size,
            max_batch=args.max_batch,
            max_wait_ms=args.max_wait_ms,
            mode=mode,
            registry=registry,
            tracer=tracer,
            slo=slo_monitor if mode == "continuous" else None,
        )
        server.warmup(cams[0])
        mem = server.memory_stats()
        if mode == "microbatch" and mem is not None:
            print(
                f"resident model: {mem['total_bytes'] / 1e6:.1f} MB "
                f"({mem['ratio_vs_f32']:.3f}x f32"
                + (", int8-quantized" if mem["compressed"] else "")
                + ")"
            )
        # Printed from the registry gauge warmup() populated — the same
        # series the /metrics endpoint exports.
        print(
            f"{mode} compile: "
            f"{compile_gauge.value(bucket='total', mode=mode):.0f} ms"
        )
        with server:
            results, wall = replay_schedule(server.submit, cams, gaps)
        walls[mode] = wall
        stats = server.stats()
        lat = np.asarray([r.latency_ms for r in results])
        print(
            f"{mode + ':':<12} {args.requests} requests in {wall:.2f}s "
            f"({args.requests / wall:.2f} req/s), {percentiles(lat)}, "
            f"occupancy {stats['occupancy']:.0%} "
            f"(mean batch {stats['mean_batch_size']:.1f}/{args.max_batch})"
        )
    print(
        f"throughput:  continuous = {walls['microbatch'] / walls['continuous']:.2f}x "
        f"micro-batching, {seq_wall / walls['continuous']:.2f}x sequential"
    )
    if slo_monitor is not None:
        snap = slo_monitor.snapshot()
        w = snap["window"]
        p95 = w["p95_ms"]
        print(
            f"slo:         state={snap['state']} "
            f"(window p95 {'—' if p95 is None else f'{p95:.1f} ms'}, "
            f"{w['req_s']:.2f} req/s, depth {w['queue_depth']})"
            + (
                " — transitions: "
                + ", ".join(
                    f"{t['from']}->{t['to']}@{t['t_s']:.2f}s"
                    for t in snap["transitions"]
                )
                if snap["transitions"]
                else ""
            )
        )

    # --- mixed-size buckets (continuous only) ------------------------------
    if args.mixed_sizes:
        small = size // 2
        mixed_cams = [
            c
            for pair in zip(
                orbit_cameras(
                    (args.requests + 1) // 2, radius=5.0, width=size, height=size
                ),
                orbit_cameras(
                    (args.requests + 1) // 2, radius=5.0, width=small, height=small
                ),
            )
            for c in pair
        ][: args.requests]
        server = RenderServer(
            model,
            config,
            sizes=[(size, size), (small, small)],
            max_batch=args.max_batch,
            mode="continuous",
            registry=registry,
            tracer=tracer,
        )
        server.warmup()
        print(
            f"mixed sizes {size}^2 + {small}^2: compile "
            f"{compile_gauge.value(bucket='total', mode='continuous'):.0f} ms "
            f"({len(server.buckets)} bucket executables)"
        )
        with server:
            results, wall = replay_schedule(server.submit, mixed_cams, gaps)
        lat = np.asarray([r.latency_ms for r in results])
        stats = server.stats()
        print(
            f"mixed:       {args.requests} requests in {wall:.2f}s "
            f"({args.requests / wall:.2f} req/s), {percentiles(lat)}, "
            f"occupancy {stats['occupancy']:.0%}"
        )

    if tracer is not None:
        tracer.save(args.trace_out)
        n = len(tracer.events())
        print(f"trace: {args.trace_out} ({n} events; open in ui.perfetto.dev)")
    if metrics_server is not None:
        metrics_server.shutdown()


if __name__ == "__main__":
    main()
