"""Render serving under a Poisson request stream — the paper's deployment
shape: a trained Gaussian model served against a stream of camera requests,
with throughput (req/s) as the headline metric.

Drives the async micro-batching :class:`repro.serve.RenderServer` with
Poisson arrivals and compares it against the sequential per-request baseline
(one ``render_jit`` dispatch per camera — the pre-batching serving path).

    PYTHONPATH=src python examples/serve_render.py [--requests 32]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RenderConfig, orbit_cameras, random_gaussians
from repro.core.render import render_jit
from repro.serve import RenderServer


def percentiles(lat_ms: np.ndarray) -> str:
    return (
        f"p50={np.percentile(lat_ms, 50):.1f} ms "
        f"p95={np.percentile(lat_ms, 95):.1f} ms"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--gaussians", type=int, default=4096)
    ap.add_argument("--image-size", type=int, default=96)
    ap.add_argument(
        "--raster-path",
        choices=("dense", "binned", "pallas", "pallas_binned"),
        default="binned",
    )
    ap.add_argument("--tile-capacity", type=int, default=512)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument(
        "--arrival-rate",
        type=float,
        default=0.0,
        help="mean Poisson arrivals per second; 0 = offered load arrives "
        "all at once (closed-loop throughput test)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = random_gaussians(jax.random.PRNGKey(0), args.gaussians, extent=1.5)
    config = RenderConfig(
        raster_path=args.raster_path, tile_capacity=args.tile_capacity
    )
    size = args.image_size
    print(
        f"serving a {args.gaussians}-Gaussian model "
        f"({args.raster_path} raster, {size}x{size})"
    )

    # Request stream: cameras orbiting the scene (one static image size ->
    # every batch hits one compiled executable).
    cams = orbit_cameras(args.requests, radius=5.0, width=size, height=size)
    rng = np.random.default_rng(args.seed)
    if args.arrival_rate > 0:
        gaps = rng.exponential(1.0 / args.arrival_rate, size=args.requests)
    else:
        gaps = np.zeros(args.requests)

    # --- sequential baseline (the pre-batching serving path) --------------
    # Explicit warmup: compile time is reported on its own line, never
    # folded into request 0's latency.
    t0 = time.perf_counter()
    render_jit(model, cams[0], config).block_until_ready()
    print(f"sequential compile: {(time.perf_counter() - t0) * 1e3:.0f} ms")

    seq_lat = []
    t_start = time.perf_counter()
    for i, cam in enumerate(cams):
        target = t_start + gaps[: i + 1].sum()
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        t_req = time.perf_counter()
        render_jit(model, cam, config).block_until_ready()
        seq_lat.append((time.perf_counter() - t_req) * 1e3)
    seq_wall = time.perf_counter() - t_start
    seq_lat = np.asarray(seq_lat)
    print(
        f"sequential: {args.requests} requests in {seq_wall:.2f}s "
        f"({args.requests / seq_wall:.2f} req/s), {percentiles(seq_lat)}"
    )

    # --- batched server ----------------------------------------------------
    server = RenderServer(
        model,
        config,
        width=size,
        height=size,
        max_batch=args.max_batch,
        max_wait_ms=args.max_wait_ms,
    )
    compile_ms = server.warmup(cams[0])
    print(f"batched compile: {compile_ms:.0f} ms")

    with server:
        t_start = time.perf_counter()
        futures = []
        for i, cam in enumerate(cams):
            target = t_start + gaps[: i + 1].sum()
            now = time.perf_counter()
            if target > now:
                time.sleep(target - now)
            futures.append(server.submit(cam))
        results = [f.result() for f in futures]
        wall = time.perf_counter() - t_start

    stats = server.stats()
    lat = np.asarray([r.latency_ms for r in results])
    print(
        f"batched:    {args.requests} requests in {wall:.2f}s "
        f"({args.requests / wall:.2f} req/s), {percentiles(lat)}, "
        f"occupancy {stats['occupancy']:.0%} "
        f"(mean batch {stats['mean_batch_size']:.1f}/{args.max_batch})"
    )
    print(
        f"throughput: batched = {seq_wall / wall:.2f}x sequential "
        f"({args.requests / wall:.2f} vs {args.requests / seq_wall:.2f} req/s)"
    )


if __name__ == "__main__":
    main()
