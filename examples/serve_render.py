"""Batched render serving — the paper's deployment shape: a trained Gaussian
model served against a stream of camera requests (feature computation +
rasterization per request, batched).

    PYTHONPATH=src python examples/serve_render.py [--requests 12]
"""

import argparse
import time

import jax
import numpy as np

from repro.core import RenderConfig, orbit_cameras, random_gaussians
from repro.core.render import render_jit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gaussians", type=int, default=4096)
    ap.add_argument("--image-size", type=int, default=96)
    ap.add_argument(
        "--raster-path",
        choices=("dense", "binned", "pallas", "pallas_binned"),
        default="binned",
    )
    ap.add_argument("--tile-capacity", type=int, default=512)
    args = ap.parse_args()

    model = random_gaussians(jax.random.PRNGKey(0), args.gaussians, extent=1.5)
    config = RenderConfig(
        raster_path=args.raster_path, tile_capacity=args.tile_capacity
    )
    print(f"serving a {args.gaussians}-Gaussian model ({args.raster_path} raster)")

    # request stream: cameras orbiting the scene (all same static image size
    # -> one compiled executable serves every request)
    cams = orbit_cameras(
        args.requests, radius=5.0, width=args.image_size, height=args.image_size
    )

    lat = []
    for i, cam in enumerate(cams):
        t0 = time.perf_counter()
        img = render_jit(model, cam, config)
        img.block_until_ready()
        ms = (time.perf_counter() - t0) * 1e3
        lat.append(ms)
        print(f"request {i:2d}: {ms:7.1f} ms   mean_rgb={float(img.mean()):.3f}")

    lat = np.asarray(lat[1:])  # drop compile
    print(
        f"\nserved {args.requests} requests: p50={np.percentile(lat, 50):.1f} ms "
        f"p95={np.percentile(lat, 95):.1f} ms "
        f"({1000.0 / np.percentile(lat, 50):.1f} req/s steady-state)"
    )


if __name__ == "__main__":
    main()
