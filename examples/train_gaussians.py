"""End-to-end driver: optimize a Gaussian cloud to fit rendered target views
(a few hundred steps, with densification + opacity reset) — the training
side of the paper's pipeline at laptop scale.

    PYTHONPATH=src python examples/train_gaussians.py [--steps 300]

``--dataset colmap:<dir>`` swaps the synthetic orbit for a real COLMAP
text model (tandt_db layout: the directory holding cameras.txt /
images.txt / points3D.txt): real camera poses, and the sparse point cloud
seeding the ground-truth Gaussians. The sparse model carries no pixels, so
targets are rendered from the point-seeded cloud and the trainable cloud
starts from a perturbed copy — real poses + real point init, synthetic
supervision.

    PYTHONPATH=src python examples/train_gaussians.py \
        --dataset colmap:tests/data/colmap --steps 100
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import RenderConfig, render, stack_cameras
from repro.core.train3dgs import (
    accumulate_grad_stats,
    densify_and_prune,
    init_densify_state,
    render_loss,
    render_loss_batch,
    reset_opacity,
)
from repro.core.gaussians import random_gaussians
from repro.data import SyntheticMultiView
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _load_colmap(path: str, image_size: int):
    """COLMAP model dir -> (cameras, GT cloud, initial trainable cloud)."""
    from repro.data.colmap import load_colmap_scene, scale_camera

    scene = load_colmap_scene(path)
    # Downscale the real image planes to the example's working resolution.
    native = max(max(c.width, c.height) for c in scene.cameras)
    factor = min(1.0, image_size / native)
    cams = [scale_camera(c, factor) for c in scene.cameras]
    gt = scene.gaussians
    # Trainable start: the same point init, jittered (the sparse model has
    # no pixels, so the point-seeded cloud doubles as ground truth).
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    init = dataclasses.replace(
        gt,
        positions=gt.positions
        + 0.02 * jax.random.normal(k1, gt.positions.shape),
        sh=gt.sh + 0.05 * jax.random.normal(k2, gt.sh.shape),
    )
    print(
        f"colmap scene {path}: {len(cams)} cameras "
        f"({cams[0].width}x{cams[0].height} at {factor:.2f}x native), "
        f"{gt.num_gaussians} seed points"
    )
    return cams, gt, init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--gaussians", type=int, default=256)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--image-size", type=int, default=48)
    ap.add_argument("--densify-every", type=int, default=100)
    ap.add_argument(
        "--dataset",
        default="synthetic",
        help='"synthetic" (orbit of views over a random GT cloud) or '
        '"colmap:<dir>" (COLMAP text model: real poses + point-cloud init)',
    )
    ap.add_argument(
        "--raster-path",
        choices=("dense", "binned", "pallas_binned", "pallas_fused"),
        default="binned",
    )
    ap.add_argument(
        "--camera-batch",
        type=int,
        default=1,
        help="views per step; >1 optimizes a multi-view loss over a camera "
        "batch through the batched render pipeline",
    )
    ap.add_argument(
        "--compress",
        choices=("none", "int8"),
        default="none",
        help="int8 = quantization-aware training: forward renders the "
        "int8/fp16-quantized cloud (straight-through estimator), "
        "gradients keep training the f32 master weights",
    )
    args = ap.parse_args()

    config = RenderConfig(
        raster_path=args.raster_path,
        pixel_chunk=None,
        compress=args.compress,
    )
    if args.dataset.startswith("colmap:"):
        cameras, gt, init = _load_colmap(
            args.dataset.split(":", 1)[1], args.image_size
        )
        targets = [render(gt, c, config) for c in cameras]
        num_active = init.num_gaussians
        capacity = 2 * num_active
        # Invisible padding rows double as free densification slots.
        from repro.core.gaussians import pad_to_multiple

        g, _ = pad_to_multiple(init, capacity)
        dstate = init_densify_state(capacity, num_active)
    elif args.dataset == "synthetic":
        data = SyntheticMultiView(
            num_gaussians=args.gaussians,
            num_views=args.views,
            image_size=args.image_size,
            render_config=config,
        )
        cameras = data.cameras
        targets = data.targets()
        print(
            f"synthetic scene: {args.gaussians} GT Gaussians, "
            f"{args.views} views"
        )
        capacity = args.gaussians * 2
        g = random_gaussians(jax.random.PRNGKey(1), capacity, extent=1.5)
        dstate = init_densify_state(capacity, args.gaussians)
    else:
        raise SystemExit(
            f"unknown --dataset {args.dataset!r} (use 'synthetic' or "
            "'colmap:<dir>')"
        )
    num_views = len(cameras)

    ocfg = AdamWConfig(
        learning_rate=1.5e-2,
        weight_decay=0.0,
        warmup_steps=0,
        total_steps=args.steps,
        clip_norm=1e9,
    )
    opt = adamw_init(g)

    cam_batch = max(1, min(args.camera_batch, num_views))
    if cam_batch > 1 and len({(c.width, c.height) for c in cameras}) > 1:
        raise SystemExit(
            "--camera-batch > 1 needs one image size across all cameras "
            "(stack_cameras / stacked targets are fixed-shape); this "
            "dataset has mixed resolutions — use --camera-batch 1"
        )

    @jax.jit
    def step(g, opt, cam, target):
        if cam_batch > 1:
            loss_fn = lambda gg: render_loss_batch(gg, cam, target, config)  # noqa: E731
        else:
            loss_fn = lambda gg: render_loss(gg, cam, target, config)  # noqa: E731
        loss, grads = jax.value_and_grad(loss_fn)(g)
        uv_grad_proxy = grads.positions[:, :2]  # screen-space grad stand-in
        g, opt, _ = adamw_update(ocfg, g, grads, opt)
        return g, opt, loss, uv_grad_proxy

    t0 = time.time()
    for i in range(args.steps):
        if cam_batch > 1:
            # Multi-view step: a contiguous window of views per step (the
            # camera batch shares one compiled executable across steps).
            views = [
                (i * cam_batch + j) % num_views for j in range(cam_batch)
            ]
            cams_i = stack_cameras([cameras[v] for v in views])
            tgt_i = jnp.stack([targets[v] for v in views])
            g, opt, loss, uvg = step(g, opt, cams_i, tgt_i)
        else:
            view = i % num_views
            g, opt, loss, uvg = step(g, opt, cameras[view], targets[view])
        dstate = accumulate_grad_stats(
            dstate, uvg, jnp.ones((capacity,))
        )
        if (i + 1) % args.densify_every == 0 and i + 1 < args.steps:
            g, dstate = densify_and_prune(
                g, dstate, jax.random.fold_in(jax.random.PRNGKey(2), i)
            )
            g = reset_opacity(g, dstate)
            opt = adamw_init(g)  # reset moments after topology change
            print(
                f"  step {i+1}: densify -> {int(dstate.active.sum())} active"
            )
        if (i + 1) % 50 == 0 or i == 0:
            print(f"step {i+1:4d}  loss {float(loss):.4f}")
    jax.block_until_ready(g)  # fence: async dispatch is still in flight
    dt = time.time() - t0
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({1000*dt/args.steps:.0f} ms/step)")

    # held-out view PSNR
    img = render(g, cameras[0], config)
    mse = float(jnp.mean((img - targets[0]) ** 2))
    psnr = -10.0 * jnp.log10(mse)
    print(f"view-0 PSNR: {float(psnr):.1f} dB")


if __name__ == "__main__":
    main()
