"""End-to-end driver: optimize a Gaussian cloud to fit rendered target views
(a few hundred steps, with densification + opacity reset) — the training
side of the paper's pipeline at laptop scale.

    PYTHONPATH=src python examples/train_gaussians.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import RenderConfig, render, stack_cameras
from repro.core.train3dgs import (
    accumulate_grad_stats,
    densify_and_prune,
    init_densify_state,
    render_loss,
    render_loss_batch,
    reset_opacity,
)
from repro.core.gaussians import random_gaussians
from repro.data import SyntheticMultiView
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--gaussians", type=int, default=256)
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--image-size", type=int, default=48)
    ap.add_argument("--densify-every", type=int, default=100)
    ap.add_argument(
        "--raster-path",
        choices=("dense", "binned", "pallas_binned"),
        default="binned",
    )
    ap.add_argument(
        "--camera-batch",
        type=int,
        default=1,
        help="views per step; >1 optimizes a multi-view loss over a camera "
        "batch through the batched render pipeline",
    )
    args = ap.parse_args()

    config = RenderConfig(raster_path=args.raster_path, pixel_chunk=None)
    data = SyntheticMultiView(
        num_gaussians=args.gaussians,
        num_views=args.views,
        image_size=args.image_size,
        render_config=config,
    )
    targets = data.targets()
    print(f"synthetic scene: {args.gaussians} GT Gaussians, {args.views} views")

    capacity = args.gaussians * 2
    g = random_gaussians(jax.random.PRNGKey(1), capacity, extent=1.5)
    dstate = init_densify_state(capacity, args.gaussians)

    ocfg = AdamWConfig(
        learning_rate=1.5e-2,
        weight_decay=0.0,
        warmup_steps=0,
        total_steps=args.steps,
        clip_norm=1e9,
    )
    opt = adamw_init(g)

    cam_batch = max(1, min(args.camera_batch, args.views))

    @jax.jit
    def step(g, opt, cam, target):
        if cam_batch > 1:
            loss_fn = lambda gg: render_loss_batch(gg, cam, target, config)  # noqa: E731
        else:
            loss_fn = lambda gg: render_loss(gg, cam, target, config)  # noqa: E731
        loss, grads = jax.value_and_grad(loss_fn)(g)
        uv_grad_proxy = grads.positions[:, :2]  # screen-space grad stand-in
        g, opt, _ = adamw_update(ocfg, g, grads, opt)
        return g, opt, loss, uv_grad_proxy

    t0 = time.time()
    for i in range(args.steps):
        if cam_batch > 1:
            # Multi-view step: a contiguous window of views per step (the
            # camera batch shares one compiled executable across steps).
            views = [
                data.view_at(i * cam_batch + j) for j in range(cam_batch)
            ]
            cams_i = stack_cameras([data.cameras[v] for v in views])
            tgt_i = jnp.stack([targets[v] for v in views])
            g, opt, loss, uvg = step(g, opt, cams_i, tgt_i)
        else:
            view = data.view_at(i)
            g, opt, loss, uvg = step(g, opt, data.cameras[view], targets[view])
        dstate = accumulate_grad_stats(
            dstate, uvg, jnp.ones((capacity,))
        )
        if (i + 1) % args.densify_every == 0 and i + 1 < args.steps:
            g, dstate = densify_and_prune(
                g, dstate, jax.random.fold_in(jax.random.PRNGKey(2), i)
            )
            g = reset_opacity(g, dstate)
            opt = adamw_init(g)  # reset moments after topology change
            print(
                f"  step {i+1}: densify -> {int(dstate.active.sum())} active"
            )
        if (i + 1) % 50 == 0 or i == 0:
            print(f"step {i+1:4d}  loss {float(loss):.4f}")
    dt = time.time() - t0
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({1000*dt/args.steps:.0f} ms/step)")

    # held-out view PSNR
    img = render(g, data.cameras[0], config)
    mse = float(jnp.mean((img - targets[0]) ** 2))
    psnr = -10.0 * jnp.log10(mse)
    print(f"view-0 PSNR: {float(psnr):.1f} dB")


if __name__ == "__main__":
    main()
