"""perfguard — declarative perf-regression gating over the BENCH trajectory.

The repo's analog of the paper's headline claim (a measured 226x
throughput increase) is the ``BENCH_PR*.json`` trajectory; perfguard is
what *enforces* it. Budgets are declared in ``[tool.perfguard]`` tables
in pyproject.toml as dotted metric paths into the BENCH schema with
absolute floors/ceilings (req/s >=, p95 <=, byte_ratio <=, psnr_db >=)
and relative-to-baseline tolerances. Detection is noise-aware: metrics
may carry multiple trials (``benchmarks/run.py --tiny --trials N``),
perfguard compares *medians* and widens the relative threshold by a
MAD-scaled noise term so 2-core-CPU jitter doesn't flake CI.

``python -m tools.perfguard check`` loads the latest BENCH results plus
the committed, provenance-stamped ``perfguard-baseline.json`` and reports
pass/regress/improve per budget (``--format github`` emits Actions
annotations); ``update-baseline`` rolls the baseline forward deliberately.

Dependency-free (stdlib only) — the sibling of ``tools.reprolint``, and
the static half of the observability story whose live half is
``repro.obs.slo`` (DESIGN.md §13).
"""

from tools.perfguard.budgets import (
    Budget,
    BudgetResult,
    evaluate_budgets,
    mad,
    median,
    resolve_metric,
)
from tools.perfguard.config import load_config

__all__ = [
    "Budget",
    "BudgetResult",
    "evaluate_budgets",
    "load_config",
    "mad",
    "median",
    "resolve_metric",
]
