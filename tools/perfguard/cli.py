"""Command line: ``python -m tools.perfguard <check|update-baseline|list-budgets>``.

``check`` exits 0 only when no budget regressed (or is missing while
required); ``update-baseline`` rolls the committed baseline forward
*deliberately* — it is a reviewed action, never something CI does for you
(DESIGN.md §13 has the when-to-roll-forward policy).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.perfguard import bench as bench_io
from tools.perfguard.budgets import evaluate_budgets
from tools.perfguard.config import load_config


def _load(args) -> tuple[dict, dict, Path]:
    root = Path(args.root).resolve()
    cfg = load_config(root)
    if args.bench:
        bench_path = Path(args.bench)
    else:
        bench_path = bench_io.latest_bench(root, cfg["bench_glob"])
        if bench_path is None:
            raise SystemExit(
                f"perfguard: no bench results matching {cfg['bench_glob']!r} "
                f"under {root} (run `python -m benchmarks.run [--tiny]` or "
                "pass --bench)"
            )
    bench = bench_io.load_bench(bench_path)
    return cfg, bench, bench_path


def cmd_check(args) -> int:
    cfg, bench, bench_path = _load(args)
    baseline_path = Path(args.root).resolve() / (args.baseline or cfg["baseline"])
    baseline = bench_io.load_baseline(baseline_path)
    profile = bench_io.bench_profile(bench)
    results = evaluate_budgets(
        cfg["budgets"], bench, baseline, profile=profile
    )
    failed = [r for r in results if r.failed]
    improved = [r for r in results if r.status == "improve"]
    if args.format == "github":
        for r in results:
            if r.failed or r.status == "improve":
                print(r.github())
    else:
        for r in results:
            print(r.text())
    meta = bench.get("_meta") or {}
    print(
        f"perfguard: {len(results)} budget(s) against {bench_path.name} "
        f"(profile={profile}, trials={meta.get('trials', 1)}, "
        f"sha={meta.get('git_sha', 'unknown')}) — "
        f"{len(failed)} regressed, {len(improved)} improved"
        + ("" if baseline else "; no baseline file — absolute bounds only"),
        file=sys.stderr,
    )
    return 1 if failed else 0


def cmd_update_baseline(args) -> int:
    cfg, bench, bench_path = _load(args)
    root = Path(args.root).resolve()
    baseline_path = root / (args.baseline or cfg["baseline"])
    doc = bench_io.build_baseline(
        cfg["budgets"], bench, source=bench_path.name, root=root
    )
    bench_io.write_baseline(baseline_path, doc)
    meta = doc["_meta"]
    print(
        f"perfguard: wrote {len(doc['budgets'])} baseline entr(ies) to "
        f"{baseline_path} (profile={meta['profile']}, "
        f"trials={meta['trials']}, sha={meta['git_sha']})",
        file=sys.stderr,
    )
    return 0


def cmd_list_budgets(args) -> int:
    cfg = load_config(Path(args.root).resolve())
    for b in cfg["budgets"]:
        bounds = []
        if b.min is not None:
            bounds.append(f">= {b.min:g}")
        if b.max is not None:
            bounds.append(f"<= {b.max:g}")
        if b.relative:
            bounds.append(
                f"within {b.rel_tolerance:.0%} (or {b.mad_k:g}*MAD) of baseline"
            )
        print(
            f"{b.name:28s} {b.metric}  [{b.better}] "
            f"{'; '.join(bounds) or 'no bounds'}  profiles={list(b.profiles)}"
        )
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.perfguard",
        description="Declarative perf-regression gating over BENCH_*.json.",
    )
    ap.add_argument(
        "--root", default=".",
        help="repo root (pyproject.toml location; default: cwd)",
    )
    sub = ap.add_subparsers(dest="command", required=True)

    common = argparse.ArgumentParser(add_help=False)
    common.add_argument(
        "--bench", default=None,
        help="bench results file (default: newest bench_glob match by PR "
        "number)",
    )
    common.add_argument(
        "--baseline", default=None,
        help="baseline file (default: [tool.perfguard] baseline)",
    )

    p = sub.add_parser(
        "check", parents=[common],
        help="evaluate every budget; exit 1 on any regression",
    )
    p.add_argument(
        "--format", choices=("text", "github"), default="text",
        help="output format (github = Actions error/notice annotations)",
    )
    p.set_defaults(func=cmd_check)

    p = sub.add_parser(
        "update-baseline", parents=[common],
        help="pin current bench medians as the new baseline (deliberate, "
        "reviewed)",
    )
    p.set_defaults(func=cmd_update_baseline)

    p = sub.add_parser("list-budgets", help="print the configured budgets")
    p.set_defaults(func=cmd_list_budgets)

    args = ap.parse_args(argv)
    return args.func(args)
