"""BENCH/baseline file IO + provenance stamping.

The bench side of the contract: ``benchmarks/run.py`` writes
``BENCH_*.json`` files whose top level carries a ``_meta`` table —
``{git_sha, date, schema_version, hostname, trials, profile}`` — so every
number in the trajectory (and every baseline derived from one) says where
it came from. Old BENCH files without ``_meta`` still load: they default
to ``profile="full"``, ``trials=1``.

The baseline side: ``perfguard-baseline.json`` is the committed document
``{_meta, budgets: {name: {metric, median, mad, n, samples}}}`` that
``check`` compares against and ``update-baseline`` rolls forward.
"""

from __future__ import annotations

import datetime
import glob
import json
import os
import re
import socket
import subprocess
from pathlib import Path
from typing import Sequence

from tools.perfguard.budgets import Budget, mad, median, resolve_metric, _samples

SCHEMA_VERSION = 1
_PR_RE = re.compile(r"BENCH_PR(\d+)\.json$")


def git_sha(root: Path | str = ".") -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.fspath(root), capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def provenance_meta(
    *, trials: int, profile: str, root: Path | str = "."
) -> dict:
    """The ``_meta`` table stamped into BENCH files and baselines."""
    return {
        "schema_version": SCHEMA_VERSION,
        "git_sha": git_sha(root),
        "date": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"
        ),
        "hostname": socket.gethostname(),
        "trials": int(trials),
        "profile": profile,
    }


def latest_bench(root: Path, pattern: str) -> Path | None:
    """Newest trajectory file by PR number (``BENCH_PR8`` > ``BENCH_PR2``);
    non-PR-numbered matches sort last by name."""
    paths = glob.glob(os.fspath(Path(root) / pattern))
    if not paths:
        return None

    def key(p: str):
        m = _PR_RE.search(p)
        return (1, int(m.group(1)), p) if m else (0, 0, p)

    return Path(max(paths, key=key))


def load_bench(path: Path) -> dict:
    with open(path) as f:
        bench = json.load(f)
    if not isinstance(bench, dict):
        raise ValueError(f"{path}: bench file must hold a JSON object")
    return bench


def bench_profile(bench: dict) -> str:
    return (bench.get("_meta") or {}).get("profile", "full")


def load_baseline(path: Path) -> dict | None:
    if not Path(path).exists():
        return None
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "budgets" not in doc:
        raise ValueError(
            f"{path}: baseline must hold {{_meta, budgets}} (regenerate "
            "with `python -m tools.perfguard update-baseline`)"
        )
    return doc


def build_baseline(
    budgets: Sequence[Budget],
    bench: dict,
    *,
    source: str,
    root: Path | str = ".",
) -> dict:
    """Capture the current bench medians as the new baseline document.

    Only budgets whose metric resolves in ``bench`` get entries; the rest
    stay unpinned (their relative check reports "no baseline entry" until
    a bench run covering them is rolled forward).
    """
    meta = (bench.get("_meta") or {})
    entries: dict[str, dict] = {}
    for b in budgets:
        raw = resolve_metric(bench, b.metric)
        samples = _samples(raw) if raw is not None else None
        if samples is None:
            continue
        entries[b.name] = {
            "metric": b.metric,
            "median": median(samples),
            "mad": mad(samples),
            "n": len(samples),
            "samples": samples,
        }
    doc_meta = provenance_meta(
        trials=int(meta.get("trials", 1)),
        profile=meta.get("profile", "full"),
        root=root,
    )
    doc_meta["source"] = source
    doc_meta["bench_git_sha"] = meta.get("git_sha", "unknown")
    doc_meta["bench_date"] = meta.get("date", "unknown")
    return {"_meta": doc_meta, "budgets": entries}


def write_baseline(path: Path, doc: dict) -> None:
    Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
