"""``[tool.perfguard]`` configuration: harness knobs + budget tables.

Budgets live in pyproject.toml so a new benchmark registers its floors in
the same review diff that adds the numbers (DESIGN.md §13):

.. code-block:: toml

    [tool.perfguard]
    baseline = "perfguard-baseline.json"
    bench_glob = "BENCH_PR*.json"
    mad_k = 3.0           # default noise widening: k * MAD(baseline trials)
    rel_tolerance = 0.25  # default relative-to-baseline tolerance

    [tool.perfguard.budgets.serving-req-s]
    metric = "bench_serving.server.req_s"  # dotted path into the BENCH json
    better = "higher"                      # or "lower" (p95, byte_ratio)
    min = 1.0                              # absolute floor (max = ceiling)
    rel_tolerance = 0.3                    # override the default
    profiles = ["tiny"]                    # bench profiles this applies to
    relative = true                        # false = absolute bounds only

Parsing reuses reprolint's TOML-subset reader (tomllib on >=3.11, the
mini parser on the 3.10 CI floor) via its ``prefix`` parameter — one
stdlib-only parser shared by both tools.
"""

from __future__ import annotations

from pathlib import Path
from typing import Any

from tools.perfguard.budgets import Budget
from tools.reprolint.config import _read_sections

SECTION_PREFIX = "tool.perfguard"

DEFAULTS: dict[str, Any] = {
    "baseline": "perfguard-baseline.json",
    "bench_glob": "BENCH_PR*.json",
    "mad_k": 3.0,
    "rel_tolerance": 0.25,
}


def load_config(root: Path) -> dict[str, Any]:
    """Read ``[tool.perfguard]`` (+ budget sub-tables) from pyproject.toml.

    Returns ``{baseline, bench_glob, mad_k, rel_tolerance,
    budgets: list[Budget]}``; budgets inherit the top-level ``mad_k`` /
    ``rel_tolerance`` unless their table overrides them.
    """
    cfg: dict[str, Any] = dict(DEFAULTS)
    cfg["budgets"] = []
    pyproject = Path(root) / "pyproject.toml"
    if not pyproject.exists():
        return cfg
    sections = _read_sections(pyproject.read_text(), SECTION_PREFIX)
    top = sections.get(SECTION_PREFIX, {})
    for key in ("baseline", "bench_glob", "mad_k", "rel_tolerance"):
        if key in top:
            cfg[key] = top[key]
    budget_prefix = SECTION_PREFIX + ".budgets."
    for name in sorted(sections):
        if not name.startswith(budget_prefix):
            continue
        table = sections[name]
        cfg["budgets"].append(
            Budget.from_table(
                name[len(budget_prefix):],
                table,
                default_mad_k=float(cfg["mad_k"]),
                default_rel_tolerance=float(cfg["rel_tolerance"]),
            )
        )
    return cfg
