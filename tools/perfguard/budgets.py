"""Budget model + evaluation: the noise-aware regression decision.

One :class:`Budget` pins one dotted metric path in the BENCH schema. Two
independent checks apply, and *either* failing flags a regression:

* **absolute** — ``min`` / ``max`` bounds on the measured median. These
  encode the claims the repo has already banked (fused >= 1.5x unfused,
  byte_ratio <= 0.45, PSNR >= 40 dB) and hold on any machine.
* **relative** — the median must stay within a tolerance band of the
  committed baseline median. The band is widened by the baseline's noise:
  ``margin = max(rel_tolerance * |baseline|, mad_k * MAD(baseline
  trials))`` so a metric whose trial-to-trial jitter exceeds the
  percentage tolerance is judged against its own measured spread (median
  + MAD are the robust pair — one outlier trial on a noisy 2-core
  container moves neither). Relative checks only run when the bench and
  baseline were produced by the same *profile* (tiny vs full) — medians
  from different scales are not comparable, so a mismatch downgrades the
  budget to its absolute bounds instead of flaking.

A measured value may be a single scalar (one trial) or a list (the
``--trials N`` schema); evaluation always reduces to ``median(samples)``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

# Budget statuses, in severity order. "missing" (a required metric absent
# from the bench file) and "regress" both fail the check; everything else
# passes. "improve" is informational: the metric beat the baseline by more
# than the noise margin — a candidate for `update-baseline`.
FAIL_STATUSES = ("regress", "missing")
STATUSES = ("pass", "improve", "regress", "missing", "skipped")


def median(xs: Sequence[float]) -> float:
    s = sorted(float(x) for x in xs)
    n = len(s)
    if n == 0:
        raise ValueError("median of empty sample set")
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


def mad(xs: Sequence[float]) -> float:
    """Median absolute deviation — the robust spread of the trial set."""
    m = median(xs)
    return median([abs(float(x) - m) for x in xs])


def resolve_metric(tree: Any, path: str) -> Any:
    """Resolve a dotted path, tolerating dots *inside* keys.

    BENCH keys like ``"1.5x_capacity"`` contain dots, so a naive
    ``path.split(".")`` cannot address them. Resolution is greedy: at each
    dict level, any key that is a prefix of the remaining path (on a dot
    boundary) is tried, longest first. Returns None when nothing matches.
    """
    if path == "":
        return tree
    if not isinstance(tree, dict):
        return None
    keys = [k for k in tree if path == k or path.startswith(k + ".")]
    for k in sorted(keys, key=len, reverse=True):
        rest = path[len(k):].lstrip(".")
        found = resolve_metric(tree[k], rest)
        if found is not None:
            return found
    return None


def _samples(value: Any) -> list[float] | None:
    """Scalar or trial-list -> list of finite floats; None if not numeric."""
    if isinstance(value, bool):
        return None
    if isinstance(value, (int, float)):
        vals = [float(value)]
    elif isinstance(value, list) and value and all(
        isinstance(v, (int, float)) and not isinstance(v, bool) for v in value
    ):
        vals = [float(v) for v in value]
    else:
        return None
    return vals if all(math.isfinite(v) for v in vals) else None


@dataclasses.dataclass(frozen=True)
class Budget:
    """One declarative perf budget over a dotted BENCH metric path."""

    name: str
    metric: str
    better: str = "higher"  # "higher" | "lower"
    min: float | None = None
    max: float | None = None
    rel_tolerance: float = 0.25
    mad_k: float = 3.0
    relative: bool = True  # False = absolute bounds only (scale-invariant)
    required: bool = True  # missing metric fails (vs skipped)
    profiles: tuple[str, ...] = ("tiny", "full")

    @classmethod
    def from_table(
        cls,
        name: str,
        table: dict,
        *,
        default_mad_k: float,
        default_rel_tolerance: float,
    ) -> "Budget":
        if "metric" not in table:
            raise ValueError(f"budget {name!r}: missing required key 'metric'")
        better = table.get("better", "higher")
        if better not in ("higher", "lower"):
            raise ValueError(
                f"budget {name!r}: better={better!r} not in ('higher', 'lower')"
            )
        profiles = tuple(table.get("profiles", ("tiny", "full")))
        unknown = set(table) - {
            "metric", "better", "min", "max", "rel_tolerance", "mad_k",
            "relative", "required", "profiles",
        }
        if unknown:
            raise ValueError(
                f"budget {name!r}: unknown key(s) {sorted(unknown)}"
            )
        return cls(
            name=name,
            metric=str(table["metric"]),
            better=better,
            min=float(table["min"]) if "min" in table else None,
            max=float(table["max"]) if "max" in table else None,
            rel_tolerance=float(
                table.get("rel_tolerance", default_rel_tolerance)
            ),
            mad_k=float(table.get("mad_k", default_mad_k)),
            relative=bool(table.get("relative", True)),
            required=bool(table.get("required", True)),
            profiles=profiles,
        )


@dataclasses.dataclass(frozen=True)
class BudgetResult:
    """Outcome of one budget against one bench file (+ optional baseline)."""

    budget: Budget
    status: str  # one of STATUSES
    message: str
    value: float | None = None  # measured median
    n_samples: int = 0
    baseline_value: float | None = None  # baseline median
    threshold: float | None = None  # the relative bound that applied

    @property
    def failed(self) -> bool:
        return self.status in FAIL_STATUSES

    def text(self) -> str:
        mark = {
            "pass": "ok  ", "improve": "UP  ", "regress": "FAIL",
            "missing": "FAIL", "skipped": "skip",
        }[self.status]
        return f"[{mark}] {self.budget.name:28s} {self.message}"

    def github(self) -> str:
        """GitHub Actions workflow-command annotation (one line)."""
        level = "error" if self.failed else "notice"
        msg = (
            self.message.replace("%", "%25")
            .replace("\r", "%0D")
            .replace("\n", "%0A")
        )
        return f"::{level} title=perfguard[{self.budget.name}]::{msg}"


def _fmt(x: float | None) -> str:
    if x is None:
        return "—"
    if x == 0 or 1e-3 <= abs(x) < 1e6:
        return f"{x:.4g}"
    return f"{x:.3e}"


def evaluate_budget(
    budget: Budget,
    bench: dict,
    baseline_entry: dict | None,
    *,
    profile_match: bool,
) -> BudgetResult:
    """Evaluate one budget. ``baseline_entry`` is the committed
    ``{median, mad, samples}`` record for this budget (None = no baseline
    yet); ``profile_match`` gates the relative check (see module doc)."""
    raw = resolve_metric(bench, budget.metric)
    samples = _samples(raw) if raw is not None else None
    if samples is None:
        status = "missing" if budget.required else "skipped"
        return BudgetResult(
            budget, status,
            f"metric {budget.metric!r} absent from bench results"
            + ("" if budget.required else " (optional)"),
        )
    med = median(samples)
    n = len(samples)
    meas = f"{budget.metric} = {_fmt(med)} (median of {n})"

    # Absolute bounds first: they hold on any machine and any baseline.
    if budget.min is not None and med < budget.min:
        return BudgetResult(
            budget, "regress",
            f"{meas} below absolute floor {_fmt(budget.min)}",
            value=med, n_samples=n, threshold=budget.min,
        )
    if budget.max is not None and med > budget.max:
        return BudgetResult(
            budget, "regress",
            f"{meas} above absolute ceiling {_fmt(budget.max)}",
            value=med, n_samples=n, threshold=budget.max,
        )

    if not budget.relative:
        return BudgetResult(
            budget, "pass", f"{meas} within absolute bounds",
            value=med, n_samples=n,
        )
    if baseline_entry is None:
        return BudgetResult(
            budget, "pass",
            f"{meas} — no baseline entry; absolute bounds only "
            "(run `update-baseline` to pin one)",
            value=med, n_samples=n,
        )
    if not profile_match:
        return BudgetResult(
            budget, "pass",
            f"{meas} — baseline profile differs from bench profile; "
            "absolute bounds only",
            value=med, n_samples=n,
        )

    base_med = float(baseline_entry["median"])
    base_mad = float(baseline_entry.get("mad", 0.0))
    margin = max(budget.rel_tolerance * abs(base_med), budget.mad_k * base_mad)
    sign = 1.0 if budget.better == "higher" else -1.0
    # better=higher: regress below base-margin, improve above base+margin;
    # better=lower is the mirror image.
    worst_ok = base_med - sign * margin
    regressed = sign * med < sign * worst_ok
    improved = sign * med > sign * (base_med + sign * margin)
    ctx = (
        f"baseline {_fmt(base_med)} (MAD {_fmt(base_mad)}), "
        f"margin {_fmt(margin)}"
    )
    if regressed:
        return BudgetResult(
            budget, "regress",
            f"{meas} regressed past {_fmt(worst_ok)}: {ctx}",
            value=med, n_samples=n, baseline_value=base_med,
            threshold=worst_ok,
        )
    if improved:
        return BudgetResult(
            budget, "improve",
            f"{meas} beats baseline by more than the noise margin: {ctx} "
            "— consider `update-baseline`",
            value=med, n_samples=n, baseline_value=base_med,
            threshold=worst_ok,
        )
    return BudgetResult(
        budget, "pass", f"{meas} within margin of {ctx}",
        value=med, n_samples=n, baseline_value=base_med, threshold=worst_ok,
    )


def evaluate_budgets(
    budgets: Sequence[Budget],
    bench: dict,
    baseline: dict | None,
    *,
    profile: str,
) -> list[BudgetResult]:
    """Evaluate every budget whose ``profiles`` admits ``profile``.

    ``baseline`` is the full baseline document (``{_meta, budgets}``);
    relative checks engage only when its ``_meta.profile`` matches the
    bench profile.
    """
    base_budgets = (baseline or {}).get("budgets", {})
    base_profile = ((baseline or {}).get("_meta") or {}).get("profile")
    out = []
    for b in budgets:
        if profile not in b.profiles:
            continue
        out.append(
            evaluate_budget(
                b, bench, base_budgets.get(b.name),
                profile_match=(base_profile == profile),
            )
        )
    return out
