"""Shared AST machinery: dotted names, jit detection, taint analysis.

Everything here is deliberately intraprocedural and conservative in the
*low-false-positive* direction: reprolint runs in CI with a zero-entry
baseline, so a rule that cries wolf is worse than one that misses an
exotic spelling. The contracts it models are the ones this codebase
actually uses (``@jax.jit`` / ``functools.partial(jax.jit, ...)``
decorators, ``name = jax.jit(fn)`` module aliases, ``*_ref`` Pallas
operand naming, ``with self._lock:`` critical sections).
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Iterator

# Attribute accesses on a traced value that yield *static* (trace-time)
# information — branching on these is the supported JAX idiom.
STATIC_ATTRS = {
    "shape",
    "ndim",
    "dtype",
    "size",
    "weak_type",
    "sharding",
    "aval",
    "itemsize",
    "num_gaussians",  # GaussianParams property: positions.shape[0]
    "num_real",  # QuantizedGaussianParams static field
    "chunk_size",  # QuantizedGaussianParams static field
    "num_chunks",  # SceneTree static chunk count
    "leaf_size",  # SceneTree static field
}

# Calls whose result is static regardless of argument taint.
STATIC_CALLS = {
    "len",
    "isinstance",
    "issubclass",
    "type",
    "hasattr",
    "callable",
    "id",
    "repr",
    "range",
    "enumerate",
    "as_config",  # RenderConfig coercion: static by construction
    "cdiv",
    "pick_tiles_per_step",
}


def dotted_name(node: ast.AST) -> str | None:
    """``jax.numpy.zeros`` for nested Attribute/Name chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def last_segment(name: str | None) -> str | None:
    return None if name is None else name.rsplit(".", 1)[-1]


def walk_functions(tree: ast.AST) -> Iterator[ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)] + (
        [a.vararg.arg] if a.vararg else []
    ) + ([a.kwarg.arg] if a.kwarg else [])


def positional_param_names(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


# -- jit / custom_vjp detection ------------------------------------------

JIT_NAMES = {"jax.jit", "jit"}
CUSTOM_VJP_NAMES = {"jax.custom_vjp", "custom_vjp"}
PARTIAL_NAMES = {"functools.partial", "partial"}


@dataclasses.dataclass
class TracedFunction:
    """A function whose body runs under JAX tracing.

    ``static_params`` are parameter names excluded from tracing
    (static_argnums/static_argnames/nondiff_argnums); everything else is
    a tracer inside the body.
    """

    fn: ast.FunctionDef | ast.AsyncFunctionDef
    static_params: set[str]
    reason: str  # "jax.jit" | "jax.custom_vjp" | "defvjp fwd" | "defvjp bwd"


def _literal_positions(node: ast.AST | None) -> list[int]:
    if node is None:
        return []
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, int)
        ]
    return []


def _literal_names(node: ast.AST | None) -> list[str]:
    if node is None:
        return []
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        ]
    return []


def _static_params_from_call(
    call: ast.Call, fn: ast.FunctionDef | ast.AsyncFunctionDef
) -> set[str]:
    """Resolve static/nondiff argnums+argnames kwargs against ``fn``."""
    statics: set[str] = set()
    positional = positional_param_names(fn)
    for kw in call.keywords:
        if kw.arg in ("static_argnums", "static_argnames", "nondiff_argnums"):
            for pos in _literal_positions(kw.value):
                if 0 <= pos < len(positional):
                    statics.add(positional[pos])
            statics.update(_literal_names(kw.value))
    return statics


def _match_wrapper(node: ast.AST, names: set[str]) -> ast.Call | bool | None:
    """Does a decorator / call expression apply one of ``names``?

    Returns the configuring ``ast.Call`` when one exists (so statics can
    be read), True for a bare name match, None for no match.
    """
    if dotted_name(node) in names:
        return True
    if isinstance(node, ast.Call):
        if dotted_name(node.func) in names:
            return node
        # functools.partial(jax.jit, static_argnames=...)
        if dotted_name(node.func) in PARTIAL_NAMES and node.args:
            if dotted_name(node.args[0]) in names:
                return node
    return None


def find_traced_functions(tree: ast.Module) -> list[TracedFunction]:
    """All functions in a module whose bodies trace: decorated with
    jit/custom_vjp (directly or via partial), aliased through a
    module-level ``x = jax.jit(f, ...)``, or registered via
    ``f.defvjp(fwd, bwd)``."""
    by_name: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = {}
    for fn in walk_functions(tree):
        by_name.setdefault(fn.name, fn)

    traced: dict[int, TracedFunction] = {}
    vjp_nondiff: dict[str, int] = {}  # custom_vjp object name -> #nondiff args

    def add(fn, statics, reason):
        if id(fn) not in traced:
            traced[id(fn)] = TracedFunction(fn, statics, reason)

    for fn in walk_functions(tree):
        for deco in fn.decorator_list:
            m = _match_wrapper(deco, JIT_NAMES)
            if m is not None:
                statics = _static_params_from_call(m, fn) if isinstance(m, ast.Call) else set()
                add(fn, statics | {"self", "cls"}, "jax.jit")
            m = _match_wrapper(deco, CUSTOM_VJP_NAMES)
            if m is not None:
                statics = _static_params_from_call(m, fn) if isinstance(m, ast.Call) else set()
                add(fn, statics | {"self", "cls"}, "jax.custom_vjp")
                if isinstance(m, ast.Call):
                    for kw in m.keywords:
                        if kw.arg == "nondiff_argnums":
                            vjp_nondiff[fn.name] = len(_literal_positions(kw.value))
                vjp_nondiff.setdefault(fn.name, 0)

    for node in ast.walk(tree):
        # name = jax.jit(f, ...) — mark f's def as traced.
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            call = node.value
            if dotted_name(call.func) in JIT_NAMES and call.args:
                target = call.args[0]
                if isinstance(target, ast.Name) and target.id in by_name:
                    fn = by_name[target.id]
                    add(fn, _static_params_from_call(call, fn) | {"self", "cls"}, "jax.jit")
        # f.defvjp(fwd, bwd): fwd traces like f; bwd's leading params are
        # the nondiff args (static), the rest (residuals, cotangents) trace.
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr == "defvjp" and len(node.args) >= 2:
                owner = dotted_name(node.func.value)
                n_nondiff = vjp_nondiff.get(owner or "", None)
                if n_nondiff is None:
                    continue
                fwd, bwd = node.args[0], node.args[1]
                if isinstance(fwd, ast.Name) and fwd.id in by_name:
                    fn = by_name[fwd.id]
                    owner_fn = by_name.get(owner or "")
                    statics = (
                        traced[id(owner_fn)].static_params
                        if owner_fn is not None and id(owner_fn) in traced
                        else set()
                    )
                    add(fn, set(statics) | {"self", "cls"}, "defvjp fwd")
                if isinstance(bwd, ast.Name) and bwd.id in by_name:
                    fn = by_name[bwd.id]
                    statics = set(positional_param_names(fn)[:n_nondiff])
                    add(fn, statics | {"self", "cls"}, "defvjp bwd")
    return list(traced.values())


# -- taint analysis -------------------------------------------------------


class Taint:
    """Monotone intraprocedural taint over a function body.

    Names in ``seeds`` start tainted; assignments propagate taint through
    expressions (monotone — a rebind never clears taint, which is the
    conservative direction for loops). ``subscript_seeds`` taints the
    *result of subscripting* a name (Pallas ``ref[...]`` loads) rather
    than the name itself.
    """

    def __init__(
        self,
        fn: ast.FunctionDef | ast.AsyncFunctionDef,
        seeds: set[str],
        *,
        subscript_seeds: set[str] | None = None,
        static_attrs: set[str] | None = None,
        static_calls: set[str] | None = None,
    ):
        self.fn = fn
        self.tainted = set(seeds)
        self.subscript_seeds = set(subscript_seeds or ())
        self.static_attrs = STATIC_ATTRS | set(static_attrs or ())
        self.static_calls = STATIC_CALLS | set(static_calls or ())

    def run(self) -> None:
        """Propagate assignments to a fixpoint (bounded)."""
        for _ in range(10):
            before = len(self.tainted)
            for node in ast.walk(self.fn):
                if isinstance(node, ast.Assign):
                    if self.is_tainted(node.value):
                        for t in node.targets:
                            self._taint_target(t)
                elif isinstance(node, ast.AnnAssign) and node.value is not None:
                    if self.is_tainted(node.value):
                        self._taint_target(node.target)
                elif isinstance(node, ast.AugAssign):
                    if self.is_tainted(node.value) or self.is_tainted(node.target):
                        self._taint_target(node.target)
                elif isinstance(node, (ast.For, ast.AsyncFor)):
                    if self.is_tainted(node.iter):
                        self._taint_target(node.target)
                elif isinstance(node, ast.withitem) and node.optional_vars:
                    if self.is_tainted(node.context_expr):
                        self._taint_target(node.optional_vars)
                elif isinstance(node, ast.NamedExpr):
                    if self.is_tainted(node.value):
                        self._taint_target(node.target)
            if len(self.tainted) == before:
                break

    def _taint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._taint_target(e)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value)
        # Attribute/Subscript stores don't introduce new tainted *names*.

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in self.static_attrs:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            base = node.value
            if isinstance(base, ast.Name) and base.id in self.subscript_seeds:
                return True
            return self.is_tainted(base) or self.is_tainted(node.slice)
        if isinstance(node, ast.Call):
            name = last_segment(call_name(node))
            if name in self.static_calls:
                return False
            parts = [node.func] if isinstance(node.func, ast.Attribute) else []
            return any(
                self.is_tainted(c)
                for c in (*parts, *node.args, *[k.value for k in node.keywords])
            )
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, (ast.Lambda, ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        return any(self.is_tainted(c) for c in ast.iter_child_nodes(node))


def control_flow_on_taint(
    fn: ast.FunctionDef | ast.AsyncFunctionDef, taint: Taint
) -> list[tuple[ast.AST, str]]:
    """Python control-flow / concretization sites whose test is tainted.

    Nested function definitions are included (closures over tracers are
    just as traced), but their *own* parameters are unknown, so only
    closure taint flows in.
    """
    hits: list[tuple[ast.AST, str]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.If) and taint.is_tainted(node.test):
            hits.append((node, "Python `if` on a traced value"))
        elif isinstance(node, ast.While) and taint.is_tainted(node.test):
            hits.append((node, "Python `while` on a traced value"))
        elif isinstance(node, ast.Assert) and taint.is_tainted(node.test):
            hits.append((node, "`assert` on a traced value"))
        elif isinstance(node, ast.Call):
            name = call_name(node)
            if name in ("bool", "int", "float") and node.args and any(
                taint.is_tainted(a) for a in node.args
            ):
                hits.append((node, f"`{name}()` concretizes a traced value"))
        elif isinstance(node, (ast.comprehension,)) and any(
            taint.is_tainted(i) for i in node.ifs
        ):
            hits.append((node.ifs[0], "comprehension `if` on a traced value"))
    return hits
