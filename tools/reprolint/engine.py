"""Rule engine: file discovery, suppressions, baseline, rule dispatch."""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Any, Iterable

from tools.reprolint.config import load_config
from tools.reprolint.findings import Finding

# Rule list = comma-separated names; an optional ` -- rationale` follows.
_SUPPRESS = re.compile(
    r"#\s*reprolint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
)


@dataclasses.dataclass
class SourceFile:
    path: str  # repo-root-relative, posix separators
    text: str
    tree: ast.Module | None
    syntax_error: str | None = None

    @property
    def lines(self) -> list[str]:
        return self.text.splitlines()

    def suppressions(self) -> tuple[dict[int, set[str]], set[str]]:
        """(line -> rules disabled on that line, rules disabled file-wide).

        A ``# reprolint: disable=<rule>`` comment applies to its own line
        and, when it sits on a comment-only line, to the next code line —
        skipping past any continuation comment lines, so a multi-line
        rationale above a statement still covers it. An optional
        `` -- rationale`` suffix is encouraged and ignored by the parser.
        ``disable-file=`` applies everywhere; ``all`` matches every rule.
        """
        per_line: dict[int, set[str]] = {}
        whole_file: set[str] = set()
        lines = self.lines
        for i, line in enumerate(lines, start=1):
            m = _SUPPRESS.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
            if m.group(1) == "disable-file":
                whole_file |= rules
                continue
            per_line.setdefault(i, set()).update(rules)
            if line.lstrip().startswith("#"):
                j = i  # 0-based index of the line after the comment
                while j < len(lines) and lines[j].lstrip().startswith("#"):
                    j += 1
                per_line.setdefault(j + 1, set()).update(rules)
        return per_line, whole_file


class Project:
    """All parsed files plus config — the unit rules run against."""

    def __init__(self, root: Path, files: list[SourceFile], cfg: dict[str, Any]):
        self.root = root
        self.files = files
        self.cfg = cfg

    def rule_option(self, rule: str, key: str, default: Any) -> Any:
        return self.cfg.get("rules", {}).get(rule, {}).get(key, default)


class Rule:
    """Base class: subclass, set ``name``/``summary``, override a check."""

    name = "rule"
    summary = ""

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        return []

    def check_project(self, project: Project) -> list[Finding]:
        return []

    # Helper: does this file fall under the rule's configured paths?
    def in_scope(self, sf: SourceFile, project: Project, default_paths: list[str]) -> bool:
        prefixes = project.rule_option(self.name, "paths", default_paths)
        return any(
            sf.path == p or sf.path.startswith(p.rstrip("/") + "/") for p in prefixes
        )


def all_rules() -> list[Rule]:
    from tools.reprolint.rules import ALL_RULES

    return [cls() for cls in ALL_RULES]


def discover_files(root: Path, paths: Iterable[str], exclude: Iterable[str]) -> list[SourceFile]:
    out: list[SourceFile] = []
    seen: set[str] = set()
    excl = [e.rstrip("/") for e in exclude]
    for p in paths:
        base = (root / p).resolve()
        candidates = [base] if base.is_file() else sorted(base.rglob("*.py"))
        for f in candidates:
            rel = f.relative_to(root).as_posix()
            if rel in seen:
                continue
            if any(rel == e or rel.startswith(e + "/") for e in excl):
                continue
            seen.add(rel)
            text = f.read_text()
            try:
                tree = ast.parse(text, filename=rel)
                out.append(SourceFile(rel, text, tree))
            except SyntaxError as e:
                out.append(SourceFile(rel, text, None, syntax_error=str(e)))
    return out


def lint_sources(
    files: list[SourceFile],
    root: Path,
    cfg: dict[str, Any] | None = None,
    select: set[str] | None = None,
) -> list[Finding]:
    """Run every (selected) rule over pre-discovered files."""
    cfg = cfg if cfg is not None else load_config(root)
    project = Project(root, files, cfg)
    rules = [r for r in all_rules() if select is None or r.name in select]
    findings: list[Finding] = []
    for sf in files:
        if sf.syntax_error is not None:
            findings.append(Finding(sf.path, 1, 1, "syntax", sf.syntax_error))
            continue
        per_line, whole = sf.suppressions()
        for rule in rules:
            for f in rule.check_file(sf, project):
                if _suppressed(f, per_line, whole):
                    continue
                findings.append(f)
    suppress_by_path = {
        sf.path: sf.suppressions() for sf in files if sf.syntax_error is None
    }
    for rule in rules:
        for f in rule.check_project(project):
            per_line, whole = suppress_by_path.get(f.path, ({}, set()))
            if _suppressed(f, per_line, whole):
                continue
            findings.append(f)
    return sorted(findings)


def _suppressed(f: Finding, per_line: dict[int, set[str]], whole: set[str]) -> bool:
    for rules in (whole, per_line.get(f.line, set())):
        if f.rule in rules or "all" in rules:
            return True
    return False


def lint_paths(
    root: Path,
    paths: Iterable[str] | None = None,
    select: set[str] | None = None,
) -> list[Finding]:
    cfg = load_config(root)
    files = discover_files(root, paths or cfg["paths"], cfg["exclude"])
    return lint_sources(files, root, cfg, select)


# -- baseline -------------------------------------------------------------


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.rstrip("\n")
        if not line.strip() or line.lstrip().startswith("#"):
            continue
        keys.add(line)
    return keys


def write_baseline(path: Path, findings: list[Finding]) -> None:
    lines = [
        "# reprolint baseline — one `path<TAB>rule<TAB>message` per line.",
        "# Policy: this file stays EMPTY; real findings get fixed or carry an",
        "# inline `# reprolint: disable=<rule>` with a rationale. The baseline",
        "# exists only to land the tool ahead of a fix in an emergency.",
    ]
    lines += sorted({f.baseline_key() for f in findings})
    path.write_text("\n".join(lines) + "\n")


def apply_baseline(findings: list[Finding], baseline: set[str]) -> list[Finding]:
    return [f for f in findings if f.baseline_key() not in baseline]
