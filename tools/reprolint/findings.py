"""Finding record + output formats (human text, GitHub annotations)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location.

    ``path`` is repo-root-relative with forward slashes so findings,
    baseline entries, and CI annotations are stable across machines.
    """

    path: str
    line: int
    col: int
    rule: str
    message: str

    def baseline_key(self) -> str:
        """Line-number-free identity used by the baseline file.

        Keyed on (path, rule, message) so unrelated edits that shift
        line numbers don't churn the baseline.
        """
        return f"{self.path}\t{self.rule}\t{self.message}"

    def text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def github(self) -> str:
        """GitHub Actions workflow-command annotation (one line)."""
        # Annotation messages must not contain raw newlines/percent signs.
        msg = (
            self.message.replace("%", "%25")
            .replace("\r", "%0D")
            .replace("\n", "%0A")
        )
        return (
            f"::error file={self.path},line={self.line},col={self.col},"
            f"title=reprolint[{self.rule}]::{msg}"
        )


def render(findings: list[Finding], fmt: str) -> str:
    if fmt == "github":
        return "\n".join(f.github() for f in findings)
    return "\n".join(f.text() for f in findings)
