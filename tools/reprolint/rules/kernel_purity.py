"""kernel-purity: host syncs / side effects / data-dependent Python
branching inside Pallas kernel modules (``kernels/*/kernel.py``).

A Pallas kernel body executes under tracing on every lowering and (in
interpret mode) per grid step. A host sync (``.item()``,
``np.asarray``, ``block_until_ready``, ``jax.device_get``) either
crashes on tracers or silently serializes the pipeline; Python side
effects (``print``, file/clock/RNG access) fire at *trace* time, not
per kernel invocation; and a Python ``if``/``while`` on a value loaded
from a ``Ref`` bakes one branch into the lowered kernel. Static
branching on Python-level parameters (``if early_exit:``) and
``pl.debug_print`` stay silent.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import (
    Taint,
    call_name,
    control_flow_on_taint,
    param_names,
    walk_functions,
)
from tools.reprolint.engine import Finding, Project, Rule, SourceFile

_DEFAULT_GLOBS = ["src/repro/kernels/*/kernel.py"]

# dotted-name suffixes of host-sync / side-effect calls.
_HOST_SYNC = {
    "np.asarray": "np.asarray materializes on host",
    "numpy.asarray": "numpy.asarray materializes on host",
    "jax.device_get": "jax.device_get syncs the device",
    "jax.block_until_ready": "block_until_ready syncs the device",
}
_HOST_SYNC_METHODS = {
    "item": ".item() syncs and concretizes",
    "block_until_ready": ".block_until_ready() syncs the device",
    "tolist": ".tolist() syncs and concretizes",
}
_SIDE_EFFECTS = {
    "print": "print() is a trace-time side effect (use pl.debug_print)",
    "open": "file I/O inside a kernel body",
    "time.time": "clock access is a trace-time side effect",
    "time.perf_counter": "clock access is a trace-time side effect",
    "random.random": "Python RNG inside a kernel (use jax.random)",
    "random.randint": "Python RNG inside a kernel (use jax.random)",
    "np.random.rand": "numpy RNG inside a kernel (use jax.random)",
    "np.random.randn": "numpy RNG inside a kernel (use jax.random)",
}


class KernelPurityRule(Rule):
    name = "kernel-purity"
    summary = (
        "host-sync calls, Python side effects, and Ref-data-dependent "
        "branching inside Pallas kernel modules"
    )

    def applies(self, sf: SourceFile, project: Project) -> bool:
        import fnmatch

        globs = project.rule_option(self.name, "globs", _DEFAULT_GLOBS)
        return any(fnmatch.fnmatch(sf.path, g) for g in globs)

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        if not self.applies(sf, project):
            return []
        findings: list[Finding] = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            msg = None
            if name in _SIDE_EFFECTS:
                msg = _SIDE_EFFECTS[name]
            elif name in _HOST_SYNC:
                msg = _HOST_SYNC[name]
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _HOST_SYNC_METHODS
                and not node.args
                and not node.keywords
            ):
                msg = _HOST_SYNC_METHODS[node.func.attr]
            if msg is not None:
                findings.append(
                    Finding(
                        sf.path,
                        node.lineno,
                        node.col_offset + 1,
                        self.name,
                        f"{msg} — kernel modules must stay pure and device-side",
                    )
                )

        # Data-dependent Python branching on Ref loads: taint flows from
        # `x = some_ref[...]` / `pl.load(some_ref, ...)` under the repo's
        # `*_ref` operand naming convention.
        for fn in walk_functions(sf.tree):
            refs = {p for p in param_names(fn) if p.endswith("_ref") or p == "ref"}
            if not refs:
                continue
            taint = Taint(fn, set(), subscript_seeds=refs)
            # pl.load(ref, ...) also yields a loaded (traced) value.
            for node in ast.walk(fn):
                if (
                    isinstance(node, ast.Call)
                    and call_name(node) in ("pl.load", "pltpu.load")
                    and node.args
                    and isinstance(node.args[0], ast.Name)
                    and node.args[0].id in refs
                ):
                    # Model by tainting targets of enclosing assignment via
                    # a synthetic seed: mark the call's ref as subscriptable
                    # (already) — Taint.is_tainted handles Call via args, so
                    # taint the ref name itself for load calls.
                    taint.tainted.add(node.args[0].id)
            taint.run()
            for node, why in control_flow_on_taint(fn, taint):
                findings.append(
                    Finding(
                        sf.path,
                        node.lineno,
                        node.col_offset + 1,
                        self.name,
                        f"{why.replace('a traced value', 'a Ref-loaded value')} "
                        f"in kernel `{fn.name}` — the branch is baked at lowering; "
                        "use lax.cond/jnp.where",
                    )
                )
        return findings
