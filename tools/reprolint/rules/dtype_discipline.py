"""dtype-discipline: keep the hot paths' dtypes explicit and f64-free.

The render/serving stack is engineered f32-end-to-end (decode-in-kernel
is *bitwise* pinned against jnp at f32; images are compared bitwise
across raster paths). An implicit f64 — from an explicit ``float64``
dtype, ``.astype(float)``, or a dtype-less constructor whose default
shifts under ``jax_enable_x64`` — either doubles bandwidth on the hot
path or breaks bitwise-equality contracts. In ``core/`` and
``kernels/`` every ``jnp.zeros/ones/empty/full/arange`` must name its
dtype, and float64 never appears.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import call_name, dotted_name
from tools.reprolint.engine import Finding, Project, Rule, SourceFile

_DEFAULT_PATHS = ["src/repro/core", "src/repro/kernels"]

# constructor -> index of the positional dtype slot (None = keyword-only).
_CONSTRUCTORS = {
    "zeros": 1,
    "ones": 1,
    "empty": 1,
    "full": 2,
    "arange": None,
}
_NS = {"jnp", "jax.numpy", "np", "numpy"}

_F64_NAMES = {
    "jnp.float64",
    "np.float64",
    "numpy.float64",
    "jax.numpy.float64",
    "jnp.complex128",
    "np.complex128",
}


class DtypeDisciplineRule(Rule):
    name = "dtype-discipline"
    summary = (
        "dtype-less jnp.zeros/ones/arange/empty/full and explicit float64 "
        "in core/ and kernels/"
    )

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        if not self.in_scope(sf, project, _DEFAULT_PATHS):
            return []
        findings: list[Finding] = []
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Attribute) and dotted_name(node) in _F64_NAMES:
                findings.append(
                    Finding(
                        sf.path,
                        node.lineno,
                        node.col_offset + 1,
                        self.name,
                        f"explicit {dotted_name(node)} on an f32-end-to-end path "
                        "— the pipeline's bitwise contracts assume f32",
                    )
                )
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name is None or "." not in name:
                continue
            ns, _, fn = name.rpartition(".")
            if ns not in _NS or fn not in _CONSTRUCTORS:
                continue
            dtype_pos = _CONSTRUCTORS[fn]
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords)
            if not has_dtype and dtype_pos is not None:
                has_dtype = len(node.args) > dtype_pos
            if not has_dtype:
                findings.append(
                    Finding(
                        sf.path,
                        node.lineno,
                        node.col_offset + 1,
                        self.name,
                        f"dtype-less {name}() — the default shifts under "
                        "jax_enable_x64 and hides the operand plane's width; "
                        "name the dtype explicitly",
                    )
                )
            # .astype(float) / dtype=float — weak f64 under x64.
            for kw in node.keywords:
                if kw.arg == "dtype" and isinstance(kw.value, ast.Name):
                    if kw.value.id == "float":
                        findings.append(
                            Finding(
                                sf.path,
                                kw.value.lineno,
                                kw.value.col_offset + 1,
                                self.name,
                                "dtype=float promotes to f64 under "
                                "jax_enable_x64; use jnp.float32",
                            )
                        )
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "astype"
                and node.args
                and isinstance(node.args[0], ast.Name)
                and node.args[0].id == "float"
            ):
                findings.append(
                    Finding(
                        sf.path,
                        node.lineno,
                        node.col_offset + 1,
                        self.name,
                        ".astype(float) promotes to f64 under jax_enable_x64; "
                        "use jnp.float32",
                    )
                )
        return findings
