"""lock-discipline: shared mutable state in threaded serving classes.

For every class that launches a worker thread (``threading.Thread(
target=self._x)``), methods are classified into two sides:

* **scheduler-side** — the transitive closure of ``self.*()`` calls
  reachable from any thread target (candidate targets are the bare
  ``self._x`` method references in the method that constructs the
  Thread, which also resolves ``target = self._a if cond else self._b``);
* **client-side** — every other method. ``__init__`` is exempt: it runs
  strictly before the thread exists (happens-before via Thread.start).

Two violation classes on private mutable attributes (``self._*``):

1. **cross-thread sharing** — an attribute *written* on one side and
   *accessed* on the other must be accessed under ``with self._lock:``
   everywhere (this is where the PR 4 batch-poisoning class of bug
   lived: generation counters / slot tables / stats read lock-free off
   the scheduler's shoulder);
2. **mixed discipline** — an attribute accessed under the lock somewhere
   and lock-free elsewhere is protected only by coincidence; either
   every access takes the lock or none should (thread-safe containers
   like ``queue.Queue`` go in the ``safe-attrs`` allowlist).
"""

from __future__ import annotations

import ast
import dataclasses

from tools.reprolint.astutil import dotted_name
from tools.reprolint.engine import Finding, Project, Rule, SourceFile

_DEFAULT_PATHS = ["src/repro/serve"]


@dataclasses.dataclass
class _Access:
    attr: str
    line: int
    col: int
    write: bool
    locked: bool
    method: str
    side: str  # "scheduler" | "client"


class LockDisciplineRule(Rule):
    name = "lock-discipline"
    summary = (
        "scheduler-thread vs client-thread classification; shared self._* "
        "state accessed outside `with self._lock:`"
    )

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        if not self.in_scope(sf, project, _DEFAULT_PATHS):
            return []
        safe = set(project.rule_option(self.name, "safe-attrs", []))
        findings: list[Finding] = []
        for node in sf.tree.body:
            if isinstance(node, ast.ClassDef):
                findings += self._check_class(sf, node, safe)
        return findings

    # -- class analysis ----------------------------------------------------

    def _check_class(
        self, sf: SourceFile, cls: ast.ClassDef, safe: set[str]
    ) -> list[Finding]:
        methods = {
            m.name: m
            for m in cls.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        lock_attrs = self._lock_attrs(methods.get("__init__"))
        targets = self._thread_targets(methods)
        if not targets or not lock_attrs:
            return []  # not a threaded class / no lock to check against

        scheduler_side = self._closure(targets, methods)
        accesses: list[_Access] = []
        for name, m in methods.items():
            if name == "__init__":
                continue  # pre-thread: happens-before Thread.start()
            side = "scheduler" if name in scheduler_side else "client"
            accesses += self._method_accesses(m, lock_attrs, side)

        by_attr: dict[str, list[_Access]] = {}
        for a in accesses:
            if a.attr.startswith("_") and a.attr not in lock_attrs and a.attr not in safe:
                by_attr.setdefault(a.attr, []).append(a)

        findings: list[Finding] = []
        for attr, accs in sorted(by_attr.items()):
            write_sides = {a.side for a in accs if a.write}
            access_sides = {a.side for a in accs}
            shared = bool(write_sides) and len(access_sides) > 1
            ever_locked = any(a.locked for a in accs)
            for a in accs:
                if a.locked:
                    continue
                if shared:
                    findings.append(
                        Finding(
                            sf.path,
                            a.line,
                            a.col,
                            self.name,
                            f"`self.{attr}` is {'written' if a.write else 'read'} "
                            f"lock-free in {a.side}-side `{cls.name}.{a.method}` "
                            f"but the {_other(a.side)} side also touches it "
                            f"(written on: {', '.join(sorted(write_sides))}); "
                            "guard every access with `with self._lock:`",
                        )
                    )
                elif ever_locked:
                    findings.append(
                        Finding(
                            sf.path,
                            a.line,
                            a.col,
                            self.name,
                            f"mixed lock discipline on `self.{attr}`: "
                            f"{'write' if a.write else 'read'} in "
                            f"`{cls.name}.{a.method}` skips the lock while other "
                            "accesses take it — hold `self._lock` here too (or "
                            "allowlist the attr as thread-safe)",
                        )
                    )
        return findings

    # -- classification helpers -------------------------------------------

    @staticmethod
    def _lock_attrs(init: ast.FunctionDef | None) -> set[str]:
        """Attributes assigned threading.Lock()/RLock() in __init__."""
        out: set[str] = set()
        if init is None:
            return out
        for node in ast.walk(init):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                if dotted_name(node.value.func) in (
                    "threading.Lock",
                    "threading.RLock",
                    "Lock",
                    "RLock",
                ):
                    for t in node.targets:
                        if (
                            isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"
                        ):
                            out.add(t.attr)
        return out

    @staticmethod
    def _thread_targets(methods: dict[str, ast.FunctionDef]) -> set[str]:
        """Method names used as thread entry points.

        Any bare ``self._x`` method reference (not a call) inside a method
        that constructs a ``threading.Thread`` counts — this resolves both
        ``Thread(target=self._loop)`` and the indirection
        ``target = self._a if cond else self._b; Thread(target=target)``.
        """
        targets: set[str] = set()
        for m in methods.values():
            makes_thread = any(
                isinstance(n, ast.Call)
                and dotted_name(n.func) in ("threading.Thread", "Thread")
                for n in ast.walk(m)
            )
            if not makes_thread:
                continue
            for node in ast.walk(m):
                if (
                    isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and node.attr in methods
                    and isinstance(node.ctx, ast.Load)
                ):
                    # A *reference* to the method (call sites wrap the
                    # Attribute in Call.func — exclude those).
                    targets.add(node.attr)
            for node in ast.walk(m):
                if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute
                ):
                    targets.discard(
                        node.func.attr
                        if isinstance(node.func.value, ast.Name)
                        and node.func.value.id == "self"
                        else ""
                    )
        return {t for t in targets if t}

    @staticmethod
    def _closure(roots: set[str], methods: dict[str, ast.FunctionDef]) -> set[str]:
        """Transitive closure of self-method calls from the thread targets."""
        seen = set(roots)
        frontier = list(roots)
        while frontier:
            m = methods.get(frontier.pop(), None)
            if m is None:
                continue
            for node in ast.walk(m):
                if (
                    isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"
                    and node.func.attr in methods
                    and node.func.attr not in seen
                ):
                    seen.add(node.func.attr)
                    frontier.append(node.func.attr)
        return seen

    # -- access extraction -------------------------------------------------

    def _method_accesses(
        self, m: ast.FunctionDef, lock_attrs: set[str], side: str
    ) -> list[_Access]:
        accesses: list[_Access] = []

        def visit(node: ast.AST, locked: bool) -> None:
            if isinstance(node, ast.With):
                holds = locked or any(
                    isinstance(item.context_expr, ast.Attribute)
                    and isinstance(item.context_expr.value, ast.Name)
                    and item.context_expr.value.id == "self"
                    and item.context_expr.attr in lock_attrs
                    for item in node.items
                )
                for item in node.items:
                    visit(item.context_expr, locked)
                for child in node.body:
                    visit(child, holds)
                return
            if isinstance(node, ast.Attribute) and (
                isinstance(node.value, ast.Name) and node.value.id == "self"
            ):
                accesses.append(
                    _Access(
                        attr=node.attr,
                        line=node.lineno,
                        col=node.col_offset + 1,
                        write=isinstance(node.ctx, (ast.Store, ast.Del)),
                        locked=locked,
                        method=m.name,
                        side=side,
                    )
                )
            # A subscript/augmented store through the attribute
            # (self._slots[i] = x) parses as Load on the Attribute with a
            # Store on the Subscript — reclassify.
            if isinstance(node, (ast.Subscript,)) and isinstance(
                node.ctx, (ast.Store, ast.Del)
            ):
                base = node.value
                if (
                    isinstance(base, ast.Attribute)
                    and isinstance(base.value, ast.Name)
                    and base.value.id == "self"
                ):
                    accesses.append(
                        _Access(
                            attr=base.attr,
                            line=base.lineno,
                            col=base.col_offset + 1,
                            write=True,
                            locked=locked,
                            method=m.name,
                            side=side,
                        )
                    )
            for child in ast.iter_child_nodes(node):
                visit(child, locked)

        for stmt in m.body:
            visit(stmt, False)
        return accesses


def _other(side: str) -> str:
    return "client" if side == "scheduler" else "scheduler"
