"""host-sync: unfenced timing of asynchronously-dispatched device work.

JAX dispatches asynchronously: a ``perf_counter()`` delta around device
work measures *dispatch*, not compute, unless something in the timed
region forces completion (``block_until_ready``, ``device_get``,
``.item()``, ``np.asarray``, ``Future.result()``). Benchmarks and
examples are exactly where such numbers get quoted, so every timed
region that launches device work must carry a fence before the delta is
taken.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import call_name, last_segment
from tools.reprolint.engine import Finding, Project, Rule, SourceFile

_DEFAULT_PATHS = ["examples", "benchmarks"]

_CLOCKS = {
    "time.perf_counter",
    "time.time",
    "time.monotonic",
    "perf_counter",
    "monotonic",
}

# Calls that force device work to completion inside the region.
_FENCE_DOTTED = {
    "jax.block_until_ready",
    "jax.device_get",
    "np.asarray",
    "numpy.asarray",
    "np.array",
    "numpy.array",
}
_FENCE_METHODS = {"block_until_ready", "item", "result", "tolist", "copy_to_host"}

# Host-side helpers that never dispatch device work: their presence in a
# timed region neither fences nor needs fencing.
_NEUTRAL = {
    "print",
    "format",
    "len",
    "range",
    "enumerate",
    "zip",
    "append",
    "extend",
    "join",
    "split",
    "items",
    "keys",
    "values",
    "get",
    "sleep",
    "time",
    "perf_counter",
    "monotonic",
    "str",
    "repr",
    "int",
    "float",
    "bool",
    "abs",
    "min",
    "max",
    "sum",
    "sorted",
    "round",
    "isinstance",
    "hasattr",
    "popleft",
    "pop",
    "add",
    "update",
    "write",
    "flush",
}


def _clock_assign(stmt: ast.stmt) -> str | None:
    """``t0 = time.perf_counter()`` -> ``t0``."""
    if (
        isinstance(stmt, ast.Assign)
        and len(stmt.targets) == 1
        and isinstance(stmt.targets[0], ast.Name)
        and isinstance(stmt.value, ast.Call)
        and call_name(stmt.value) in _CLOCKS
    ):
        return stmt.targets[0].id
    return None


def _uses_delta(node: ast.AST, timer: str) -> bool:
    """Any ``<expr> - <timer>`` inside ``node``."""
    for sub in ast.walk(node):
        if (
            isinstance(sub, ast.BinOp)
            and isinstance(sub.op, ast.Sub)
            and isinstance(sub.right, ast.Name)
            and sub.right.id == timer
        ):
            return True
    return False


def _classify_calls(stmts: list[ast.stmt], neutral: set[str]) -> tuple[bool, bool]:
    """(region launches device work, region contains a fence)."""
    device_work = fence = False
    for stmt in stmts:
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            seg = last_segment(name)
            if name in _FENCE_DOTTED or (
                isinstance(node.func, ast.Attribute) and node.func.attr in _FENCE_METHODS
            ):
                fence = True
            elif seg in neutral or (name or "").startswith("time."):
                continue
            else:
                device_work = True
    return device_work, fence


class HostSyncRule(Rule):
    name = "host-sync"
    summary = (
        "perf_counter deltas around device work without a completion fence "
        "(times async dispatch, not compute)"
    )

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        if not self.in_scope(sf, project, _DEFAULT_PATHS):
            return []
        neutral = _NEUTRAL | set(project.rule_option(self.name, "neutral-calls", []))
        findings: list[Finding] = []

        def check_block(stmts: list[ast.stmt]) -> None:
            for i, stmt in enumerate(stmts):
                timer = _clock_assign(stmt)
                if timer is not None:
                    region: list[ast.stmt] = []
                    for later in stmts[i + 1 :]:
                        if _uses_delta(later, timer):
                            break
                        region.append(later)
                    else:
                        region = []  # delta never taken in this block
                    if region:
                        device_work, fence = _classify_calls(region, neutral)
                        if device_work and not fence:
                            findings.append(
                                Finding(
                                    sf.path,
                                    stmt.lineno,
                                    stmt.col_offset + 1,
                                    self.name,
                                    f"timed region starting at `{timer} = "
                                    "perf_counter()` launches device work but "
                                    "never fences before the delta — wrap the "
                                    "result in jax.block_until_ready (async "
                                    "dispatch makes this measure launch time)",
                                )
                            )
                # Recurse into nested suites — but not into nested function
                # or class definitions (each function body gets its own
                # top-level pass below).
                if isinstance(
                    stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
                ):
                    continue
                for attr in ("body", "orelse", "finalbody"):
                    sub = getattr(stmt, attr, None)
                    if sub:
                        check_block(sub)
                for handler in getattr(stmt, "handlers", []) or []:
                    check_block(handler.body)

        check_block(sf.tree.body)
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                check_block(node.body)
        return findings
