"""Rule registry. Adding a rule = write the module, list the class here
(see DESIGN.md §12 for the checklist: rule module, registry entry,
positive + negative golden fixtures, docs row)."""

from tools.reprolint.rules.dead_code import DeadModuleRule
from tools.reprolint.rules.dtype_discipline import DtypeDisciplineRule
from tools.reprolint.rules.host_sync import HostSyncRule
from tools.reprolint.rules.kernel_purity import KernelPurityRule
from tools.reprolint.rules.lock_discipline import LockDisciplineRule
from tools.reprolint.rules.retrace import RetraceHazardRule
from tools.reprolint.rules.tracer_leak import TracerLeakRule

ALL_RULES = [
    TracerLeakRule,
    RetraceHazardRule,
    KernelPurityRule,
    DtypeDisciplineRule,
    HostSyncRule,
    LockDisciplineRule,
    DeadModuleRule,
]
