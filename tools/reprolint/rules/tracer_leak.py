"""tracer-leak: Python control flow on traced values in traced bodies.

Inside a ``@jax.jit`` / ``jax.custom_vjp`` body every non-static argument
is a tracer. A Python ``if``/``while``/``assert``/``bool()`` on a value
data-flowing from one either crashes at trace time (ConcretizationTypeError)
or — worse — silently bakes one branch into the compiled program. The
supported idioms (branching on ``.shape``/``.dtype``/static argnames,
``lax.cond``/``jnp.where``) stay silent.
"""

from __future__ import annotations

from tools.reprolint.astutil import (
    Taint,
    control_flow_on_taint,
    find_traced_functions,
    param_names,
)
from tools.reprolint.engine import Finding, Project, Rule, SourceFile


class TracerLeakRule(Rule):
    name = "tracer-leak"
    summary = (
        "Python if/while/assert/bool() on values data-flowing from traced "
        "arguments inside jit/custom_vjp bodies"
    )

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        extra_static = set(project.rule_option(self.name, "static-attrs", []))
        extra_calls = set(project.rule_option(self.name, "static-calls", []))
        findings: list[Finding] = []
        for tf in find_traced_functions(sf.tree):
            seeds = {
                p for p in param_names(tf.fn) if p not in tf.static_params
            }
            if not seeds:
                continue
            taint = Taint(
                tf.fn, seeds, static_attrs=extra_static, static_calls=extra_calls
            )
            taint.run()
            for node, why in control_flow_on_taint(tf.fn, taint):
                findings.append(
                    Finding(
                        sf.path,
                        node.lineno,
                        node.col_offset + 1,
                        self.name,
                        f"{why} inside `{tf.fn.name}` (traced via {tf.reason}); "
                        "use lax.cond/jnp.where, or mark the argument static",
                    )
                )
        return findings
