"""dead-module: modules unreachable from the project's entry points.

Builds the static import graph over ``src/`` and walks reachability from
the configured roots (the runnable surface: ``examples/``,
``benchmarks/`` — tests deliberately do *not* keep a module alive; a
module only a test imports is dead product code). Seed-era zoo modules
loaded dynamically (``repro.configs.*`` via ``importlib`` in the config
registry, ``repro.models.*`` via ``family_module``) live in the
pyproject allowlist; anything *new* that nothing reaches fails CI.
"""

from __future__ import annotations

import ast
import fnmatch

from tools.reprolint.engine import Finding, Project, Rule, SourceFile

_DEFAULT_ROOTS = ["examples", "benchmarks"]
_DEFAULT_ALLOW: list[str] = []


class DeadModuleRule(Rule):
    name = "dead-module"
    summary = (
        "src/ modules unreachable from the configured entry-point roots "
        "(allowlist covers dynamically-imported seed zoo modules)"
    )

    def check_project(self, project: Project) -> list[Finding]:
        roots = project.rule_option(self.name, "roots", _DEFAULT_ROOTS)
        allow = project.rule_option(self.name, "allow", _DEFAULT_ALLOW)

        # Map module name -> source file for everything under src/.
        modules: dict[str, SourceFile] = {}
        for sf in project.files:
            mod = _module_name(sf.path)
            if mod is not None:
                modules[mod] = sf

        # Import edges (module -> imported repro modules).
        edges: dict[str, set[str]] = {}
        for mod, sf in modules.items():
            if sf.tree is not None:
                edges[mod] = _imports(sf.tree, mod, modules)

        # Roots: repro modules imported by any file under the root dirs.
        reachable: set[str] = set()
        frontier: list[str] = []
        for sf in project.files:
            if sf.tree is None:
                continue
            if any(
                sf.path == r or sf.path.startswith(r.rstrip("/") + "/")
                for r in roots
            ):
                frontier.extend(_imports(sf.tree, None, modules))
        while frontier:
            mod = frontier.pop()
            if mod in reachable:
                continue
            reachable.add(mod)
            # Importing a submodule imports every ancestor package.
            parts = mod.split(".")
            for i in range(1, len(parts)):
                anc = ".".join(parts[:i])
                if anc in modules and anc not in reachable:
                    frontier.append(anc)
            frontier.extend(edges.get(mod, ()))

        findings: list[Finding] = []
        for mod in sorted(modules):
            if mod in reachable:
                continue
            if mod.endswith("__init__"):
                continue  # handled via package name
            if any(fnmatch.fnmatch(mod, pat) for pat in allow):
                continue
            # A package counts as reachable if any of its children are.
            if any(r.startswith(mod + ".") for r in reachable):
                continue
            findings.append(
                Finding(
                    modules[mod].path,
                    1,
                    1,
                    self.name,
                    f"module `{mod}` is unreachable from the entry-point roots "
                    f"({', '.join(roots)}) — delete it or add it to the "
                    "[tool.reprolint.dead-module] allow list with a reason",
                )
            )
        return findings


def _module_name(path: str) -> str | None:
    """src/repro/core/render.py -> repro.core.render (None outside src/)."""
    if not path.startswith("src/") or not path.endswith(".py"):
        return None
    parts = path[len("src/") : -len(".py")].split("/")
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


def _imports(
    tree: ast.Module, current: str | None, modules: dict[str, SourceFile]
) -> set[str]:
    """Resolve Import/ImportFrom nodes to known module names."""
    out: set[str] = set()

    def add_known(name: str) -> None:
        # `from pkg import symbol`: try pkg.symbol as a module, else pkg.
        if name in modules:
            out.add(name)
        elif name.rpartition(".")[0] in modules:
            out.add(name.rpartition(".")[0])

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                add_known(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level and current is not None:
                base_parts = current.split(".")[: -node.level]
                base = ".".join(base_parts)
                prefix = f"{base}.{node.module}" if node.module else base
            elif node.level:
                continue  # relative import outside src/ — not resolvable
            else:
                prefix = node.module or ""
            if not prefix:
                continue
            if prefix in modules and all(
                f"{prefix}.{a.name}" not in modules for a in node.names
            ):
                out.add(prefix)
            for alias in node.names:
                add_known(f"{prefix}.{alias.name}")
    return out
