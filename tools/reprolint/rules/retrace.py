"""retrace-hazard: patterns that silently recompile on every call.

Three hazard classes, all of which have bitten JAX serving stacks:

1. ``jax.jit(...)`` constructed inside a function body — every call of
   the enclosing function builds a *fresh* jitted callable with an empty
   cache, so the executable recompiles per call (per iteration, when the
   construction sits in a loop). Module-level jits, ``self._f =
   jax.jit(...)`` cached in ``__init__``, and ``functools.lru_cache``-
   wrapped factories are the supported shapes. Single-invocation scopes
   — pytest ``test_*`` functions and the configured ``entry-functions``
   (default ``main``) — are exempt when the construction is not inside
   a loop: a body that runs once per process cannot retrace.
2. Mutable defaults (list/dict/set) on static parameters — unhashable
   values reaching ``static_argnums``/``static_argnames`` raise at call
   time, and a call site passing a list literal for a static parameter
   does the same.
3. A jitted function reading a module-level *mutable* global (a
   list/dict/set that the module also mutates or rebinds): the value is
   baked in at trace time, so later mutation silently serves stale
   constants (or retraces, when it changes hashability).
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import (
    JIT_NAMES,
    _match_wrapper,
    call_name,
    dotted_name,
    find_traced_functions,
    positional_param_names,
    walk_functions,
)
from tools.reprolint.engine import Finding, Project, Rule, SourceFile

_CACHE_DECOS = {
    "functools.lru_cache",
    "lru_cache",
    "functools.cache",
    "cache",
}

_MUTATORS = {
    "append",
    "extend",
    "insert",
    "update",
    "setdefault",
    "pop",
    "popitem",
    "clear",
    "add",
    "discard",
    "remove",
}


class RetraceHazardRule(Rule):
    name = "retrace-hazard"
    summary = (
        "jax.jit built per call/iteration, mutable values on static args, "
        "jitted closures over mutable module globals"
    )

    def check_file(self, sf: SourceFile, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        findings += self._jit_in_function_bodies(sf, project)
        findings += self._mutable_static_defaults(sf)
        findings += self._mutable_global_capture(sf)
        return findings

    # -- 1. jit constructed inside function bodies ------------------------

    def _jit_in_function_bodies(
        self, sf: SourceFile, project: Project
    ) -> list[Finding]:
        findings: list[Finding] = []
        entry_fns = set(
            project.rule_option(self.name, "entry-functions", ["main"])
        )
        for fn in walk_functions(sf.tree):
            one_shot = fn.name in entry_fns or fn.name.startswith("test_")
            enclosing_loops = self._loop_lines(fn)
            for node in ast.walk(fn):
                site = None
                if isinstance(node, ast.Call) and dotted_name(node.func) in JIT_NAMES:
                    site = node
                elif isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)
                ) and node is not fn:
                    for deco in node.decorator_list:
                        if _match_wrapper(deco, JIT_NAMES) is not None:
                            site = deco
                            break
                if site is None:
                    continue
                if self._is_cached(fn, node, site):
                    continue
                in_loop = any(
                    lo <= site.lineno <= hi for lo, hi in enclosing_loops
                )
                if one_shot and not in_loop:
                    continue
                detail = (
                    "inside a loop — a fresh executable (and compile) per iteration"
                    if in_loop
                    else f"inside `{fn.name}` — a fresh jit cache per call"
                )
                findings.append(
                    Finding(
                        sf.path,
                        site.lineno,
                        site.col_offset + 1,
                        self.name,
                        f"jax.jit constructed {detail}; hoist to module level, "
                        "cache on self in __init__, or wrap the factory in "
                        "functools.lru_cache",
                    )
                )
        return findings

    @staticmethod
    def _loop_lines(fn: ast.AST) -> list[tuple[int, int]]:
        spans = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.While, ast.AsyncFor)):
                spans.append((node.lineno, node.end_lineno or node.lineno))
        return spans

    @staticmethod
    def _is_cached(
        fn: ast.FunctionDef | ast.AsyncFunctionDef, node: ast.AST, site: ast.AST
    ) -> bool:
        """Sanctioned construction-in-body shapes."""
        # self._f = jax.jit(...) inside __init__: compiled once per instance.
        if fn.name == "__init__":
            for stmt in ast.walk(fn):
                if isinstance(stmt, ast.Assign) and stmt.value is node:
                    if any(
                        isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"
                        for t in stmt.targets
                    ):
                        return True
        # Enclosing function is an lru_cache'd factory: one jit per key.
        for deco in fn.decorator_list:
            name = dotted_name(deco if not isinstance(deco, ast.Call) else deco.func)
            if name in _CACHE_DECOS:
                return True
        return False

    # -- 2. mutable / unhashable values on static parameters --------------

    def _mutable_static_defaults(self, sf: SourceFile) -> list[Finding]:
        findings: list[Finding] = []
        static_by_fn: dict[str, set[str]] = {}
        for tf in find_traced_functions(sf.tree):
            statics = tf.static_params - {"self", "cls"}
            if statics:
                static_by_fn[tf.fn.name] = statics
            args = tf.fn.args
            pos = positional_param_names(tf.fn)
            defaults = list(args.defaults)
            owners = pos[len(pos) - len(defaults) :] if defaults else []
            pairs = list(zip(owners, defaults)) + [
                (a.arg, d)
                for a, d in zip(args.kwonlyargs, args.kw_defaults)
                if d is not None
            ]
            for pname, default in pairs:
                if pname in statics and isinstance(
                    default, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp)
                ):
                    findings.append(
                        Finding(
                            sf.path,
                            default.lineno,
                            default.col_offset + 1,
                            self.name,
                            f"static parameter `{pname}` of `{tf.fn.name}` has an "
                            "unhashable (mutable) default — jit static args must "
                            "hash; use a tuple/frozen value",
                        )
                    )
        # Call sites in the same module passing list/dict/set literals to a
        # known static parameter by keyword.
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = call_name(node)
            statics = static_by_fn.get(callee or "", None) or static_by_fn.get(
                (callee or "").rsplit(".", 1)[-1], None
            )
            if not statics:
                continue
            for kw in node.keywords:
                if kw.arg in statics and isinstance(
                    kw.value, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp)
                ):
                    findings.append(
                        Finding(
                            sf.path,
                            kw.value.lineno,
                            kw.value.col_offset + 1,
                            self.name,
                            f"unhashable literal passed to static parameter "
                            f"`{kw.arg}` of `{callee}` — jit static args must "
                            "hash; pass a tuple",
                        )
                    )
        return findings

    # -- 3. jitted closures over mutable module globals -------------------

    def _mutable_global_capture(self, sf: SourceFile) -> list[Finding]:
        tree = sf.tree
        # Module-level names bound to mutable literals...
        mutable_literals: dict[str, int] = {}
        bind_counts: dict[str, int] = {}
        for stmt in tree.body:
            targets = []
            if isinstance(stmt, ast.Assign):
                targets = [t for t in stmt.targets if isinstance(t, ast.Name)]
                value = stmt.value
            elif isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
                targets, value = [stmt.target], stmt.value
            else:
                continue
            for t in targets:
                bind_counts[t.id] = bind_counts.get(t.id, 0) + 1
                if isinstance(
                    value, (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp)
                ):
                    mutable_literals[t.id] = t.lineno
        # ...that the module actually mutates (method call, subscript store,
        # `global` rebind, or repeated module-level binding).
        mutated: set[str] = {n for n, c in bind_counts.items() if c > 1}
        for node in ast.walk(tree):
            if isinstance(node, ast.Global):
                mutated.update(node.names)
            elif isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
                if node.func.attr in _MUTATORS and isinstance(
                    node.func.value, ast.Name
                ):
                    mutated.add(node.func.value.id)
            elif isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                    if isinstance(node, ast.AugAssign)
                    else node.targets
                )
                for t in targets:
                    if isinstance(t, ast.Subscript) and isinstance(t.value, ast.Name):
                        mutated.add(t.value.id)
        hazardous = {n for n in mutable_literals if n in mutated}
        if not hazardous:
            return []
        findings = []
        for tf in find_traced_functions(tree):
            # Params and locally-assigned names shadow the module global.
            local = set(positional_param_names(tf.fn))
            for node in ast.walk(tf.fn):
                if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                    local.add(node.id)
            for node in ast.walk(tf.fn):
                if (
                    isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)
                    and node.id in hazardous
                    and node.id not in local
                ):
                    findings.append(
                        Finding(
                            sf.path,
                            node.lineno,
                            node.col_offset + 1,
                            self.name,
                            f"jitted `{tf.fn.name}` reads module global "
                            f"`{node.id}`, a mutable container this module also "
                            "mutates — the value is baked at trace time; pass it "
                            "as an argument or freeze it",
                        )
                    )
        return findings
