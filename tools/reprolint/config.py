"""Configuration loading: ``[tool.reprolint]`` tables in pyproject.toml.

Uses :mod:`tomllib` when available (Python >= 3.11) and falls back to a
deliberately tiny TOML-subset reader on 3.10 (the container/CI floor).
The subset covers exactly what reprolint's own tables use: ``[a.b.c]``
headers, string / bool / int / float values, and (possibly multiline)
arrays of strings. Unknown sections are skipped wholesale, so the rest of
pyproject.toml can use any TOML it likes.

``_read_sections`` is shared with the sibling ``tools.perfguard`` (whose
``[tool.perfguard]`` budget tables use the same subset plus floats) via
the ``prefix`` parameter — one parser, two stdlib-only tools.
"""

from __future__ import annotations

import re
from pathlib import Path
from typing import Any

SECTION_PREFIX = "tool.reprolint"

DEFAULTS: dict[str, Any] = {
    "paths": ["src", "tests", "benchmarks", "examples"],
    "exclude": [],
    "baseline": "tools/reprolint/baseline.txt",
    "rules": {},  # per-rule tables: {"kernel-purity": {"globs": [...]}, ...}
}


def load_config(root: Path) -> dict[str, Any]:
    """Read ``[tool.reprolint]`` (+ sub-tables) from ``root/pyproject.toml``."""
    cfg = {k: (dict(v) if isinstance(v, dict) else list(v) if isinstance(v, list) else v)
           for k, v in DEFAULTS.items()}
    pyproject = root / "pyproject.toml"
    if not pyproject.exists():
        return cfg
    sections = _read_sections(pyproject.read_text())
    top = sections.get(SECTION_PREFIX, {})
    for key in ("paths", "exclude", "baseline"):
        if key in top:
            cfg[key] = top[key]
    for name, table in sections.items():
        if name.startswith(SECTION_PREFIX + "."):
            cfg["rules"][name[len(SECTION_PREFIX) + 1 :]] = table
    return cfg


def rule_table(cfg: dict[str, Any], rule: str) -> dict[str, Any]:
    return cfg.get("rules", {}).get(rule, {})


def _read_sections(
    text: str, prefix: str = SECTION_PREFIX
) -> dict[str, dict[str, Any]]:
    try:
        import tomllib  # Python >= 3.11

        data = tomllib.loads(text)
        out: dict[str, dict[str, Any]] = {}
        _flatten(data, "", out)
        return out
    except ModuleNotFoundError:
        return _mini_toml(text, prefix)


def _flatten(node: Any, prefix: str, out: dict[str, dict[str, Any]]) -> None:
    if not isinstance(node, dict):
        return
    scalars = {k: v for k, v in node.items() if not isinstance(v, dict)}
    if scalars and prefix:
        out.setdefault(prefix, {}).update(scalars)
    for k, v in node.items():
        if isinstance(v, dict):
            _flatten(v, f"{prefix}.{k}" if prefix else k, out)


# -- TOML-subset fallback (3.10) -----------------------------------------

_HEADER = re.compile(r"^\[([A-Za-z0-9_.\-\"]+)\]\s*(?:#.*)?$")
_KEYVAL = re.compile(r"^([A-Za-z0-9_\-]+)\s*=\s*(.*)$")


def _mini_toml(
    text: str, prefix: str = SECTION_PREFIX
) -> dict[str, dict[str, Any]]:
    sections: dict[str, dict[str, Any]] = {}
    current: dict[str, Any] | None = None
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        line = lines[i].strip()
        i += 1
        if not line or line.startswith("#"):
            continue
        m = _HEADER.match(line)
        if m:
            name = m.group(1).replace('"', "")
            if name == prefix or name.startswith(prefix + "."):
                current = sections.setdefault(name, {})
            else:
                current = None
            continue
        if current is None:
            continue
        m = _KEYVAL.match(line)
        if not m:
            continue
        key, raw = m.group(1), _strip_comment(m.group(2).strip())
        if raw.startswith("[") and "]" not in _strip_strings(raw):
            # Multiline array: accumulate (comment-stripped) lines until
            # the closing bracket.
            while i < len(lines):
                piece = _strip_comment(lines[i].strip())
                raw += " " + piece
                i += 1
                if "]" in _strip_strings(piece):
                    break
        current[key] = _parse_value(raw)
    return sections


def _strip_comment(s: str) -> str:
    """Drop a trailing ``# ...`` comment (string literals respected)."""
    stripped = _strip_strings(s)
    if "#" in stripped:
        return s[: stripped.index("#")].rstrip()
    return s


def _strip_strings(s: str) -> str:
    """Remove string literals so structural chars inside them are ignored."""
    return re.sub(r'"(?:[^"\\]|\\.)*"|\'[^\']*\'', '""', s)


def _parse_value(raw: str) -> Any:
    raw = _strip_comment(raw.strip())
    if raw.startswith("["):
        body = raw[raw.index("[") + 1 : raw.rindex("]")]
        items = [s.strip() for s in _split_top(body)]
        return [_parse_value(s) for s in items if s]
    if raw.startswith('"') and raw.endswith('"'):
        return raw[1:-1].replace('\\"', '"').replace("\\\\", "\\")
    if raw.startswith("'") and raw.endswith("'"):
        return raw[1:-1]
    if raw in ("true", "false"):
        return raw == "true"
    try:
        return int(raw)
    except ValueError:
        pass
    try:
        return float(raw)
    except ValueError:
        return raw


def _split_top(body: str) -> list[str]:
    """Split an array body on commas that are not inside string literals."""
    out, cur, in_str, quote = [], [], False, ""
    i = 0
    while i < len(body):
        ch = body[i]
        if in_str:
            cur.append(ch)
            if ch == "\\" and quote == '"' and i + 1 < len(body):
                cur.append(body[i + 1])
                i += 1
            elif ch == quote:
                in_str = False
        elif ch in "\"'":
            in_str, quote = True, ch
            cur.append(ch)
        elif ch == ",":
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
        i += 1
    if cur:
        out.append("".join(cur))
    return out
