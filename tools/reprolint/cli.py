"""Command line: ``python -m tools.reprolint [paths...]``.

Exit status is 0 when every finding is baselined (the shipped baseline
is empty, so in practice: when there are no findings), 1 otherwise.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.reprolint.config import load_config
from tools.reprolint.engine import (
    all_rules,
    apply_baseline,
    discover_files,
    lint_sources,
    load_baseline,
    write_baseline,
)
from tools.reprolint.findings import render


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="Project-specific JAX/Pallas contract checker and "
        "serving-layer race detector.",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files/directories to lint (default: [tool.reprolint] paths)",
    )
    ap.add_argument(
        "--format",
        choices=("text", "github"),
        default="text",
        help="output format (github = Actions error annotations)",
    )
    ap.add_argument(
        "--root",
        default=".",
        help="repo root (pyproject.toml location; paths resolve against it)",
    )
    ap.add_argument(
        "--select",
        default=None,
        help="comma-separated rule names to run (default: all)",
    )
    ap.add_argument(
        "--baseline",
        default=None,
        help="baseline file (default: [tool.reprolint] baseline)",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="report findings even if baselined",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="write current findings to the baseline file and exit 0",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.name:18s} {rule.summary}")
        return 0

    root = Path(args.root).resolve()
    cfg = load_config(root)
    select = (
        {s.strip() for s in args.select.split(",") if s.strip()}
        if args.select
        else None
    )
    files = discover_files(root, args.paths or cfg["paths"], cfg["exclude"])
    findings = lint_sources(files, root, cfg, select)

    baseline_path = root / (args.baseline or cfg["baseline"])
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(
            f"wrote {len(findings)} finding(s) to {baseline_path}",
            file=sys.stderr,
        )
        return 0
    if not args.no_baseline:
        findings = apply_baseline(findings, load_baseline(baseline_path))

    if findings:
        print(render(findings, args.format))
        print(
            f"\nreprolint: {len(findings)} finding(s) in {len(files)} file(s)",
            file=sys.stderr,
        )
        return 1
    print(
        f"reprolint: clean ({len(files)} files, "
        f"{len(select) if select else len(all_rules())} rules)",
        file=sys.stderr,
    )
    return 0
