"""reprolint — project-specific static analysis for the repro codebase.

Two rule families guard the contracts this reproduction lives by:

* **JAX/Pallas contract rules** — tracer leaks (Python control flow on
  traced values inside jitted / custom-VJP / kernel bodies), retracing
  hazards (``jax.jit`` constructed per call, mutable statics, jitted
  closures over mutable globals), kernel purity (host syncs and
  data-dependent Python branching under ``kernels/*/kernel.py``), and
  dtype discipline (implicit f64, dtype-less constructors in hot paths).
* **Serving race rules** — lock discipline for the threaded render
  server (shared ``self._*`` state touched by both the scheduler thread
  and client threads must be accessed under ``self._lock``), plus a
  dead-module reachability check.

Run it as ``python -m tools.reprolint [paths...]`` from the repo root.
Configuration lives in ``pyproject.toml`` under ``[tool.reprolint]``;
per-line escapes use ``# reprolint: disable=<rule>[,<rule>...]``.
See DESIGN.md §12 for the rule catalog and how to add a rule.
"""

from tools.reprolint.engine import lint_paths, lint_sources  # noqa: F401
from tools.reprolint.findings import Finding  # noqa: F401

__version__ = "1.0.0"
