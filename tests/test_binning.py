"""Tile-binning subsystem: binned raster == dense oracle, list invariants,
overflow behavior, gradient equivalence, RenderConfig plumbing."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    compute_features_fused,
    look_at_camera,
    random_gaussians,
    render,
    render_jit,
)
from repro.core.binning import bin_gaussians, tile_block_lists
from repro.core.rasterize import sort_by_depth


def _scene(n=256, seed=0, w=48, h=48, base_scale=0.03, extent=2.0):
    g = random_gaussians(
        jax.random.PRNGKey(seed), n, base_scale=base_scale, extent=extent
    )
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=w, height=h)
    return g, cam


def _dense_vs_binned(g, cam, **cfg_kw):
    dense = render(g, cam, RenderConfig(raster_path="dense"))
    cfg = RenderConfig(
        raster_path="binned", tile_capacity=g.num_gaussians, **cfg_kw
    )
    binned = render(g, cam, cfg)
    return np.asarray(dense), np.asarray(binned)


class TestBinnedMatchesDense:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_scenes(self, seed):
        g, cam = _scene(n=300, seed=seed)
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_border_straddling_gaussians(self):
        """Large-radius Gaussians overlap many tiles and cross every border."""
        g, cam = _scene(n=128, seed=5, base_scale=0.3)
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_offscreen_gaussians(self):
        g, cam = _scene(n=128, seed=6, extent=12.0)  # most miss the frustum
        feats = compute_features_fused(g, cam)
        assert float(feats.mask.sum()) < g.num_gaussians  # premise
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_partial_tiles(self):
        """Image size not divisible by tile_size: crop path + edge tiles."""
        g, cam = _scene(n=200, seed=7, w=50, h=34)
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_tile_chunking_invariant(self):
        g, cam = _scene(n=200, seed=8)
        _, a = _dense_vs_binned(g, cam, tile_chunk=None)
        _, b = _dense_vs_binned(g, cam, tile_chunk=2)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_pallas_raster_path(self):
        g, cam = _scene(n=300, seed=9, w=40, h=56)
        dense = render(g, cam, RenderConfig(raster_path="dense"))
        pallas = render(g, cam, RenderConfig(raster_path="pallas"))
        np.testing.assert_allclose(
            np.asarray(pallas), np.asarray(dense), rtol=1e-4, atol=1e-5
        )

    def test_pallas_max_blocks_cap(self):
        """Capping the per-tile block list keeps the front-most blocks and
        degrades gracefully (finite image, background may bleed through)."""
        g, cam = _scene(n=300, seed=10)
        img = render(
            g, cam, RenderConfig(raster_path="pallas", max_blocks_per_tile=1)
        )
        assert np.isfinite(np.asarray(img)).all()


class TestTileBins:
    def test_list_invariants(self):
        g, cam = _scene(n=200, seed=1)
        feats = sort_by_depth(compute_features_fused(g, cam))
        bins = bin_gaussians(feats, cam.height, cam.width, capacity=64)
        idx = np.asarray(bins.indices)
        count = np.asarray(bins.count)
        n = g.num_gaussians
        assert bins.tiles_y == 3 and bins.tiles_x == 3  # 48/16
        for t in range(bins.num_tiles):
            k = count[t]
            valid = idx[t, :k]
            assert (valid < n).all()
            assert (np.diff(valid) > 0).all()  # ascending = front-to-back
            assert (idx[t, k:] == n).all()  # sentinel padding

    def test_overflow_keeps_front_most(self):
        g, cam = _scene(n=300, seed=2, base_scale=0.3)  # heavy overlap
        feats = sort_by_depth(compute_features_fused(g, cam))
        full = bin_gaussians(feats, cam.height, cam.width, capacity=300)
        tiny = bin_gaussians(feats, cam.height, cam.width, capacity=8)
        assert bool(np.asarray(tiny.overflowed).any())  # premise
        # The tiny list must be the PREFIX of the full list (front-most win).
        f = np.asarray(full.indices)
        t = np.asarray(tiny.indices)
        for i in range(full.num_tiles):
            k = min(8, int(np.asarray(full.count)[i]))
            np.testing.assert_array_equal(t[i, :k], f[i, :k])

    def test_overflow_renders_finite_and_conservative(self):
        """Dropping back-most Gaussians can only let more background through;
        the image stays finite and valid."""
        g, cam = _scene(n=300, seed=3, base_scale=0.3)
        img = render(
            g,
            cam,
            RenderConfig(raster_path="binned", tile_capacity=8),
        )
        assert np.isfinite(np.asarray(img)).all()

    def test_block_lists_cover_index_lists(self):
        """Every Gaussian on a tile's index list lives in a block on that
        tile's block list (the kernel sees a superset of the exact list)."""
        g, cam = _scene(n=300, seed=4)
        feats = sort_by_depth(compute_features_fused(g, cam))
        bins = bin_gaussians(feats, cam.height, cam.width, capacity=300)
        block_ids, num_blocks, _ = tile_block_lists(
            feats, cam.height, cam.width, block_g=128
        )
        idx = np.asarray(bins.indices)
        count = np.asarray(bins.count)
        blocks = np.asarray(block_ids)
        for t in range(bins.num_tiles):
            need = set(idx[t, : count[t]] // 128)
            have = set(b for b in blocks[t] if b < num_blocks)
            assert need <= have, (t, need - have)


class TestGradientEquivalence:
    def test_binned_grads_match_dense(self):
        g, cam = _scene(n=96, seed=0, w=32, h=32)
        target = jnp.linspace(0, 1, 32 * 32 * 3).reshape(32, 32, 3)

        def loss(gg, cfg):
            return jnp.mean((render(gg, cam, cfg) - target) ** 2)

        g_dense = jax.grad(loss)(
            g, RenderConfig(raster_path="dense", pixel_chunk=None)
        )
        g_binned = jax.grad(loss)(
            g, RenderConfig(raster_path="binned", tile_capacity=96)
        )
        for name in ["positions", "quats", "log_scales", "sh", "opacity_logit"]:
            a = np.asarray(getattr(g_dense, name))
            b = np.asarray(getattr(g_binned, name))
            assert np.isfinite(b).all(), name
            np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-6, err_msg=name)


class TestRenderConfig:
    def test_hashable_and_jit_static(self):
        cfg = RenderConfig(background=[0.1, 0.2, 0.3])  # list normalizes
        assert hash(cfg) == hash(RenderConfig(background=(0.1, 0.2, 0.3)))
        g, cam = _scene(n=64, w=32, h=32)
        img = render_jit(g, cam, cfg)
        assert img.shape == (32, 32, 3)

    def test_invalid_paths_rejected(self):
        with pytest.raises(ValueError):
            RenderConfig(feature_path="bogus")
        with pytest.raises(ValueError):
            RenderConfig(raster_path="bogus")

    def test_legacy_kwargs_shim_warns_and_matches(self):
        g, cam = _scene(n=64, w=32, h=32)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            old = render(g, cam, feature_path="staged", pixel_chunk=None)
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)
        new = render(
            g,
            cam,
            RenderConfig(feature_path="staged", pixel_chunk=None),
        )
        np.testing.assert_allclose(np.asarray(old), np.asarray(new), atol=1e-7)
