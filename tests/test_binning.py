"""Tile-binning subsystem: binned raster == dense oracle, list invariants,
overflow behavior, gather-to-compact stage, early-exit blending, gradient
equivalence (jnp binned and compact-Pallas paths), RenderConfig plumbing."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    clustered_gaussians,
    compute_features_fused,
    look_at_camera,
    random_gaussians,
    render,
    render_jit,
)
from repro.core.binning import (
    EARLY_EXIT_EPS,
    bin_gaussians,
    compact_tile_features,
    lane_occupancy_stats,
    tile_block_lists,
)
from repro.core.rasterize import sort_by_depth


def _scene(n=256, seed=0, w=48, h=48, base_scale=0.03, extent=2.0):
    g = random_gaussians(
        jax.random.PRNGKey(seed), n, base_scale=base_scale, extent=extent
    )
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=w, height=h)
    return g, cam


def _dense_vs_binned(g, cam, **cfg_kw):
    dense = render(g, cam, RenderConfig(raster_path="dense"))
    cfg = RenderConfig(
        raster_path="binned", tile_capacity=g.num_gaussians, **cfg_kw
    )
    binned = render(g, cam, cfg)
    return np.asarray(dense), np.asarray(binned)


class TestBinnedMatchesDense:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_scenes(self, seed):
        g, cam = _scene(n=300, seed=seed)
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_border_straddling_gaussians(self):
        """Large-radius Gaussians overlap many tiles and cross every border."""
        g, cam = _scene(n=128, seed=5, base_scale=0.3)
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_offscreen_gaussians(self):
        g, cam = _scene(n=128, seed=6, extent=12.0)  # most miss the frustum
        feats = compute_features_fused(g, cam)
        assert float(feats.mask.sum()) < g.num_gaussians  # premise
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_partial_tiles(self):
        """Image size not divisible by tile_size: crop path + edge tiles."""
        g, cam = _scene(n=200, seed=7, w=50, h=34)
        dense, binned = _dense_vs_binned(g, cam)
        np.testing.assert_allclose(binned, dense, atol=1e-5)

    def test_tile_chunking_invariant(self):
        g, cam = _scene(n=200, seed=8)
        _, a = _dense_vs_binned(g, cam, tile_chunk=None)
        _, b = _dense_vs_binned(g, cam, tile_chunk=2)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_pallas_raster_path(self):
        g, cam = _scene(n=300, seed=9, w=40, h=56)
        dense = render(g, cam, RenderConfig(raster_path="dense"))
        pallas = render(g, cam, RenderConfig(raster_path="pallas"))
        np.testing.assert_allclose(
            np.asarray(pallas), np.asarray(dense), rtol=1e-4, atol=1e-5
        )

    def test_pallas_max_blocks_cap(self):
        """Capping the per-tile block list keeps the front-most blocks and
        degrades gracefully (finite image, background may bleed through)."""
        g, cam = _scene(n=300, seed=10)
        img = render(
            g, cam, RenderConfig(raster_path="pallas", max_blocks_per_tile=1)
        )
        assert np.isfinite(np.asarray(img)).all()


class TestTileBins:
    def test_list_invariants(self):
        g, cam = _scene(n=200, seed=1)
        feats = sort_by_depth(compute_features_fused(g, cam))
        bins = bin_gaussians(feats, cam.height, cam.width, capacity=64)
        idx = np.asarray(bins.indices)
        count = np.asarray(bins.count)
        n = g.num_gaussians
        assert bins.tiles_y == 3 and bins.tiles_x == 3  # 48/16
        for t in range(bins.num_tiles):
            k = count[t]
            valid = idx[t, :k]
            assert (valid < n).all()
            assert (np.diff(valid) > 0).all()  # ascending = front-to-back
            assert (idx[t, k:] == n).all()  # sentinel padding

    def test_sort_and_topk_selections_identical(self):
        """The two selection primitives are interchangeable — pinned so the
        "sort" default (ROADMAP flip, ~5x faster binning on CPU) can never
        drift from the original top_k lists."""
        for seed, base_scale in ((1, 0.03), (2, 0.3)):  # sparse + overflowing
            g, cam = _scene(n=300, seed=seed, base_scale=base_scale)
            feats = sort_by_depth(compute_features_fused(g, cam))
            by_sort = bin_gaussians(
                feats, cam.height, cam.width, capacity=32, select="sort"
            )
            by_topk = bin_gaussians(
                feats, cam.height, cam.width, capacity=32, select="topk"
            )
            np.testing.assert_array_equal(
                np.asarray(by_sort.indices), np.asarray(by_topk.indices)
            )
            np.testing.assert_array_equal(
                np.asarray(by_sort.count), np.asarray(by_topk.count)
            )
            np.testing.assert_array_equal(
                np.asarray(by_sort.overflowed), np.asarray(by_topk.overflowed)
            )

    def test_default_select_is_sort(self):
        """The ROADMAP default flip: bare calls get the sorted-prefix path."""
        import inspect

        sig = inspect.signature(bin_gaussians)
        assert sig.parameters["select"].default == "sort"
        with pytest.raises(ValueError, match="select"):
            g, cam = _scene(n=32)
            feats = sort_by_depth(compute_features_fused(g, cam))
            bin_gaussians(feats, cam.height, cam.width, select="heap")

    def test_overflow_keeps_front_most(self):
        g, cam = _scene(n=300, seed=2, base_scale=0.3)  # heavy overlap
        feats = sort_by_depth(compute_features_fused(g, cam))
        full = bin_gaussians(feats, cam.height, cam.width, capacity=300)
        tiny = bin_gaussians(feats, cam.height, cam.width, capacity=8)
        assert bool(np.asarray(tiny.overflowed).any())  # premise
        # The tiny list must be the PREFIX of the full list (front-most win).
        f = np.asarray(full.indices)
        t = np.asarray(tiny.indices)
        for i in range(full.num_tiles):
            k = min(8, int(np.asarray(full.count)[i]))
            np.testing.assert_array_equal(t[i, :k], f[i, :k])

    def test_overflow_renders_finite_and_conservative(self):
        """Dropping back-most Gaussians can only let more background through;
        the image stays finite and valid."""
        g, cam = _scene(n=300, seed=3, base_scale=0.3)
        img = render(
            g,
            cam,
            RenderConfig(raster_path="binned", tile_capacity=8),
        )
        assert np.isfinite(np.asarray(img)).all()

    def test_block_lists_cover_index_lists(self):
        """Every Gaussian on a tile's index list lives in a block on that
        tile's block list (the kernel sees a superset of the exact list)."""
        g, cam = _scene(n=300, seed=4)
        feats = sort_by_depth(compute_features_fused(g, cam))
        bins = bin_gaussians(feats, cam.height, cam.width, capacity=300)
        block_ids, num_blocks, _ = tile_block_lists(
            feats, cam.height, cam.width, block_g=128
        )
        idx = np.asarray(bins.indices)
        count = np.asarray(bins.count)
        blocks = np.asarray(block_ids)
        for t in range(bins.num_tiles):
            need = set(idx[t, : count[t]] // 128)
            have = set(b for b in blocks[t] if b < num_blocks)
            assert need <= have, (t, need - have)


class TestCompaction:
    def test_compact_equals_gather_over_bins(self):
        """The compact tensor IS the feature gather over TileBins.indices."""
        g, cam = _scene(n=200, seed=1)
        feats = sort_by_depth(compute_features_fused(g, cam))
        bins = bin_gaussians(feats, cam.height, cam.width, capacity=64)
        compact = np.asarray(compact_tile_features(feats, bins))
        assert compact.shape == (bins.num_tiles, bins.capacity, 11)

        rec = np.concatenate(
            [
                np.asarray(feats.uv),
                np.asarray(feats.conic),
                np.asarray(feats.color),
                np.asarray(feats.radius)[:, None],
                np.asarray(feats.opacity)[:, None],
                np.asarray(feats.mask)[:, None],
            ],
            axis=-1,
        )
        rec_pad = np.concatenate([rec, np.zeros((1, 11), rec.dtype)])
        np.testing.assert_array_equal(
            compact, rec_pad[np.asarray(bins.indices)]
        )

    def test_compact_sentinel_rows_zero(self):
        g, cam = _scene(n=128, seed=2)
        feats = sort_by_depth(compute_features_fused(g, cam))
        bins = bin_gaussians(feats, cam.height, cam.width, capacity=128)
        compact = np.asarray(compact_tile_features(feats, bins))
        count = np.asarray(bins.count)
        for t in range(bins.num_tiles):
            np.testing.assert_array_equal(compact[t, count[t]:], 0.0)

    def test_compact_overflow_prefix(self):
        """A capacity-k compaction is the first k rows of the full one."""
        g, cam = _scene(n=300, seed=2, base_scale=0.3)  # heavy overlap
        feats = sort_by_depth(compute_features_fused(g, cam))
        full = bin_gaussians(feats, cam.height, cam.width, capacity=300)
        tiny = bin_gaussians(feats, cam.height, cam.width, capacity=8)
        assert bool(np.asarray(tiny.overflowed).any())  # premise
        c_full = np.asarray(compact_tile_features(feats, full))
        c_tiny = np.asarray(compact_tile_features(feats, tiny))
        np.testing.assert_array_equal(c_tiny, c_full[:, :8])

    def test_kernel_operands_match_compact_tensor(self):
        """The ops-level packed-row compaction the Pallas kernel streams is
        the same gather compact_tile_features defines — pinned so the two
        implementations cannot drift."""
        from repro.kernels.gaussian_features.ref import pack_features
        from repro.kernels.tile_rasterize.ops import build_compact_operands

        g, cam = _scene(n=200, seed=1)
        feats = sort_by_depth(compute_features_fused(g, cam))
        compact_ops, nsteps, bins, steps = build_compact_operands(
            pack_features(feats), cam.height, cam.width, capacity=64
        )
        want = np.asarray(compact_tile_features(feats, bins))  # (T, K, 11)
        # Kernel layout: (12, T*K_pad) with rows [uv, conic, color, depth,
        # radius, opacity, mask]; K padded to whole block_g chunks.
        k_pad = compact_ops.shape[1] // bins.num_tiles
        got = np.asarray(compact_ops).reshape(12, bins.num_tiles, k_pad)
        got = got.transpose(1, 2, 0)  # (T, K_pad, 12)
        rows_no_depth = list(range(8)) + [9, 10, 11]
        np.testing.assert_array_equal(
            got[:, : bins.capacity, rows_no_depth], want
        )
        np.testing.assert_array_equal(got[:, bins.capacity:], 0.0)  # padding
        np.testing.assert_array_equal(
            np.asarray(nsteps),
            np.ceil(np.asarray(bins.count) / 128.0),
        )
        assert steps * 128 == k_pad

    def test_clustered_occupancy_beats_block_lists(self):
        """On a non-uniform scene the compacted lists keep lanes live where
        128-wide depth-consecutive blocks blend mostly masked lanes."""
        g = clustered_gaussians(jax.random.PRNGKey(0), 2048)
        cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=128, height=128)
        feats = sort_by_depth(compute_features_fused(g, cam))
        occ = lane_occupancy_stats(feats, cam.height, cam.width)
        assert occ["compact_occupancy"] > occ["block_occupancy"]
        assert occ["live_lanes"] <= occ["compact_lanes"] <= occ["block_lanes"]


class TestEarlyExit:
    def test_early_exit_is_noop_on_unsaturated_scene(self):
        g, cam = _scene(n=256, seed=4)
        on = render(
            g, cam, RenderConfig(raster_path="binned", early_exit=True)
        )
        off = render(
            g, cam, RenderConfig(raster_path="binned", early_exit=False)
        )
        np.testing.assert_allclose(np.asarray(on), np.asarray(off), atol=1e-6)

    def test_early_exit_error_bounded_on_saturated_scene(self):
        """Opaque wall of Gaussians: the scan stops early; anything dropped
        is below one u8 quantization step per channel."""
        g, cam = _scene(n=400, seed=5, base_scale=0.5)
        dense = render(g, cam, RenderConfig(raster_path="dense"))
        # tile_chunk=1 exits per tile — the most aggressive skip granularity.
        ee = render(
            g,
            cam,
            RenderConfig(
                raster_path="binned",
                tile_capacity=400,
                tile_chunk=1,
                early_exit=True,
            ),
        )
        err = float(jnp.max(jnp.abs(ee - dense)))
        assert np.isfinite(np.asarray(ee)).all()
        # Dropped contribution per pixel <= t_exit * max_color; colors in
        # this scene reach ~2, hence the small multiple of the threshold.
        assert err <= 4 * EARLY_EXIT_EPS, err

    @pytest.mark.slow  # grad-of-scan-of-cond compile, ~17s
    def test_early_exit_differentiable(self):
        g, cam = _scene(n=96, seed=6, w=32, h=32)
        target = jnp.zeros((32, 32, 3))

        def loss(gg):
            cfg = RenderConfig(raster_path="binned", early_exit=True)
            return jnp.mean((render(gg, cam, cfg) - target) ** 2)

        grads = jax.grad(loss)(g)
        for leaf in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(leaf)).all()


class TestPallasBinnedPath:
    def test_forward_matches_dense(self):
        g, cam = _scene(n=300, seed=9, w=40, h=56)
        dense = render(g, cam, RenderConfig(raster_path="dense"))
        compact = render(
            g,
            cam,
            RenderConfig(raster_path="pallas_binned", tile_capacity=300),
        )
        np.testing.assert_allclose(
            np.asarray(compact), np.asarray(dense), rtol=1e-4, atol=1e-5
        )

    def test_capacity_capped_matches_binned(self):
        """Same lists -> same semantics: the compact kernel under overflow
        reproduces the jnp binned path at the same capacity exactly."""
        g, cam = _scene(n=300, seed=3, base_scale=0.3)
        binned = render(
            g, cam, RenderConfig(raster_path="binned", tile_capacity=8)
        )
        compact = render(
            g,
            cam,
            RenderConfig(raster_path="pallas_binned", tile_capacity=8),
        )
        np.testing.assert_allclose(
            np.asarray(compact), np.asarray(binned), rtol=1e-4, atol=1e-5
        )

    def test_render_loss_grads_match_jnp_binned(self):
        """The acceptance bar: pallas_binned trains — render_loss gradients
        through the compact kernel's custom VJP match the differentiable
        jnp binned path to 1e-4 on every parameter leaf."""
        from repro.core.train3dgs import render_loss

        g, cam = _scene(n=96, seed=0, w=32, h=32)
        target = jnp.linspace(0, 1, 32 * 32 * 3).reshape(32, 32, 3)

        g_jnp = jax.grad(render_loss)(
            g,
            cam,
            target,
            RenderConfig(
                raster_path="binned", tile_capacity=96, early_exit=False
            ),
        )
        g_pal = jax.grad(render_loss)(
            g,
            cam,
            target,
            RenderConfig(raster_path="pallas_binned", tile_capacity=96),
        )
        for name in ["positions", "quats", "log_scales", "sh", "opacity_logit"]:
            a = np.asarray(getattr(g_jnp, name))
            b = np.asarray(getattr(g_pal, name))
            assert np.isfinite(b).all(), name
            np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-6, err_msg=name)


class TestGradientEquivalence:
    def test_binned_grads_match_dense(self):
        g, cam = _scene(n=96, seed=0, w=32, h=32)
        target = jnp.linspace(0, 1, 32 * 32 * 3).reshape(32, 32, 3)

        def loss(gg, cfg):
            return jnp.mean((render(gg, cam, cfg) - target) ** 2)

        g_dense = jax.grad(loss)(
            g, RenderConfig(raster_path="dense", pixel_chunk=None)
        )
        g_binned = jax.grad(loss)(
            g, RenderConfig(raster_path="binned", tile_capacity=96)
        )
        for name in ["positions", "quats", "log_scales", "sh", "opacity_logit"]:
            a = np.asarray(getattr(g_dense, name))
            b = np.asarray(getattr(g_binned, name))
            assert np.isfinite(b).all(), name
            np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-6, err_msg=name)


class TestRenderConfig:
    def test_hashable_and_jit_static(self):
        cfg = RenderConfig(background=[0.1, 0.2, 0.3])  # list normalizes
        assert hash(cfg) == hash(RenderConfig(background=(0.1, 0.2, 0.3)))
        g, cam = _scene(n=64, w=32, h=32)
        img = render_jit(g, cam, cfg)
        assert img.shape == (32, 32, 3)

    def test_invalid_paths_rejected(self):
        with pytest.raises(ValueError):
            RenderConfig(feature_path="bogus")
        with pytest.raises(ValueError):
            RenderConfig(raster_path="bogus")

    def test_legacy_kwargs_shim_warns_and_matches(self):
        g, cam = _scene(n=64, w=32, h=32)
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            old = render(g, cam, feature_path="staged", pixel_chunk=None)
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)
        new = render(
            g,
            cam,
            RenderConfig(feature_path="staged", pixel_chunk=None),
        )
        np.testing.assert_allclose(np.asarray(old), np.asarray(new), atol=1e-7)
