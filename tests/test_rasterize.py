"""Rasterizer correctness + property tests (blending invariants)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    RenderConfig,
    compute_features_staged,
    look_at_camera,
    random_gaussians,
    render,
)
from repro.core.rasterize import accumulated_alpha, rasterize, sort_by_depth
from repro.core.train3dgs import gsplat_loss, ssim


def _scene(n=256, seed=0, size=48):
    g = random_gaussians(jax.random.PRNGKey(seed), n)
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=size, height=size)
    return g, cam


class TestBlending:
    def test_coverage_in_unit_interval(self):
        g, cam = _scene()
        feats = compute_features_staged(g, cam)
        cov = np.asarray(accumulated_alpha(feats, cam.height, cam.width))
        assert cov.min() >= 0.0 and cov.max() <= 1.0

    def test_background_fills_empty_pixels(self):
        g, cam = _scene(n=1)
        g.opacity_logit = jnp.full_like(g.opacity_logit, -30.0)  # invisible
        img = render(g, cam, RenderConfig(background=(0.25, 0.5, 0.75)))
        np.testing.assert_allclose(img[0, 0], [0.25, 0.5, 0.75], atol=1e-5)
        np.testing.assert_allclose(img[-1, -1], [0.25, 0.5, 0.75], atol=1e-5)

    def test_transmittance_monotone_in_gaussian_count(self):
        """Adding Gaussians can only decrease transmittance (raise coverage)."""
        g, cam = _scene(n=128)
        f_all = compute_features_staged(g, cam)
        half = jax.tree.map(lambda x: x[:64], g)
        f_half = compute_features_staged(half, cam)
        cov_all = np.asarray(accumulated_alpha(f_all, cam.height, cam.width))
        cov_half = np.asarray(accumulated_alpha(f_half, cam.height, cam.width))
        assert (cov_all - cov_half).min() >= -1e-5

    def test_pixel_chunking_invariant(self):
        g, cam = _scene()
        feats = compute_features_staged(g, cam)
        a = rasterize(feats, cam.height, cam.width, pixel_chunk=None)
        b = rasterize(feats, cam.height, cam.width, pixel_chunk=256)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_sort_puts_culled_last(self):
        g, cam = _scene()
        feats = compute_features_staged(g, cam)
        s = sort_by_depth(feats)
        m = np.asarray(s.mask)
        if (m == 0).any() and (m == 1).any():
            first_invalid = int(np.argmin(m))
            assert m[first_invalid:].max() == 0.0

    def test_gradients_flow_to_all_params(self):
        g, cam = _scene(n=64, size=32)
        target = jnp.zeros((32, 32, 3))
        cfg = RenderConfig(pixel_chunk=None)

        def loss(g):
            return jnp.mean((render(g, cam, cfg) - target) ** 2)

        grads = jax.grad(loss)(g)
        for name in ["positions", "quats", "log_scales", "sh", "opacity_logit"]:
            gn = float(jnp.linalg.norm(getattr(grads, name)))
            assert np.isfinite(gn) and gn > 0.0, name


class TestSSIM:
    def test_identity(self):
        img = jax.random.uniform(jax.random.PRNGKey(0), (32, 32, 3))
        assert abs(float(ssim(img, img)) - 1.0) < 1e-6

    def test_range_and_symmetry(self):
        k = jax.random.PRNGKey(1)
        a = jax.random.uniform(k, (32, 32, 3))
        b = jax.random.uniform(jax.random.fold_in(k, 1), (32, 32, 3))
        s_ab, s_ba = float(ssim(a, b)), float(ssim(b, a))
        assert -1.0 <= s_ab <= 1.0
        assert abs(s_ab - s_ba) < 1e-6
        assert s_ab < 0.9  # independent noise is dissimilar

    def test_loss_zero_on_match(self):
        img = jax.random.uniform(jax.random.PRNGKey(2), (24, 24, 3))
        assert float(gsplat_loss(img, img)) < 1e-6
