"""Golden NEGATIVE: sanctioned jit-construction shapes."""
import functools

import jax

FROZEN = (1, 2, 3)  # immutable module global — fine to close over

module_level = jax.jit(lambda x: x * 2)  # module scope — fine


class Server:
    def __init__(self, f):
        self._f = jax.jit(f)  # cached on self in __init__ — fine

    def call(self, x):
        return self._f(x)


@functools.lru_cache(maxsize=8)
def jit_factory(n):
    return jax.jit(lambda x: x * n)  # lru_cache'd factory — fine


@jax.jit
def reads_frozen_global(x):
    return x * FROZEN[0]  # immutable capture — fine


def main():
    step = jax.jit(lambda x: x + 1)  # single-invocation entry point — fine
    return step(0)


def test_something():
    f = jax.jit(lambda x: x * 3)  # a test body runs once — fine
    assert f(1) == 3
