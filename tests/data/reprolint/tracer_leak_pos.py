"""Golden POSITIVE: every flagged line is a real tracer leak."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def branch_on_traced(x):
    if x > 0:  # LINE: if
        return x
    return -x


@jax.jit
def loop_on_traced(x):
    while x.sum() > 1:  # LINE: while
        x = x * 0.5
    return x


@jax.jit
def assert_on_traced(x):
    assert x.min() >= 0  # LINE: assert
    return x


@jax.jit
def bool_of_traced(x):
    flag = bool(x)  # LINE: bool
    return x if flag else -x


@functools.partial(jax.jit, static_argnames=("mode",))
def branch_on_flowed(x, mode):
    y = jnp.abs(x) + 1.0
    if y[0] > 2.0:  # LINE: flowed — y taints from x through arithmetic
        return y
    return x


@jax.custom_vjp
def custom_op(x):
    if x > 0:  # LINE: custom_vjp primal traces too
        return x
    return -x
