"""Golden POSITIVE: unfenced timing of async device work (benchmarks path)."""
import time

from somekernel import launch_render  # noqa: F401


def unfenced_benchmark(g):
    t0 = time.perf_counter()  # LINE: region measures dispatch, not compute
    img = launch_render(g)  # device work, never fenced
    dt = time.perf_counter() - t0
    return img, dt


def unfenced_time_time(g):
    t0 = time.time()
    out = launch_render(g)
    print("still launching...")
    wall = time.time() - t0  # flagged via the same t0 region
    return out, wall
