"""Golden POSITIVE: racy threaded server (synthetic src/repro/serve path)."""
import threading


class RacyServer:
    def __init__(self):
        self._lock = threading.Lock()
        self._stats = {}  # written by both sides
        self._pending = 0  # locked in submit, lock-free in the loop
        self._thread = None

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        while True:
            self._stats["served"] = self._stats.get("served", 0) + 1  # LINE
            self._pending -= 1  # LINE: mixed discipline

    def submit(self, item):
        with self._lock:
            self._pending += 1
        self._stats["submitted"] = item  # LINE: cross-thread, lock-free
