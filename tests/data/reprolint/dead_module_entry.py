"""Golden fixture: the entry-point root (mapped to examples/entry.py)."""
from repro.deadfix.used import helper  # keeps `used` alive

print(helper())
