"""Golden fixture: every suppression form silences a real violation."""
import jax


def inline_form(f, x):
    g = jax.jit(f)  # reprolint: disable=retrace-hazard -- fixture rationale
    return g(x)


def standalone_form(f, x):
    # reprolint: disable=retrace-hazard -- a standalone comment covers the
    # next code line, skipping past this continuation comment line.
    g = jax.jit(f)
    return g(x)


def still_fires(f, x):
    g = jax.jit(f)  # LINE: no suppression — must still be reported
    return g(x)
