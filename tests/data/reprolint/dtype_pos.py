"""Golden POSITIVE: dtype-discipline breaches (synthetic src/repro/core path)."""
import jax.numpy as jnp
import numpy as np


def implicit_widths(n):
    idx = jnp.arange(n)  # LINE: dtype-less arange
    acc = jnp.zeros((n,))  # LINE: dtype-less zeros
    one = jnp.ones((n, 3))  # LINE: dtype-less ones
    buf = jnp.empty((n,))  # LINE: dtype-less empty
    host = np.asarray([1.0, 2.0], dtype=np.float64)  # LINE: explicit f64
    wide = jnp.asarray(host, dtype=jnp.float64)  # LINE: explicit f64
    return idx, acc, one, buf, wide
