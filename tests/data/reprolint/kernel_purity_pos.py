"""Golden POSITIVE: impurity inside a Pallas kernel module.

Linted under the synthetic path ``src/repro/kernels/fx/kernel.py`` so the
kernel-purity globs apply.
"""
import numpy as np


def bad_kernel(x_ref, o_ref):
    v = x_ref[...]
    print("tracing")  # LINE: trace-time side effect
    host = np.asarray(v)  # LINE: host materialization
    s = v.sum().item()  # LINE: host sync
    if v[0] > 0:  # LINE: branch baked on Ref-loaded data
        o_ref[...] = v + s
    else:
        o_ref[...] = v - host.mean()
