"""Golden NEGATIVE: a pure kernel body (synthetic kernels/*/kernel.py path)."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def good_kernel(x_ref, o_ref, *, early_exit: bool):
    v = x_ref[...]
    if early_exit:  # static Python-level parameter — fine
        pl.debug_print("skipping")  # sanctioned debug print
        o_ref[...] = jnp.zeros_like(v)
        return
    o_ref[...] = jnp.where(v > 0, v, -v)  # data-dependence via where — fine
