"""Golden NEGATIVE: properly fenced timings and host-only regions."""
import time

import jax

from somekernel import launch_render  # noqa: F401


def fenced_benchmark(g):
    t0 = time.perf_counter()
    img = jax.block_until_ready(launch_render(g))
    dt = time.perf_counter() - t0  # fenced — fine
    return img, dt


def fenced_via_item(g):
    t0 = time.perf_counter()
    loss = launch_render(g).sum().item()  # .item() syncs — fine
    return loss, time.perf_counter() - t0


def host_only_region():
    t0 = time.perf_counter()
    total = sum(range(1000))  # no device work in the region
    return total, time.perf_counter() - t0
