"""Golden NEGATIVE: disciplined threaded server (src/repro/serve path)."""
import queue
import threading


class DisciplinedServer:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = queue.Queue()  # allowlisted thread-safe container
        self._stats = {}
        self._scratch = []  # scheduler-private: only the loop touches it
        self._thread = None  # pre-thread init is exempt

    def start(self):
        with self._lock:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def _loop(self):
        while True:
            item = self._queue.get()
            self._scratch.append(item)  # single-side access — fine
            with self._lock:
                self._stats["served"] = self._stats.get("served", 0) + 1

    def submit(self, item):
        self._queue.put(item)  # safe-attrs allowlist
        with self._lock:
            self._stats["submitted"] = item

    def stats(self):
        with self._lock:
            return dict(self._stats)
