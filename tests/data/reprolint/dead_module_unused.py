"""Golden POSITIVE: nothing imports this (src/repro/deadfix/unused.py)."""


def never_called():
    return "dead"
