"""Golden POSITIVE: retracing hazards the rule must flag."""
import functools

import jax

REGISTRY = {}  # mutable module global...
REGISTRY["k"] = 1  # ...that the module mutates


def fresh_jit_per_call(f, x):
    g = jax.jit(f)  # LINE: fresh jit cache per call
    return g(x)


def jit_in_loop(fns, x):
    out = []
    for f in fns:
        out.append(jax.jit(f)(x))  # LINE: compile per iteration
    return out


def decorated_inner(x):
    @jax.jit  # LINE: fresh decorated jit per enclosing call
    def inner(y):
        return y * 2

    return inner(x)


@jax.jit
def reads_mutable_global(x):
    return x * REGISTRY["k"]  # LINE: baked at trace time


@functools.partial(jax.jit, static_argnames=("axes",))
def mutable_static_default(x, axes=[0, 1]):  # LINE: unhashable static default
    return x.sum()


def main():
    for _ in range(3):
        f = jax.jit(lambda v: v + 1)  # LINE: loop beats the main() exemption
        f(0)

