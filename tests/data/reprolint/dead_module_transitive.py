"""Golden NEGATIVE: reachable transitively (src/repro/deadfix/transitive.py)."""


def value():
    return 42
