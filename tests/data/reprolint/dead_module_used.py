"""Golden NEGATIVE: reachable from the entry root (src/repro/deadfix/used.py)."""
from repro.deadfix import transitive  # noqa: F401


def helper():
    return transitive.value()
