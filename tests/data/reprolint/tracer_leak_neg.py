"""Golden NEGATIVE: static-value branching that must stay silent."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode", "n"))
def branch_on_static(x, mode, n):
    if mode == "fast":  # static argument — fine
        return x
    for _ in range(n):  # static trip count — fine
        x = x + 1
    return x


@jax.jit
def branch_on_shape(x):
    if x.shape[0] > 2:  # .shape is trace-time static — fine
        return x
    if x.ndim == 1 and x.dtype == jnp.float32:  # static attrs — fine
        return x[None]
    return x


@jax.jit
def untainted_locals(x):
    n = len([1, 2, 3])  # host value, no flow from x
    if n > 2:  # fine
        return x * n
    return x


def plain_python(x):
    if x > 0:  # not traced at all — fine
        return x
    return -x
