"""Golden fixture: disable-file silences the whole module for one rule."""
# reprolint: disable-file=retrace-hazard -- fixture: whole-module waiver
import jax


def first(f, x):
    return jax.jit(f)(x)


def second(f, x):
    return jax.jit(f)(x)
