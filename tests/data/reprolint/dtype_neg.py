"""Golden NEGATIVE: explicit operand-plane widths everywhere."""
import jax.numpy as jnp
import numpy as np


def explicit_widths(n):
    idx = jnp.arange(n, dtype=jnp.int32)
    acc = jnp.zeros((n,), dtype=jnp.float32)
    one = jnp.ones((n, 3), jnp.float32)  # positional dtype — fine
    host = np.asarray([1.0, 2.0], dtype=np.float32)
    return idx, acc, one, host
