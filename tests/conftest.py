"""Shared fixtures. NOTE: no global XLA_FLAGS here — smoke tests must see the
real single-device CPU; multi-device tests spawn subprocesses (see
``run_multidevice`` fixture) so the 512-device dry-run env never leaks in.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def repo_root() -> str:
    return REPO


def _multidevice_env(devices: int) -> dict[str, str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
        " --xla_disable_hlo_passes=all-reduce-promotion"
    )
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO, env.get("PYTHONPATH", "")]
    )
    return env


_DEVICE_PROBE_CACHE: dict[int, int] = {}


def _forced_device_count(devices: int) -> int:
    """How many devices a subprocess actually sees under the forced flag."""
    if devices not in _DEVICE_PROBE_CACHE:
        proc = subprocess.run(
            [sys.executable, "-c", "import jax; print(jax.device_count())"],
            capture_output=True,
            text=True,
            env=_multidevice_env(devices),
            timeout=120,
        )
        try:
            _DEVICE_PROBE_CACHE[devices] = int(proc.stdout.strip().split()[-1])
        except (ValueError, IndexError):
            _DEVICE_PROBE_CACHE[devices] = 0
    return _DEVICE_PROBE_CACHE[devices]


@pytest.fixture(scope="session")
def run_multidevice():
    """Run a python snippet in a subprocess with N fake host devices.

    Skips (rather than fails) when the host cannot expose the requested
    device count — e.g. a backend that ignores
    ``--xla_force_host_platform_device_count``.
    """

    def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
        available = _forced_device_count(devices)
        if available < devices:
            pytest.skip(
                f"host exposes {available} devices; test needs {devices}"
            )
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True,
            text=True,
            env=_multidevice_env(devices),
            timeout=timeout,
        )
        if proc.returncode != 0:
            raise AssertionError(
                f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
            )
        return proc.stdout

    return _run
