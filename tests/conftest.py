"""Shared fixtures. NOTE: no global XLA_FLAGS here — smoke tests must see the
real single-device CPU; multi-device tests spawn subprocesses (see
``run_multidevice`` fixture) so the 512-device dry-run env never leaks in.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def repo_root() -> str:
    return REPO


@pytest.fixture(scope="session")
def run_multidevice():
    """Run a python snippet in a subprocess with N fake host devices."""

    def _run(code: str, devices: int = 8, timeout: int = 600) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}"
            " --xla_disable_hlo_passes=all-reduce-promotion"
        )
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(REPO, "src"), REPO, env.get("PYTHONPATH", "")]
        )
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True,
            text=True,
            env=env,
            timeout=timeout,
        )
        if proc.returncode != 0:
            raise AssertionError(
                f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
            )
        return proc.stdout

    return _run
