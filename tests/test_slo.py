"""repro.obs.slo: rolling-window SLO monitor + overload state machine.

Load-bearing contracts pinned here:

* the stdlib windowed percentile equals ``np.percentile`` (linear
  interpolation) exactly, over every window size that matters;
* the hysteresis schedule is deterministic under a scripted clock:
  ``ok -> degraded -> overloaded -> ok`` exactly when ``trip_s`` /
  ``clear_s`` say so, a sub-``trip_s`` spike never escalates, and the
  queue-depth ledger (admit minus done) can't leak through cancel or
  exception paths because the server hangs it off the future's own done
  callback;
* ``/healthz`` + ``/slo`` are served live next to ``/metrics`` on the
  same ``serve_metrics`` handle (503 exactly while overloaded), and the
  in-use-port / port-0 behaviors of that handle are explicit.
"""

from __future__ import annotations

import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import RenderConfig, look_at_camera, orbit_cameras, random_gaussians
from repro.obs.metrics import Registry, serve_metrics, validate_prometheus
from repro.obs.slo import SLOMonitor, SLOTargets, _percentile
from repro.serve import RenderServer

SIZE = 32


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def _monitor(targets: SLOTargets, **kw) -> tuple[SLOMonitor, FakeClock]:
    clk = FakeClock()
    return SLOMonitor(targets, clock=clk, **kw), clk


# -- window math -----------------------------------------------------------


class TestWindowMath:
    @pytest.mark.parametrize("n", [1, 2, 3, 5, 19, 20, 50, 100])
    @pytest.mark.parametrize("q", [50.0, 95.0, 99.0])
    def test_percentile_matches_numpy(self, n, q):
        rng = np.random.default_rng(n)
        vals = sorted(rng.exponential(100.0, size=n).tolist())
        assert _percentile(vals, q) == pytest.approx(
            float(np.percentile(vals, q)), abs=1e-9, rel=1e-12
        )

    def test_windowed_p95_equals_numpy_after_pruning(self):
        m, clk = _monitor(SLOTargets(p95_ms=1e9, window_s=10.0))
        # 40 observations, one per 0.5s: only the last 10s count (horizon
        # inclusive -> 21 events: t in [10.0, 20.0]).
        lats = [float(i) for i in range(40)]
        for lat in lats:
            clk.t += 0.5
            m.observe_latency(lat)
        w = m.window()
        live = lats[-21:]  # exactly the un-pruned tail
        assert w["n_latency"] == len(live)
        assert w["p95_ms"] == pytest.approx(float(np.percentile(live, 95)))
        assert w["p50_ms"] == pytest.approx(float(np.percentile(live, 50)))

    def test_req_s_uses_elapsed_capped_span(self):
        m, clk = _monitor(SLOTargets(window_s=30.0))
        clk.t = 2.0
        m.note_admit(4)
        m.note_done(4)
        # Monitor is 2s old: rate divides by true age, not the 30s window.
        assert m.window()["req_s"] == pytest.approx(4 / 2.0)

    def test_reject_rate_over_offered(self):
        m, clk = _monitor(SLOTargets())
        m.note_admit(3)
        m.note_reject(1)
        assert m.window()["reject_rate"] == pytest.approx(0.25)
        assert m.window()["queue_depth"] == 3


# -- state machine ---------------------------------------------------------

TARGETS = SLOTargets(
    p95_ms=100.0,
    max_queue_depth=10.0,
    overload_factor=2.0,
    window_s=60.0,
    trip_s=1.0,
    clear_s=2.0,
)


class TestStateMachine:
    def test_scripted_hysteresis_full_cycle(self):
        m, clk = _monitor(TARGETS)
        assert m.state == "ok"
        # Soft breach (p95 over 100, under 200) sustained past trip_s.
        m.observe_latency(150.0)
        assert m.state == "ok"  # pressure noted, hold not yet elapsed
        clk.t = 0.5
        m.observe_latency(150.0)
        assert m.state == "ok"
        clk.t = 1.1
        m.observe_latency(150.0)
        assert m.state == "degraded"
        # Hard breach (p95 over 2x the target) sustained past trip_s.
        clk.t = 1.2
        m.observe_latency(400.0)
        assert m.state == "degraded"
        clk.t = 2.3
        m.observe_latency(400.0)
        assert m.state == "overloaded"
        # Recovery: window drains, calm must hold clear_s, then a direct
        # overloaded -> ok jump (no forced pass through degraded).
        clk.t = 70.0
        assert m.evaluate() == "overloaded"
        clk.t = 71.9
        assert m.evaluate() == "overloaded"
        clk.t = 72.1
        assert m.evaluate() == "ok"
        assert [(t["from"], t["to"]) for t in m.transitions()] == [
            ("ok", "degraded"),
            ("degraded", "overloaded"),
            ("overloaded", "ok"),
        ]

    def test_sub_trip_spike_never_escalates(self):
        m, clk = _monitor(TARGETS)
        m.note_admit(20)  # depth 20 > 10: hard pressure...
        assert m.state == "ok"
        clk.t = 0.5  # ...but gone before trip_s elapses
        m.note_done(20)
        clk.t = 5.0
        assert m.evaluate() == "ok"
        assert m.transitions() == []

    def test_cold_start_grace_on_throughput_floor(self):
        # A just-admitted first request reads req_s=0; that must not trip
        # the min_req_s floor until a full expected service interval of
        # demand (1/min_req_s) has elapsed with nothing completing.
        m, clk = _monitor(SLOTargets(min_req_s=1.0, window_s=60.0, trip_s=0.0))
        m.note_admit()
        assert m.state == "ok"
        clk.t = 0.9
        assert m.evaluate() == "ok"  # still inside the grace interval
        clk.t = 1.1
        assert m.evaluate() == "overloaded"  # 0 req/s past grace IS a stall

    def test_idle_monitor_is_healthy(self):
        m, clk = _monitor(SLOTargets(min_req_s=5.0, p95_ms=10.0))
        clk.t = 100.0
        assert m.evaluate() == "ok"
        healthy, doc = m.healthz()
        assert healthy and doc["status"] == "ok"

    def test_gauges_and_transition_counter_exported(self):
        reg = Registry()
        clk = FakeClock()
        m = SLOMonitor(
            SLOTargets(max_queue_depth=2.0, trip_s=0.0, clear_s=1.0),
            registry=reg, clock=clk, mode="continuous",
        )
        m.note_admit(5)
        assert m.state == "overloaded"
        text = reg.render_prometheus()
        validate_prometheus(text)
        assert 'slo_state{mode="continuous"} 2' in text
        assert "slo_queue_depth" in text and "slo_state_transitions_total" in text

    def test_targets_validation(self):
        with pytest.raises(ValueError):
            SLOTargets(overload_factor=0.5)
        with pytest.raises(ValueError):
            SLOTargets(window_s=0.0)


# -- HTTP surfaces ---------------------------------------------------------


def _get(port: int, path: str):
    try:
        with urllib.request.urlopen(
            f"http://127.0.0.1:{port}{path}", timeout=10
        ) as r:
            return r.status, r.read()
    except urllib.error.HTTPError as e:
        return e.code, e.read()


class TestEndpoints:
    def test_healthz_slo_metrics_served_together(self):
        reg = Registry()
        clk = FakeClock()
        m = SLOMonitor(
            SLOTargets(max_queue_depth=2.0, trip_s=0.0, clear_s=0.5),
            registry=reg, clock=clk,
        )
        srv = serve_metrics(reg, slo=m)
        try:
            code, body = _get(srv.port, "/metrics")
            assert code == 200 and b"slo_state" in body
            code, body = _get(srv.port, "/healthz")
            assert code == 200 and json.loads(body)["status"] == "ok"
            # Overload -> /healthz flips to 503, /slo stays 200 and says why.
            m.note_admit(5)
            code, body = _get(srv.port, "/healthz")
            assert code == 503 and json.loads(body)["ok"] is False
            code, body = _get(srv.port, "/slo")
            doc = json.loads(body)
            assert code == 200 and doc["state"] == "overloaded"
            assert doc["window"]["queue_depth"] == 5
            assert doc["targets"]["max_queue_depth"] == 2.0
            # Drain + clear_s: pollers observe recovery with no new traffic.
            m.note_done(5)
            clk.t = 1.0
            code, body = _get(srv.port, "/healthz")
            assert code == 200 and json.loads(body)["status"] == "ok"
            code, _ = _get(srv.port, "/nope")
            assert code == 404
        finally:
            srv.shutdown()

    def test_no_slo_404s_health_endpoints(self):
        srv = serve_metrics(Registry())
        try:
            assert _get(srv.port, "/metrics")[0] == 200
            assert _get(srv.port, "/healthz")[0] == 404
            assert _get(srv.port, "/slo")[0] == 404
        finally:
            srv.shutdown()

    def test_port_zero_reports_bound_port(self):
        srv = serve_metrics(Registry(), port=0)
        try:
            assert isinstance(srv.port, int) and srv.port > 0
            assert srv.port == srv.server_address[1]
            assert _get(srv.port, "/metrics")[0] == 200
        finally:
            srv.shutdown()

    def test_port_in_use_raises_naming_the_port(self):
        srv = serve_metrics(Registry())
        try:
            with pytest.raises(OSError, match=str(srv.port)):
                serve_metrics(Registry(), port=srv.port)
        finally:
            srv.shutdown()


# -- RenderServer integration ---------------------------------------------


def _tiny_server(**kw) -> RenderServer:
    model = random_gaussians(jax.random.PRNGKey(0), 64, extent=1.5)
    cfg = RenderConfig(raster_path="binned", tile_capacity=64, early_exit=False)
    return RenderServer(
        model, cfg, width=SIZE, height=SIZE, max_batch=4, **kw
    )


class TestRenderServerIntegration:
    def test_targets_build_monitor_and_stats_carry_snapshot(self):
        srv = _tiny_server(slo=SLOTargets(max_queue_depth=64.0, p95_ms=60_000.0))
        cams = orbit_cameras(6, radius=5.0, width=SIZE, height=SIZE)
        with srv:
            [f.result(timeout=120) for f in map(srv.submit, cams)]
        snap = srv.stats()["slo"]
        assert snap["state"] == "ok"
        assert snap["window"]["n_latency"] == 6
        assert snap["window"]["queue_depth"] == 0  # every admit was resolved
        # Latencies feed both the histogram and the SLO window.
        assert snap["window"]["p95_ms"] > 0.0
        # The monitor's gauges landed in the *server's* registry.
        assert "slo_state" in srv.registry.render_prometheus()

    def test_reject_and_cancel_paths_keep_the_ledger_exact(self):
        srv = _tiny_server(slo=SLOTargets(max_queue_depth=64.0))
        cam = look_at_camera(
            (0.0, 1.0, -5.0), (0.0, 0.0, 0.0), width=SIZE, height=SIZE
        )
        bad = look_at_camera(
            (0.0, 1.0, -5.0), (0.0, 0.0, 0.0), width=SIZE * 2, height=SIZE * 2
        )
        with srv:
            with pytest.raises(ValueError):
                srv.submit(bad)  # size outside the bucket set
            futs = [srv.submit(cam) for _ in range(4)]
            [f.result(timeout=120) for f in futs]
        w = srv.slo.window()
        assert w["queue_depth"] == 0
        assert w["reject_rate"] == pytest.approx(1 / 5)
        # A future cancelled before it ever ran still settles its depth
        # unit through the done callback.
        from concurrent.futures import Future

        m, _ = _monitor(SLOTargets())
        f = Future()
        m.note_admit()
        f.add_done_callback(lambda _f: m.note_done())
        assert m.window()["queue_depth"] == 1
        f.cancel()
        assert m.window()["queue_depth"] == 0

    def test_prebuilt_monitor_shared_with_endpoint(self):
        reg = Registry()
        m = SLOMonitor(
            SLOTargets(max_queue_depth=64.0), registry=reg, mode="continuous"
        )
        srv = _tiny_server(registry=reg, slo=m)
        assert srv.slo is m  # adopted, not wrapped
        http = serve_metrics(reg, slo=m)
        try:
            cams = orbit_cameras(4, radius=5.0, width=SIZE, height=SIZE)
            with srv:
                [f.result(timeout=120) for f in map(srv.submit, cams)]
                code, body = _get(http.port, "/slo")
                assert code == 200
                assert json.loads(body)["window"]["n_latency"] == 4
                assert _get(http.port, "/healthz")[0] == 200
        finally:
            http.shutdown()
