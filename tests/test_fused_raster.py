"""Fused streaming raster pipeline vs the unfused ladder (interpret mode).

The fused kernel's contract: identical sort + tile lists to ``pallas_binned``
(same pre-pass geometry), in-kernel feature math bitwise-equal to the staged
jnp path, blending equal to ~1e-7 — so forward images must match the unfused
paths to float rounding, the custom VJP must match jnp autodiff through the
binned path, and early exit must be bitwise-exact once transmittance
underflows to zero behind an opaque front layer.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    build_scene_tree,
    clustered_gaussians,
    look_at_camera,
    random_gaussians,
)
from repro.core.features import compute_features_staged
from repro.core.multicam import (
    render_batch_jit,
    render_batch_masked_jit,
    stack_cameras,
)
from repro.core.rasterize import rasterize_features
from repro.core.render import render_jit
from repro.core.scene import apply_sh_lod
from repro.kernels.fused_raster import (
    fused_render,
    lane_feature_cloud,
    pick_tiles_per_step,
)

BG = (0.1, 0.2, 0.3)


def _cfg(path: str, **kw) -> RenderConfig:
    kw.setdefault("early_exit", False)
    return RenderConfig(raster_path=path, background=BG, **kw)


def _cam(eye=(0, 1.0, -6.0), target=(0, 0, 0), width=64, height=64):
    return look_at_camera(eye, target, width=width, height=height)


class TestLaneFeatures:
    def test_bitwise_equal_to_staged(self):
        """In-kernel lane math calls the staged stage functions on AoS views
        of the raw records — every feature field must match bitwise."""
        g = random_gaussians(jax.random.PRNGKey(3), 512)
        cam = _cam((1.0, 0.5, -4.0), (0.2, 0, 0), width=80, height=48)
        got = lane_feature_cloud(g, cam)
        want = compute_features_staged(g, cam, sh_degree=3)
        for f in dataclasses.fields(want):
            a = np.asarray(getattr(got, f.name))
            b = np.asarray(getattr(want, f.name))
            np.testing.assert_array_equal(a, b, err_msg=f.name)


class TestFusedForward:
    @pytest.mark.parametrize("kind", ["uniform", "clustered"])
    def test_matches_unfused(self, kind):
        if kind == "uniform":
            g = random_gaussians(jax.random.PRNGKey(1), 3000, extent=1.5)
        else:
            g = clustered_gaussians(jax.random.PRNGKey(2), 3000)
        cam = _cam()
        # Capacity above N: no tile-list overflow, so the capped paths stay
        # comparable to the uncapped dense oracle.
        kw = dict(tile_capacity=3072)
        binned = render_jit(g, cam, _cfg("pallas_binned", **kw))
        dense = render_jit(g, cam, _cfg("dense", **kw))
        fused = render_jit(g, cam, _cfg("pallas_fused", **kw))
        assert float(jnp.max(jnp.abs(fused - binned))) <= 1e-6
        assert float(jnp.max(jnp.abs(fused - dense))) <= 2e-6

    def test_off_center_camera_non_square(self):
        g = clustered_gaussians(jax.random.PRNGKey(5), 2000)
        cam = _cam((2.0, -0.8, -4.5), (0.6, 0.3, 0.2), width=80, height=48)
        binned = render_jit(g, cam, _cfg("pallas_binned"))
        fused = render_jit(g, cam, _cfg("pallas_fused"))
        assert float(jnp.max(jnp.abs(fused - binned))) <= 1e-6

    def test_scene_tree_culled(self):
        g = clustered_gaussians(
            jax.random.PRNGKey(4), 8000, num_clusters=12, extent=2.0
        )
        tree = build_scene_tree(g, leaf_size=128)
        cam = look_at_camera(
            (0.8, 0.2, 0.0), (2.4, 0.2, 0.0), width=64, height=64
        )
        kw = dict(cull=True, visible_capacity=48)
        binned = render_jit(tree, cam, _cfg("pallas_binned", **kw))
        fused = render_jit(tree, cam, _cfg("pallas_fused", **kw))
        assert float(jnp.max(jnp.abs(fused - binned))) <= 1e-6

    def test_lod_banded(self):
        """Banding is a FLOP cut, not an approximation: the banded fused
        render must equal (a) the unfused path on the same LOD'd scene and
        (b) the *unbanded* fused render of explicitly-zeroed coefficients."""
        g = clustered_gaussians(
            jax.random.PRNGKey(6), 8000, num_clusters=12, extent=2.0
        )
        tree = build_scene_tree(g, leaf_size=128)
        cam = look_at_camera(
            (0.8, 0.2, 0.0), (2.4, 0.2, 0.0), width=64, height=64
        )
        kw = dict(cull=True, visible_capacity=48, lod_thresholds=(0.2, 0.5))
        binned = render_jit(tree, cam, _cfg("pallas_binned", **kw))
        fused = render_jit(tree, cam, _cfg("pallas_fused", **kw))
        assert float(jnp.max(jnp.abs(fused - binned))) <= 1e-6

        # Direct check of the in-kernel band switch: zeroing coefficients
        # above each Gaussian's band must reproduce the banded kernel
        # exactly (the switch skips exactly the zeroed basis terms).
        g2 = random_gaussians(jax.random.PRNGKey(7), 1024)
        band = jax.random.randint(jax.random.PRNGKey(8), (1024,), 0, 4)
        zeroed = dataclasses.replace(g2, sh=apply_sh_lod(g2.sh, band))
        bg = jnp.asarray(BG, jnp.float32)
        cam2 = _cam()
        banded = fused_render(
            zeroed, cam2, bg, band=band, early_exit=False
        )
        unbanded = fused_render(zeroed, cam2, bg, early_exit=False)
        np.testing.assert_array_equal(
            np.asarray(banded), np.asarray(unbanded)
        )

    def test_batched_and_masked(self):
        g = clustered_gaussians(jax.random.PRNGKey(9), 2000)
        cams = stack_cameras(
            [
                _cam(),
                _cam((2.0, -0.8, -4.5), (0.6, 0.3, 0.2)),
            ]
        )
        cfg_f = _cfg("pallas_fused")
        cfg_b = _cfg("pallas_binned")
        batch_f = render_batch_jit(g, cams, cfg_f)
        batch_b = render_batch_jit(g, cams, cfg_b)
        assert float(jnp.max(jnp.abs(batch_f - batch_b))) <= 1e-6

        active = jnp.asarray([True, False])
        masked = render_batch_masked_jit(g, cams, active, cfg_f)
        np.testing.assert_array_equal(
            np.asarray(masked[0]), np.asarray(batch_f[0])
        )
        np.testing.assert_array_equal(
            np.asarray(masked[1]),
            np.broadcast_to(np.asarray(BG, np.float32), masked[1].shape),
        )


class TestFusedVJP:
    def _loss_pair(self):
        g = clustered_gaussians(jax.random.PRNGKey(11), 600)
        cam = _cam(width=32, height=32)
        w = jax.random.normal(jax.random.PRNGKey(12), (32, 32, 3))
        cfg_ref = _cfg("binned", feature_path="staged")
        cfg_fused = _cfg("pallas_fused")

        def loss(cfg):
            return lambda gg: jnp.sum(render_jit(gg, cam, cfg) * w)

        return g, loss(cfg_ref), loss(cfg_fused)

    def test_grads_match_jnp_binned(self):
        g, loss_ref, loss_fused = self._loss_pair()
        g_ref = jax.grad(loss_ref)(g)
        g_fused = jax.grad(loss_fused)(g)
        for f in dataclasses.fields(g):
            a = np.asarray(getattr(g_fused, f.name))
            b = np.asarray(getattr(g_ref, f.name))
            # Scale-relative: elementwise rtol is meaningless on the many
            # near-zero entries of a scatter-added gradient field.
            np.testing.assert_allclose(
                a,
                b,
                rtol=1e-4,
                atol=1e-5 * max(float(np.abs(b).max()), 1e-6),
                err_msg=f.name,
            )

    def test_early_exit_grads_bitwise(self):
        """The backward kernel replays the forward's early-exit gate, so it
        differentiates the actually-computed function: grads with and
        without the exit are identical when the images are."""
        g = clustered_gaussians(jax.random.PRNGKey(13), 600)
        cam = _cam(width=32, height=32)
        w = jax.random.normal(jax.random.PRNGKey(14), (32, 32, 3))
        bg = jnp.asarray(BG, jnp.float32)

        def loss(ee):
            return lambda gg: jnp.sum(
                fused_render(gg, cam, bg, early_exit=ee) * w
            )

        g_ee = jax.grad(loss(True))(g)
        g_no = jax.grad(loss(False))(g)
        for f in dataclasses.fields(g):
            np.testing.assert_array_equal(
                np.asarray(getattr(g_ee, f.name)),
                np.asarray(getattr(g_no, f.name)),
                err_msg=f.name,
            )


class TestEarlyExit:
    def _opaque_front_scene(self):
        """A wall of near-opaque Gaussians in front of a random cloud: once
        a pixel's first chunks blend the wall, float32 transmittance
        underflows to exactly 0 and every later chunk contributes exactly
        nothing — the saturation skip becomes bitwise-exact."""
        back = clustered_gaussians(jax.random.PRNGKey(21), 1500)
        # 32 screen-filling near-opaque Gaussians (sigma = 2 world units at
        # depth ~3.5 -> the 3-sigma box covers the whole 64x64 image and
        # alpha is the 0.99 cap at every pixel): after the first chunk,
        # T = 0.01^32 underflows to exactly 0.0 in float32.
        n_front = 32
        key = jax.random.PRNGKey(22)
        front = random_gaussians(key, n_front, extent=0.05, base_scale=2.0)
        front = dataclasses.replace(
            front,
            positions=front.positions.at[:, 2].add(-2.5),
            log_scales=jnp.full((n_front, 3), jnp.log(2.0)),
            opacity_logit=jnp.full((n_front,), 30.0),
        )
        return jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), front, back
        )

    def test_opaque_front_bitwise(self):
        g = self._opaque_front_scene()
        cam = _cam()
        bg = jnp.asarray(BG, jnp.float32)
        ee = fused_render(g, cam, bg, early_exit=True)
        no = fused_render(g, cam, bg, early_exit=False)
        np.testing.assert_array_equal(np.asarray(ee), np.asarray(no))

    def test_general_scene_bounded(self):
        g = clustered_gaussians(jax.random.PRNGKey(23), 3000)
        cam = _cam()
        bg = jnp.asarray(BG, jnp.float32)
        ee = fused_render(g, cam, bg, early_exit=True)
        no = fused_render(g, cam, bg, early_exit=False)
        assert float(jnp.max(jnp.abs(ee - no))) <= 1.0 / 255.0


class TestPlumbing:
    def test_rasterize_features_rejects_fused(self):
        g = random_gaussians(jax.random.PRNGKey(0), 64)
        cam = _cam(width=32, height=32)
        feats = compute_features_staged(g, cam)
        with pytest.raises(ValueError, match="pallas_fused"):
            rasterize_features(feats, 32, 32, _cfg("pallas_fused"))

    @pytest.mark.parametrize(
        "num_tiles,target,want",
        [(16, 16, 16), (20, 16, 10), (7, 16, 7), (30, 16, 15), (1, 16, 1)],
    )
    def test_pick_tiles_per_step(self, num_tiles, target, want):
        got = pick_tiles_per_step(num_tiles, target)
        assert got == want
        assert num_tiles % got == 0 and got <= max(target, 1)
