"""COLMAP text-format loader: parsing, pose convention, point-cloud init.

The fixture under ``tests/data/colmap/`` is a 3-camera orbit written in
COLMAP's text layout (one camera per supported model: PINHOLE,
SIMPLE_PINHOLE, SIMPLE_RADIAL) over a small two-cluster point cloud; poses
were generated from the repo's own ``look_at_camera``, so loading must
reproduce them.
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RenderConfig, build_scene_tree, render
from repro.core.camera import orbit_cameras
from repro.core.sh import SH_C0, eval_sh_color
from repro.data.colmap import (
    gaussians_from_points,
    load_colmap_scene,
    read_cameras_txt,
    scale_camera,
)

FIXTURE = pathlib.Path(__file__).parent / "data" / "colmap"


@pytest.fixture(scope="module")
def scene():
    return load_colmap_scene(FIXTURE)


class TestParsing:
    def test_counts(self, scene):
        assert len(scene.cameras) == 3
        assert len(scene.image_names) == 3
        assert scene.points.shape == (40, 3)
        assert scene.colors.shape == (40, 3)
        assert scene.gaussians.num_gaussians == 40

    def test_intrinsics_all_models(self, scene):
        # One camera per model; all share the generator's focal/principal.
        for cam in scene.cameras:
            assert (cam.width, cam.height) == (64, 48)
            np.testing.assert_allclose(float(cam.fx), float(cam.fy))
            np.testing.assert_allclose(float(cam.cx), 32.0)
            np.testing.assert_allclose(float(cam.cy), 24.0)

    def test_poses_match_generator(self, scene):
        want = orbit_cameras(3, radius=5.0, width=64, height=48)
        for got, ref in zip(scene.cameras, want):
            np.testing.assert_allclose(
                np.asarray(got.r_cw), np.asarray(ref.r_cw), atol=1e-5
            )
            np.testing.assert_allclose(
                np.asarray(got.t_cw), np.asarray(ref.t_cw), atol=1e-5
            )
            r = np.asarray(got.r_cw)
            np.testing.assert_allclose(r @ r.T, np.eye(3), atol=1e-5)

    def test_colors_in_unit_range(self, scene):
        assert (scene.colors >= 0.0).all() and (scene.colors <= 1.0).all()

    def test_missing_file_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            load_colmap_scene(tmp_path)

    def test_image_name_with_spaces_survives(self, tmp_path):
        """COLMAP preserves original filenames; a space in the name must
        not make the pose line parse as an observation line."""
        from repro.data.colmap import read_images_txt

        (tmp_path / "images.txt").write_text(
            "1 1.0 0.0 0.0 0.0 0.1 0.2 0.3 1 IMG 0012.jpg\n\n"
        )
        cams, names = read_images_txt(
            tmp_path / "images.txt",
            {1: dict(width=64, height=48, fx=70.0, fy=70.0, cx=32.0, cy=24.0)},
        )
        assert len(cams) == 1
        assert names == ["IMG 0012.jpg"]

    def test_unsupported_model_raises(self, tmp_path):
        (tmp_path / "cameras.txt").write_text(
            "1 OPENCV 64 48 70 70 32 24 0 0 0 0\n"
        )
        with pytest.raises(ValueError, match="unsupported"):
            read_cameras_txt(tmp_path / "cameras.txt")


class TestPointInit:
    def test_dc_color_reproduces_point_color(self, scene):
        g = scene.gaussians
        np.testing.assert_allclose(
            np.asarray(g.sh[:, 0, :]) * SH_C0 + 0.5,
            scene.colors,
            atol=1e-5,
        )
        # Degree-0 evaluation returns the point color for any direction.
        dirs = jnp.tile(jnp.asarray([0.0, 0.0, 1.0]), (40, 1))
        col = eval_sh_color(g.sh, dirs, degree=0)
        np.testing.assert_allclose(
            np.asarray(col), scene.colors, atol=1e-5
        )

    def test_scales_track_local_density(self):
        # Two points close together + one far away: the pair gets a much
        # smaller init scale than the outlier.
        pts = np.array(
            [[0.0, 0, 0], [0.01, 0, 0], [5.0, 0, 0]], np.float32
        )
        cols = np.full((3, 3), 0.5, np.float32)
        g = gaussians_from_points(pts, cols)
        s = np.exp(np.asarray(g.log_scales))[:, 0]
        assert s[0] < s[2] and s[1] < s[2]

    def test_opacity_uniform_start(self, scene):
        opa = jax.nn.sigmoid(np.asarray(scene.gaussians.opacity_logit))
        np.testing.assert_allclose(opa, 0.1, atol=1e-5)


class TestIntegration:
    def test_render_from_loaded_pose(self, scene):
        img = render(
            scene.gaussians,
            scene.cameras[0],
            RenderConfig(raster_path="binned"),
        )
        assert img.shape == (48, 64, 3)
        assert np.isfinite(np.asarray(img)).all()
        assert float(img.max()) > 0.0  # the cloud is on screen

    def test_scale_camera(self, scene):
        half = scale_camera(scene.cameras[0], 0.5)
        assert (half.width, half.height) == (32, 24)
        np.testing.assert_allclose(
            float(half.fx), 0.5 * float(scene.cameras[0].fx)
        )
        img = render(scene.gaussians, half, RenderConfig())
        assert img.shape == (24, 32, 3)

    def test_scene_tree_over_colmap_points(self, scene):
        tree = build_scene_tree(scene.gaussians, leaf_size=16)
        cfg = RenderConfig(raster_path="binned", cull=True, early_exit=False)
        culled = render(tree, scene.cameras[0], cfg)
        base = render(
            scene.gaussians,
            scene.cameras[0],
            cfg.replace(cull=False),
        )
        np.testing.assert_allclose(
            np.asarray(culled), np.asarray(base), atol=1e-5
        )
