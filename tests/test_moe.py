"""MoE routing properties: dispatch conservation, capacity behavior, aux loss."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import params as P
from repro.models.api import family_module
from repro.models.moe import expert_capacity, moe_block


def _setup(capacity_factor=8.0, seed=0):
    cfg = get_smoke_config("qwen3-moe-30b-a3b", capacity_factor=capacity_factor)
    mod = family_module(cfg)
    params = P.init_tree(jax.random.PRNGKey(seed), mod.param_defs(cfg))
    lp = jax.tree.map(lambda x: x[0], params["layers"]["mlp"])  # layer 0
    return cfg, lp


class TestDispatch:
    def test_no_drop_equals_exact_topk(self):
        """With capacity >= T*K, scatter-dispatch == explicit per-token experts."""
        cfg, lp = _setup(capacity_factor=float(8))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        out, aux = moe_block(cfg, lp, x)

        # explicit reference: per token, run its top-k experts densely
        logits = jnp.einsum("btd,de->bte", x, lp["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
        gates = gates / gates.sum(-1, keepdims=True)
        wg, wu, wd = lp["w_gate"][idx], lp["w_up"][idx], lp["w_down"][idx]
        h = jax.nn.silu(jnp.einsum("btd,btkdf->btkf", x, wg)) * jnp.einsum(
            "btd,btkdf->btkf", x, wu
        )
        want = jnp.einsum(
            "btkf,btkfd->btkd", h, wd
        ) * gates[..., None]
        want = want.sum(axis=2)
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4)
        assert float(aux) > 0.0

    def test_capacity_drop_reduces_output_norm(self):
        """Dropping tokens (small capacity) can only remove contributions."""
        cfg_hi, lp = _setup(capacity_factor=8.0)
        cfg_lo = dataclasses.replace(cfg_hi, capacity_factor=0.25)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg_hi.d_model))
        out_hi, _ = moe_block(cfg_hi, lp, x)
        out_lo, _ = moe_block(cfg_lo, lp, x)
        # dropped tokens produce zero output rows; column norms shrink
        assert float(jnp.linalg.norm(out_lo)) <= float(jnp.linalg.norm(out_hi)) + 1e-4

    def test_capacity_is_lane_aligned(self):
        cfg, _ = _setup()
        for t in [16, 64, 100, 1000]:
            c = expert_capacity(cfg, t)
            assert c % 8 == 0 and c >= 8

    @pytest.mark.parametrize("seed", [0, 3, 7, 11, 14, 17, 19, 20])
    def test_gates_normalized(self, seed):
        cfg, lp = _setup(seed=seed)
        x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, cfg.d_model))
        logits = jnp.einsum("btd,de->bte", x, lp["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        gates, _ = jax.lax.top_k(probs, cfg.experts_per_token)
        gates = gates / gates.sum(-1, keepdims=True)
        np.testing.assert_allclose(gates.sum(-1), 1.0, rtol=1e-5)

    def test_aux_loss_uniform_router_is_one(self):
        """With perfectly uniform routing, E * sum(f_e * P_e) == 1."""
        cfg, lp = _setup()
        # zero router -> uniform probs; top-k picks arbitrary but f is ~uniform
        lp = dict(lp, router=jnp.zeros_like(lp["router"]))
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 128, cfg.d_model))
        _, aux = moe_block(cfg, lp, x)
        # P_e uniform = 1/E exactly; f_e sums to 1 -> aux == 1
        np.testing.assert_allclose(float(aux), 1.0, rtol=1e-4)


class TestSortBasedRouting:
    """The argsort position-in-expert must equal the one-hot-cumsum reference."""

    @pytest.mark.parametrize("seed", [0, 5, 13, 27, 41, 50])
    @pytest.mark.parametrize("e", [4, 8, 16])
    def test_matches_cumsum_reference(self, seed, e):
        from repro.models.moe import _pos_in_expert

        key = jax.random.PRNGKey(seed)
        eid = jax.random.randint(key, (2, 64), 0, e)
        got = _pos_in_expert(eid)
        # reference: O(TK*E) one-hot cumsum rank
        onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)
        pos_all = jnp.cumsum(onehot, axis=1) - onehot
        want = jnp.sum(pos_all * onehot, axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_raster_priority(self):
        from repro.models.moe import _pos_in_expert

        eid = jnp.array([[3, 3, 1, 3, 1]])
        pos = np.asarray(_pos_in_expert(eid))[0]
        np.testing.assert_array_equal(pos, [0, 1, 0, 2, 1])
