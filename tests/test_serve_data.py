"""Serving loop + data pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import SyntheticLMData
from repro.models import params as P
from repro.models.api import family_module
from repro.serve import BatchedServer


class TestServer:
    @pytest.mark.slow  # full prefill+decode consistency sweep, ~8s
    def test_greedy_matches_teacher_forced(self):
        cfg = get_smoke_config("tinyllama-1.1b")
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        srv = BatchedServer(cfg, params, max_seq=64)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
        out = srv.generate({"tokens": prompt}, steps=4)
        assert out.tokens.shape == (2, 4)
        assert out.logprobs.shape == (2, 4)
        assert (out.logprobs <= 0).all()
        # re-run the full sequence teacher-forced; greedy tokens must be the
        # argmax continuation at every step
        toks = prompt
        for i in range(4):
            logits = mod.forward(cfg, params, {"tokens": toks})
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
            np.testing.assert_array_equal(nxt, out.tokens[:, i])
            toks = jnp.concatenate(
                [toks, jnp.asarray(nxt, jnp.int32)[:, None]], axis=1
            )

    def test_temperature_sampling_differs(self):
        cfg = get_smoke_config("tinyllama-1.1b")
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        srv = BatchedServer(cfg, params, max_seq=64, temperature=2.0)
        prompt = jnp.zeros((4, 8), jnp.int32)
        a = srv.generate({"tokens": prompt}, steps=6, seed=0)
        b = srv.generate({"tokens": prompt}, steps=6, seed=1)
        assert (a.tokens != b.tokens).any()

    def test_sampling_deterministic_and_first_key_folded(self):
        """Same seed replays the same stream, and the *first* sample's key
        is fold_in(PRNGKey(seed), 0) — never the raw un-folded seed key
        (which another consumer of the seed could share)."""
        cfg = get_smoke_config("tinyllama-1.1b")
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        srv = BatchedServer(cfg, params, max_seq=64, temperature=1.0)
        prompt = jax.random.randint(jax.random.PRNGKey(9), (2, 8), 0, cfg.vocab_size)
        a = srv.generate({"tokens": prompt}, steps=4, seed=3)
        b = srv.generate({"tokens": prompt}, steps=4, seed=3)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.logprobs, b.logprobs)
        # pin the fold: step-0 token == categorical(fold_in(key, 0), logits)
        _, logits = srv._prefill(params, {"tokens": prompt})
        key = jax.random.PRNGKey(3)
        want = jax.random.categorical(
            jax.random.fold_in(key, 0), logits / srv.temperature
        )
        np.testing.assert_array_equal(np.asarray(want), a.tokens[:, 0])


class TestData:
    def test_deterministic_replay(self):
        d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        a = d.batch_at(7)
        b = d.batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = d.batch_at(8)
        assert (a["tokens"] != c["tokens"]).any()

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=2)
        b = d.batch_at(0)
        assert b["tokens"].shape == (2, 16)
        assert b["labels"].shape == (2, 16)
        # same underlying stream shifted by one position
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        """The Markov stream must be more predictable than uniform."""
        d = SyntheticLMData(vocab_size=100, seq_len=64, global_batch=8)
        b = d.batch_at(0)
        deltas = (b["labels"] - b["tokens"]) % 100
        # steps are in [1, 6] by construction
        assert deltas.min() >= 1 and deltas.max() <= 6
