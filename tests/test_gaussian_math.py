"""Unit + property tests for the Gaussian feature pipeline (paper Section IV)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    compute_features_naive,
    compute_features_staged,
    look_at_camera,
    random_gaussians,
)
from repro.core.features import (
    quat_to_rotmat,
    stage_cov2d,
    stage_cov2d_inv,
    stage_cov3d,
    stage_jacobian,
    stage_projection,
    stage_ray_dir,
)
from repro.core.sh import eval_sh_color, sh_basis

FIELDS = ["uv", "conic", "color", "depth", "radius", "opacity", "mask"]


def _cam(w=96, h=64):
    return look_at_camera((0.5, 1.0, -6.0), (0, 0, 0), width=w, height=h)


class TestNaiveVsStaged:
    """The paper's Listing-1 (naive) and Listing-2 (vectorized) paths agree."""

    @pytest.mark.parametrize(
        # n=1 is compile-bound (~15s for a degenerate shape): slow-marked,
        # still covered by `pytest -m slow`.
        "n",
        [pytest.param(1, marks=pytest.mark.slow), 17, 256],
    )
    def test_all_fields_match(self, n):
        g = random_gaussians(jax.random.PRNGKey(n), n)
        cam = _cam()
        fa = compute_features_naive(g, cam)
        fb = compute_features_staged(g, cam)
        for f in FIELDS:
            np.testing.assert_allclose(
                getattr(fa, f), getattr(fb, f), rtol=3e-5, atol=3e-5, err_msg=f
            )

    @pytest.mark.parametrize("deg", [0, 1, 2, 3])
    def test_sh_degrees(self, deg):
        g = random_gaussians(jax.random.PRNGKey(0), 64)
        cam = _cam()
        fa = compute_features_naive(g, cam, sh_degree=deg)
        fb = compute_features_staged(g, cam, sh_degree=deg)
        np.testing.assert_allclose(fa.color, fb.color, rtol=3e-5, atol=3e-5)


def _random_quat_scale(seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic stand-in for the hypothesis strategies: a non-degenerate
    quaternion in [-1, 1]^4 and positive scales in [0.01, 2.0]."""
    rng = np.random.RandomState(seed)
    q = rng.uniform(-1.0, 1.0, size=4).astype(np.float32)
    q[np.abs(q) < 1e-3] = 1e-2
    s = rng.uniform(0.01, 2.0, size=3).astype(np.float32)
    return q, s


class TestCov3DProperties:
    @pytest.mark.parametrize("seed", range(25))
    def test_rotation_matrix_orthonormal(self, seed):
        q, _ = _random_quat_scale(seed)
        r = np.asarray(quat_to_rotmat(jnp.asarray(q)))
        np.testing.assert_allclose(r @ r.T, np.eye(3), atol=1e-5)
        assert abs(np.linalg.det(r) - 1.0) < 1e-5

    @pytest.mark.parametrize("seed", range(25))
    def test_cov3d_psd_and_det(self, seed):
        q, s = _random_quat_scale(seed)
        cov6 = np.asarray(
            stage_cov3d(jnp.asarray(q)[None], jnp.asarray(s)[None])
        )[0]
        xx, xy, xz, yy, yz, zz = cov6
        sigma = np.array([[xx, xy, xz], [xy, yy, yz], [xz, yz, zz]])
        eig = np.linalg.eigvalsh(sigma)
        assert eig.min() >= -1e-5  # PSD
        # det(R S R^T) = prod(s^2) — rotation invariance of volume
        np.testing.assert_allclose(
            np.linalg.det(sigma), np.prod(s.astype(np.float64) ** 2), rtol=1e-3
        )

    @pytest.mark.parametrize("seed", range(15))
    def test_quaternion_scale_invariance(self, seed):
        """q and c*q encode the same rotation -> identical covariance."""
        q, s = _random_quat_scale(seed)
        scale = np.float32(np.random.RandomState(seed + 1000).uniform(0.1, 10.0))
        a = stage_cov3d(jnp.asarray(q)[None], jnp.asarray(s)[None])
        b = stage_cov3d(jnp.asarray(q * scale)[None], jnp.asarray(s)[None])
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


class TestProjection:
    def test_center_projects_to_principal_point(self):
        cam = _cam()
        # A point straight ahead of the camera lands on (cx, cy).
        p = cam.cam_pos + cam.r_cw.T @ jnp.array([0.0, 0.0, 3.0])
        _, uv, depth = stage_projection(p[None], cam)
        np.testing.assert_allclose(uv[0], [cam.cx, cam.cy], atol=1e-3)
        np.testing.assert_allclose(depth[0], 3.0, atol=1e-5)

    def test_behind_camera_masked(self):
        cam = _cam()
        p = cam.cam_pos - cam.r_cw.T @ jnp.array([0.0, 0.0, 3.0])
        g = random_gaussians(jax.random.PRNGKey(0), 1)
        g = jax.tree.map(lambda x: x, g)
        g.positions = p[None]
        feats = compute_features_staged(g, cam)
        assert float(feats.mask[0]) == 0.0

    def test_jacobian_matches_autodiff(self):
        cam = _cam()
        p_cam = jnp.array([[0.3, -0.2, 2.5]])

        def proj(pc):
            return jnp.stack(
                [cam.fx * pc[0] / pc[2], cam.fy * pc[1] / pc[2]]
            )

        j_auto = jax.jacfwd(proj)(p_cam[0])
        j_ours = stage_jacobian(p_cam, cam)[0]
        np.testing.assert_allclose(j_ours, j_auto, rtol=1e-4, atol=1e-5)


class TestCov2D:
    def test_conic_is_inverse(self):
        g = random_gaussians(jax.random.PRNGKey(3), 128)
        cam = _cam()
        cov3d = stage_cov3d(g.quats, g.scales())
        p_cam, _, _ = stage_projection(g.positions, cam)
        jac = stage_jacobian(p_cam, cam)
        cov2d = stage_cov2d(cov3d, jac, cam)
        conic, radius = stage_cov2d_inv(cov2d)
        a, b, c = cov2d[:, 0], cov2d[:, 1], cov2d[:, 2]
        ca, cb, cc = conic[:, 0], conic[:, 1], conic[:, 2]
        # [a b; b c] @ [ca cb; cb cc] == I where det > 0
        det = a * c - b * b
        valid = det > 1e-9
        np.testing.assert_allclose(
            np.where(valid, a * ca + b * cb, 1.0), 1.0, atol=1e-3
        )
        np.testing.assert_allclose(
            np.where(valid, a * cb + b * cc, 0.0), 0.0, atol=1e-3
        )
        assert np.all(np.asarray(radius) >= 0)

    def test_blur_lower_bounds_eigenvalues(self):
        """The +0.3 screen-space blur keeps the 2D covariance PSD."""
        g = random_gaussians(jax.random.PRNGKey(4), 256, base_scale=1e-4)
        cam = _cam()
        cov3d = stage_cov3d(g.quats, g.scales())
        p_cam, _, _ = stage_projection(g.positions, cam)
        jac = stage_jacobian(p_cam, cam)
        cov2d = np.asarray(stage_cov2d(cov3d, jac, cam))
        a, b, c = cov2d[:, 0], cov2d[:, 1], cov2d[:, 2]
        mid = 0.5 * (a + c)
        disc = np.sqrt(np.maximum(mid**2 - (a * c - b * b), 0))
        lam_min = mid - disc
        assert lam_min.min() > 0.0


class TestSphericalHarmonics:
    def test_deg0_is_view_independent(self):
        sh = 0.5 * jax.random.normal(jax.random.PRNGKey(0), (8, 16, 3))
        d1 = jnp.tile(jnp.array([[0.0, 0.0, 1.0]]), (8, 1))
        d2 = jnp.tile(jnp.array([[1.0, 0.0, 0.0]]), (8, 1))
        c1 = eval_sh_color(sh, d1, degree=0)
        c2 = eval_sh_color(sh, d2, degree=0)
        np.testing.assert_allclose(c1, c2, atol=1e-6)

    @pytest.mark.parametrize("seed", range(25))
    def test_basis_orthogonality_constants(self, seed):
        """Y_00 is constant; all 16 values finite for any unit direction."""
        rng = np.random.RandomState(seed)
        d = rng.uniform(-1.0, 1.0, size=3).astype(np.float32)
        while np.linalg.norm(d) <= 1e-2:
            d = rng.uniform(-1.0, 1.0, size=3).astype(np.float32)
        d = d / np.linalg.norm(d)
        b = np.asarray(sh_basis(jnp.asarray(d)))
        assert b.shape == (16,)
        assert np.isfinite(b).all()
        np.testing.assert_allclose(b[0], 0.28209479, rtol=1e-5)

    def test_color_clamped_nonnegative(self):
        sh = -5.0 * jnp.ones((4, 16, 3))
        d = jnp.tile(jnp.array([[0.0, 0.0, 1.0]]), (4, 1))
        c = eval_sh_color(sh, d)
        assert float(c.min()) >= 0.0


class TestRayDir:
    def test_unit_norm(self):
        g = random_gaussians(jax.random.PRNGKey(5), 64)
        cam = _cam()
        r = stage_ray_dir(g.positions, cam)
        np.testing.assert_allclose(
            jnp.linalg.norm(r, axis=-1), 1.0, atol=1e-5
        )
