"""Optimizer + checkpoint store tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(
            learning_rate=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200
        )
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)

        @jax.jit
        def step(params, state):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            return adamw_update(cfg, params, grads, state)

        for _ in range(150):
            params, state, metrics = step(params, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clipping(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(3)}
        state = adamw_init(params)
        grads = {"w": jnp.array([1e6, 0.0, 0.0])}
        _, _, metrics = adamw_update(cfg, params, grads, state)
        assert float(metrics["grad_norm"]) > 1e5  # pre-clip norm reported

    def test_schedule_shape(self):
        cfg = AdamWConfig(
            learning_rate=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1
        )
        lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 55, 100]]
        assert lrs[0] == 0.0
        assert abs(lrs[1] - 0.5) < 1e-6  # mid-warmup
        assert abs(lrs[2] - 1.0) < 1e-6  # peak
        assert lrs[3] < lrs[2]  # decaying
        assert abs(lrs[4] - 0.1) < 1e-3  # floor


class TestCheckpoint:
    def _tree(self, key):
        return {
            "a": jax.random.normal(key, (8, 4)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
        }

    def test_round_trip(self, tmp_path):
        tree = self._tree(jax.random.PRNGKey(0))
        save_checkpoint(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
        )
        restored = restore_checkpoint(str(tmp_path), 7, abstract)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected(self, tmp_path):
        tree = self._tree(jax.random.PRNGKey(1))
        path = save_checkpoint(str(tmp_path), 1, tree)
        victim = os.path.join(path, "a.npy")
        arr = np.load(victim)
        arr = arr + 1.0
        np.save(victim, arr)
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
        )
        with pytest.raises(IOError, match="checksum"):
            restore_checkpoint(str(tmp_path), 1, abstract)

    def test_manager_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree(jax.random.PRNGKey(2))
        for s in [1, 2, 3, 4]:
            mgr.save_async(s, tree)
        mgr.wait()
        names = sorted(os.listdir(tmp_path))
        assert names == ["step_000000003", "step_000000004"]

    def test_shape_mismatch_raises(self, tmp_path):
        tree = self._tree(jax.random.PRNGKey(3))
        save_checkpoint(str(tmp_path), 1, tree)
        bad = {
            "a": jax.ShapeDtypeStruct((4, 4), jnp.float32),
            "nested": {"b": jax.ShapeDtypeStruct((10,), jnp.int32)},
        }
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_checkpoint(str(tmp_path), 1, bad)
