"""Batched multi-camera rendering: equivalence vs the per-camera path.

The batched pipeline reorders *scheduling* only (vmapped features, sort-based
binning, pooled load-balanced tiles) — per-tile blending math is shared with
the per-camera path via ``binning.blend_tile_chunks``. These tests pin that:
``render_batch`` must reproduce per-camera ``render`` on every raster path,
and multi-view-loss gradients must match the averaged per-camera gradients.

Equivalence configs set ``early_exit=False``: the saturation skip is the one
knob whose chunk grouping (and therefore skip decisions) legitimately
differs between the pooled and per-camera schedules, with error bounded by
the <1/255 transmittance contract rather than f32 noise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    orbit_cameras,
    random_gaussians,
    render,
    render_batch,
    render_batch_masked,
    stack_cameras,
    unstack_cameras,
)
from repro.core.binning import bin_gaussians
from repro.core.camera import Camera, look_at_camera
from repro.core.features import compute_features_fused
from repro.core.multicam import CameraBatch, bin_gaussians_batch
from repro.core.rasterize import sort_by_depth
from repro.core.train3dgs import render_loss, render_loss_batch


def _scene(n=256, seed=0):
    return random_gaussians(jax.random.PRNGKey(seed), n, extent=1.5)


def _cams(num=3, size=32):
    return orbit_cameras(num, radius=5.0, width=size, height=size)


class TestCameraBatch:
    def test_stack_unstack_roundtrip(self):
        cams = _cams(4)
        cb = stack_cameras(cams)
        assert isinstance(cb, CameraBatch)
        assert cb.num_cameras == 4
        back = unstack_cameras(cb)
        for a, b in zip(cams, back):
            assert isinstance(b, Camera)
            np.testing.assert_array_equal(np.asarray(a.r_cw), np.asarray(b.r_cw))
            np.testing.assert_array_equal(np.asarray(a.t_cw), np.asarray(b.t_cw))
            assert (a.width, a.height) == (b.width, b.height)

    def test_mixed_sizes_rejected(self):
        a = look_at_camera((0, 1, -5), (0, 0, 0), width=32, height=32)
        b = look_at_camera((0, 1, -5), (0, 0, 0), width=64, height=32)
        with pytest.raises(ValueError, match="static image size"):
            stack_cameras([a, b])

    def test_orbit_stacked_matches_list(self):
        cams = orbit_cameras(5, radius=4.0, width=24, height=24)
        cb = orbit_cameras(5, radius=4.0, width=24, height=24, stacked=True)
        assert isinstance(cb, CameraBatch)
        np.testing.assert_allclose(
            np.asarray(cb.r_cw), np.stack([np.asarray(c.r_cw) for c in cams])
        )

    def test_batch_is_pytree_with_static_size(self):
        cb = orbit_cameras(3, width=16, height=16, stacked=True)
        leaves, treedef = jax.tree.flatten(cb)
        assert all(x.shape[0] == 3 for x in leaves)
        rebuilt = jax.tree.unflatten(treedef, leaves)
        assert (rebuilt.width, rebuilt.height) == (16, 16)

    def test_cam_pos_matches_per_camera(self):
        cb = orbit_cameras(4, width=16, height=16, stacked=True)
        per = np.stack(
            [np.asarray(c.cam_pos) for c in unstack_cameras(cb)]
        )
        np.testing.assert_allclose(np.asarray(cb.cam_pos), per, atol=1e-6)


class TestBatchedBinning:
    def test_lists_match_bin_gaussians(self):
        """Sort-based batched selection == the per-camera top_k lists."""
        g = _scene()
        cams = _cams(3)
        cb = stack_cameras(cams)
        feats = jax.vmap(
            lambda cam: sort_by_depth(compute_features_fused(g, cam))
        )(cb)
        idx, cnt = bin_gaussians_batch(
            feats, 32, 32, tile_size=16, capacity=64
        )
        for i, cam in enumerate(cams):
            f = sort_by_depth(compute_features_fused(g, cam))
            bins = bin_gaussians(f, 32, 32, tile_size=16, capacity=64)
            np.testing.assert_array_equal(
                np.asarray(idx[i]), np.asarray(bins.indices)
            )
            np.testing.assert_array_equal(
                np.asarray(cnt[i]), np.asarray(bins.count)
            )


class TestRenderBatch:
    @pytest.mark.parametrize(
        "path", ["dense", "binned", "pallas", "pallas_binned"]
    )
    def test_matches_per_camera_render(self, path):
        g = _scene()
        cams = _cams(3)
        cb = stack_cameras(cams)
        cfg = RenderConfig(
            raster_path=path,
            tile_capacity=128,
            early_exit=False,
            pixel_chunk=None,
        )
        out = render_batch(g, cb, cfg)
        assert out.shape == (3, 32, 32, 3)
        for i, cam in enumerate(cams):
            want = render(g, cam, cfg)
            np.testing.assert_allclose(
                np.asarray(out[i]), np.asarray(want), atol=1e-5, rtol=1e-5
            )

    def test_early_exit_stays_in_transmittance_contract(self):
        """With the saturation skip on, pooled scheduling may skip different
        chunks than the per-camera path — bounded by the 1/255 contract."""
        g = _scene(n=512)
        cb = stack_cameras(_cams(3))
        cfg = RenderConfig(raster_path="binned", tile_capacity=128)
        out = render_batch(g, cb, cfg)
        for i, cam in enumerate(unstack_cameras(cb)):
            want = render(g, cam, cfg)
            err = float(jnp.max(jnp.abs(out[i] - want)))
            assert err < 2.0 / 255.0, err

    def test_single_camera_batch(self):
        g = _scene(n=128)
        cams = _cams(1)
        cfg = RenderConfig(raster_path="binned", early_exit=False)
        out = render_batch(g, stack_cameras(cams), cfg)
        want = render(g, cams[0], cfg)
        np.testing.assert_allclose(
            np.asarray(out[0]), np.asarray(want), atol=1e-5
        )

    def test_partial_tiles_nonsquare(self):
        """Image size not a multiple of tile_size (crop path, per camera)."""
        g = _scene(n=128)
        cams = orbit_cameras(2, radius=5.0, width=40, height=24)
        cfg = RenderConfig(raster_path="binned", early_exit=False)
        out = render_batch(g, stack_cameras(cams), cfg)
        assert out.shape == (2, 24, 40, 3)
        for i, cam in enumerate(cams):
            np.testing.assert_allclose(
                np.asarray(out[i]),
                np.asarray(render(g, cam, cfg)),
                atol=1e-5,
            )


class TestRenderBatchMasked:
    """Slot-masked render_batch — the continuous-batching serving primitive."""

    @pytest.mark.parametrize("path", ["binned", "dense"])
    def test_active_slots_match_render_batch(self, path):
        g = _scene(n=128)
        cb = stack_cameras(_cams(3))
        cfg = RenderConfig(
            raster_path=path,
            tile_capacity=64,
            early_exit=False,
            pixel_chunk=None,
        )
        active = jnp.asarray([True, False, True])
        masked = render_batch_masked(g, cb, active, cfg)
        full = render_batch(g, cb, cfg)
        for i in (0, 2):
            np.testing.assert_allclose(
                np.asarray(masked[i]), np.asarray(full[i]), atol=1e-6
            )

    def test_inactive_slots_render_background(self):
        g = _scene(n=128)
        cb = stack_cameras(_cams(3))
        cfg = RenderConfig(
            raster_path="binned",
            tile_capacity=64,
            early_exit=False,
            background=(0.25, 0.5, 0.75),
        )
        active = jnp.asarray([False, True, False])
        out = render_batch_masked(g, cb, active, cfg)
        bg = np.broadcast_to(np.asarray(cfg.background), (32, 32, 3))
        np.testing.assert_allclose(np.asarray(out[0]), bg, atol=1e-6)
        np.testing.assert_allclose(np.asarray(out[2]), bg, atol=1e-6)
        assert not np.allclose(np.asarray(out[1]), bg)

    def test_one_executable_any_occupancy(self):
        """The active mask is a traced operand: every occupancy pattern
        hits the same compiled executable."""
        from repro.core import render_batch_masked_jit

        g = _scene(n=64)
        cb = orbit_cameras(3, radius=5.0, width=16, height=16, stacked=True)
        cfg = RenderConfig(raster_path="binned", tile_capacity=64)
        fn = render_batch_masked_jit
        a = fn(g, cb, jnp.asarray([True, True, True]), cfg)
        before = fn._cache_size()
        b = fn(g, cb, jnp.asarray([True, False, False]), cfg)
        assert fn._cache_size() == before  # no retrace
        assert a.shape == b.shape == (3, 16, 16, 3)


@pytest.mark.slow  # batched-vs-per-camera autodiff: ~80s of compiles
class TestBatchedGradients:
    @pytest.mark.parametrize("path", ["binned", "pallas_binned"])
    def test_loss_grads_match_summed_per_camera(self, path):
        """d(mean_i loss_i)/dg through render_batch == the average of the
        per-camera render_loss gradients (well-conditioned: targets come
        from a different cloud, so grads are far from zero)."""
        g = _scene(n=128)
        gt = _scene(n=128, seed=7)
        cams = _cams(3)
        cb = stack_cameras(cams)
        cfg = RenderConfig(
            raster_path=path, tile_capacity=128, early_exit=False
        )
        targets = jnp.stack([render(gt, c, cfg) for c in cams])

        batch_grads = jax.grad(
            lambda gg: render_loss_batch(gg, cb, targets, cfg)
        )(g)
        per_cam = [
            jax.grad(lambda gg, c=c, t=t: render_loss(gg, c, t, cfg))(g)
            for c, t in zip(cams, targets)
        ]
        mean_grads = jax.tree.map(
            lambda *xs: sum(xs) / len(xs), *per_cam
        )
        for name in ["positions", "quats", "log_scales", "sh", "opacity_logit"]:
            a = np.asarray(getattr(batch_grads, name))
            b = np.asarray(getattr(mean_grads, name))
            scale = max(1e-3, float(np.abs(b).max()))
            assert float(np.abs(a - b).max()) <= 1e-4 * scale, name

    def test_gradients_flow_and_finite(self):
        g = _scene(n=64)
        cb = stack_cameras(_cams(2))
        cfg = RenderConfig(raster_path="binned", tile_capacity=64)
        targets = jnp.zeros((2, 32, 32, 3))
        grads = jax.grad(
            lambda gg: render_loss_batch(gg, cb, targets, cfg)
        )(g)
        for name in ["positions", "quats", "log_scales", "sh", "opacity_logit"]:
            gn = float(jnp.linalg.norm(getattr(grads, name)))
            assert np.isfinite(gn) and gn > 0.0, name


class TestRenderBatchJit:
    def test_one_executable_many_batches(self):
        """Same static shapes -> the jitted entry point retraces once."""
        from repro.core import render_batch_jit

        g = _scene(n=64)
        cfg = RenderConfig(raster_path="binned", tile_capacity=64)
        cams_a = orbit_cameras(2, radius=5.0, width=16, height=16, stacked=True)
        cams_b = orbit_cameras(2, radius=3.0, width=16, height=16, stacked=True)
        a = render_batch_jit(g, cams_a, cfg)
        b = render_batch_jit(g, cams_b, cfg)
        assert a.shape == b.shape == (2, 16, 16, 3)
        assert not np.allclose(np.asarray(a), np.asarray(b))
        # different radius -> same treedef/static config -> cache hit
        assert jax.tree.structure(cams_a) == jax.tree.structure(cams_b)
