"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import look_at_camera, random_gaussians
from repro.core.features import compute_features_fused
from repro.core.rasterize import pixel_grid, sort_by_depth
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gaussian_features.ops import gaussian_features_packed
from repro.kernels.gaussian_features.ref import (
    gaussian_features_ref,
    pack_features,
    unpack_features,
)
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.tile_rasterize.ops import (
    tile_rasterize,
    tile_rasterize_compact,
)
from repro.kernels.tile_rasterize.ref import tile_rasterize_ref


class TestGaussianFeaturesKernel:
    @pytest.mark.parametrize("n", [64, 100, 513, 2048])
    @pytest.mark.parametrize("block", [128, 512])
    def test_shape_sweep(self, n, block):
        g = random_gaussians(jax.random.PRNGKey(n), n)
        cam = look_at_camera((1, 2, -5), (0, 0, 0), width=80, height=60)
        got = gaussian_features_packed(g, cam, block=block)
        want = gaussian_features_ref(g, cam)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("deg", [0, 1, 2, 3])
    def test_degree_sweep(self, deg):
        g = random_gaussians(jax.random.PRNGKey(7), 256)
        cam = look_at_camera((0, 0.5, -4), (0, 0, 0), width=64, height=64)
        got = gaussian_features_packed(g, cam, sh_degree=deg)
        want = gaussian_features_ref(g, cam, sh_degree=deg)
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)

    def test_extreme_inputs_no_nan(self):
        g = random_gaussians(jax.random.PRNGKey(1), 128, base_scale=10.0)
        g.positions = g.positions * 100.0  # far outside the frustum
        cam = look_at_camera((0, 0, -2), (0, 0, 0), width=32, height=32)
        got = np.asarray(gaussian_features_packed(g, cam))
        assert np.isfinite(got).all()


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "b,h,hk,t,d,causal,window",
        [
            (2, 4, 4, 256, 64, True, None),  # MHA causal
            (2, 8, 2, 256, 64, True, None),  # GQA 4:1
            (1, 4, 1, 384, 128, True, None),  # MQA, d=128
            (2, 4, 2, 256, 64, False, None),  # bidirectional
            (1, 8, 4, 512, 64, True, 128),  # sliding window
            (1, 2, 2, 128, 32, True, 32),  # small window
        ],
    )
    def test_variants(self, b, h, hk, t, d, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(t + h), 3)
        q = jax.random.normal(ks[0], (b, h, t, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hk, t, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hk, t, d), jnp.float32)
        got = flash_attention(q, k, v, causal=causal, window=window)
        want = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.bfloat16)
        got = flash_attention(q, k, v).astype(jnp.float32)
        want = attention_ref(q, k, v).astype(jnp.float32)
        assert float(jnp.max(jnp.abs(got - want))) < 0.05

    @pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 256), (256, 128)])
    def test_block_shape_invariance(self, block_q, block_k):
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (1, 4, 512, 64))
        k = jax.random.normal(ks[1], (1, 4, 512, 64))
        v = jax.random.normal(ks[2], (1, 4, 512, 64))
        got = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
        want = attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestSSDKernel:
    @pytest.mark.parametrize(
        "b,h,t,p,n,chunk",
        [
            (2, 4, 256, 64, 128, 128),
            (1, 2, 512, 32, 64, 128),
            (2, 3, 128, 16, 32, 64),
            (1, 1, 64, 8, 16, 64),
        ],
    )
    def test_vs_sequential(self, b, h, t, p, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(t * h), 5)
        x = jax.random.normal(ks[0], (b, h, t, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, t)) - 1.0)
        bm = jax.random.normal(ks[2], (b, h, t, n)) / np.sqrt(n)
        cm = jax.random.normal(ks[3], (b, h, t, n)) / np.sqrt(n)
        a = -jnp.exp(jax.random.normal(ks[4], (h,)))
        y_k, h_k = ssd_scan(x, dt, bm, cm, a, chunk=chunk)
        y_r, h_r = ssd_scan_ref(x, dt, bm, cm, a)
        np.testing.assert_allclose(y_k, y_r, rtol=2e-3, atol=2e-4)
        np.testing.assert_allclose(h_k, h_r, rtol=2e-3, atol=2e-4)

    @pytest.mark.parametrize("chunk", [32, 64, 128, 256])
    def test_chunk_invariance(self, chunk):
        """The chunk size is an implementation detail — results identical."""
        ks = jax.random.split(jax.random.PRNGKey(5), 5)
        x = jax.random.normal(ks[0], (1, 2, 256, 16))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 2, 256)))
        bm = jax.random.normal(ks[2], (1, 2, 256, 32)) / np.sqrt(32)
        cm = jax.random.normal(ks[3], (1, 2, 256, 32)) / np.sqrt(32)
        a = -jnp.exp(jax.random.normal(ks[4], (2,)))
        y, hf = ssd_scan(x, dt, bm, cm, a, chunk=chunk)
        y_ref, h_ref = ssd_scan_ref(x, dt, bm, cm, a)
        np.testing.assert_allclose(y, y_ref, rtol=2e-3, atol=2e-4)


class TestTileRasterizeKernel:
    @pytest.mark.parametrize("n,size", [(100, 32), (500, 48), (1000, 64)])
    def test_vs_fullimage_oracle(self, n, size):
        g = random_gaussians(jax.random.PRNGKey(n), n)
        cam = look_at_camera((0, 1, -6), (0, 0, 0), width=size, height=size)
        feats = sort_by_depth(compute_features_fused(g, cam))
        packed = pack_features(feats)
        bg = jnp.array([0.1, 0.2, 0.3])
        got = tile_rasterize(packed, cam.height, cam.width, bg)
        pix = pixel_grid(cam.height, cam.width)
        want = tile_rasterize_ref(pix, packed, bg)[:, :3].reshape(
            cam.height, cam.width, 3
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_block_size_invariance(self):
        g = random_gaussians(jax.random.PRNGKey(3), 512)
        cam = look_at_camera((0, 1, -6), (0, 0, 0), width=32, height=32)
        packed = pack_features(sort_by_depth(compute_features_fused(g, cam)))
        bg = jnp.zeros(3)
        a = tile_rasterize(packed, 32, 32, bg, block_g=128)
        b = tile_rasterize(packed, 32, 32, bg, block_g=256)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


class TestCompactRasterizeKernel:
    """Gather-to-compact Pallas kernel: forward vs the full-image oracle,
    custom VJP vs jnp autodiff through the binned path (interpret mode)."""

    @pytest.mark.parametrize("n,size", [(100, 32), (500, 48), (1000, 64)])
    def test_vs_fullimage_oracle(self, n, size):
        g = random_gaussians(jax.random.PRNGKey(n), n)
        cam = look_at_camera((0, 1, -6), (0, 0, 0), width=size, height=size)
        packed = pack_features(sort_by_depth(compute_features_fused(g, cam)))
        bg = jnp.array([0.1, 0.2, 0.3])
        got = tile_rasterize_compact(
            packed, cam.height, cam.width, bg, capacity=n
        )
        pix = pixel_grid(cam.height, cam.width)
        want = tile_rasterize_ref(pix, packed, bg)[:, :3].reshape(
            cam.height, cam.width, 3
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_chunk_width_invariance(self):
        g = random_gaussians(jax.random.PRNGKey(3), 512)
        cam = look_at_camera((0, 1, -6), (0, 0, 0), width=32, height=32)
        packed = pack_features(sort_by_depth(compute_features_fused(g, cam)))
        bg = jnp.zeros(3)
        a = tile_rasterize_compact(packed, 32, 32, bg, capacity=512, block_g=128)
        b = tile_rasterize_compact(packed, 32, 32, bg, capacity=512, block_g=256)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize(
        "capacity",
        # capacity 64 forces the overflow path and costs an extra ~13s of
        # backward-kernel compile: slow-marked, CI's explicit kernel step
        # still runs it (that step overrides the not-slow default).
        [pytest.param(64, marks=pytest.mark.slow), 300],
    )
    def test_custom_vjp_matches_jnp_binned_grads(self, capacity):
        """The ISSUE acceptance bar at the packed-feature level: gradients
        for uv / conic / color / opacity through the backward Pallas kernel
        equal jnp autodiff through the binned path to 1e-4 — including
        under list overflow (capacity 64 overflows this scene)."""
        from repro.core import binning

        g = random_gaussians(jax.random.PRNGKey(7), 300, base_scale=0.1)
        cam = look_at_camera((0, 1, -6), (0, 0, 0), width=48, height=48)
        packed = pack_features(sort_by_depth(compute_features_fused(g, cam)))
        bg = jnp.array([0.2, 0.1, 0.3])
        target = jnp.linspace(0, 1, 48 * 48 * 3).reshape(48, 48, 3)

        def loss_pallas(p):
            img = tile_rasterize_compact(p, 48, 48, bg, capacity=capacity)
            return jnp.mean((img - target) ** 2)

        def loss_jnp(p):
            feats = unpack_features(p)
            bins = binning.bin_gaussians(feats, 48, 48, capacity=capacity)
            img = binning.rasterize_binned(
                feats, bins, 48, 48, bg, early_exit=False
            )
            return jnp.mean((img - target) ** 2)

        lp, gp = jax.value_and_grad(loss_pallas)(packed)
        lj, gj = jax.value_and_grad(loss_jnp)(packed)
        np.testing.assert_allclose(float(lp), float(lj), rtol=1e-5)
        gp, gj = np.asarray(gp), np.asarray(gj)
        rows = {
            "uv": slice(0, 2),
            "conic": slice(2, 5),
            "color": slice(5, 8),
            "opacity": slice(10, 11),
        }
        for name, sl in rows.items():
            assert np.isfinite(gp[sl]).all(), name
            np.testing.assert_allclose(
                gp[sl], gj[sl], rtol=1e-4, atol=1e-7, err_msg=name
            )

    def test_background_gradient(self):
        """d(loss)/d(bg) flows through the custom VJP's jnp-side term."""
        g = random_gaussians(jax.random.PRNGKey(1), 128)
        cam = look_at_camera((0, 1, -6), (0, 0, 0), width=32, height=32)
        packed = pack_features(sort_by_depth(compute_features_fused(g, cam)))

        def loss(bg):
            img = tile_rasterize_compact(packed, 32, 32, bg, capacity=128)
            return jnp.mean(img)

        gbg = np.asarray(jax.grad(loss)(jnp.zeros(3)))
        assert np.isfinite(gbg).all() and (gbg > 0).all()


class TestRMSNormKernel:
    @pytest.mark.parametrize(
        "shape,dtype",
        [
            ((2, 64, 128), jnp.float32),
            ((4, 100, 256), jnp.float32),
            ((1, 512, 128), jnp.bfloat16),
            ((8, 384), jnp.float32),
        ],
    )
    def test_vs_layers_oracle(self, shape, dtype):
        from repro.kernels.rmsnorm.ops import rmsnorm
        from repro.kernels.rmsnorm.ref import rmsnorm_ref

        key = jax.random.PRNGKey(sum(shape))
        x = jax.random.normal(key, shape, dtype)
        scale = 1.0 + 0.1 * jax.random.normal(
            jax.random.fold_in(key, 1), (shape[-1],), dtype
        )
        got = rmsnorm(x, scale, eps=1e-5).astype(jnp.float32)
        want = rmsnorm_ref(x, scale, 1e-5).astype(jnp.float32)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_block_rows_invariance(self):
        from repro.kernels.rmsnorm.ops import rmsnorm

        x = jax.random.normal(jax.random.PRNGKey(0), (300, 128))
        scale = jnp.ones((128,))
        a = rmsnorm(x, scale, block_rows=64)
        b = rmsnorm(x, scale, block_rows=256)
        np.testing.assert_allclose(a, b, rtol=1e-6)
