"""RenderServer: micro-batching correctness, padding, and stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RenderConfig, orbit_cameras, random_gaussians, render
from repro.core.camera import look_at_camera
from repro.serve import RenderServer


SIZE = 32


def _server(model, **kw):
    cfg = RenderConfig(raster_path="binned", tile_capacity=64, early_exit=False)
    kw.setdefault("width", SIZE)
    kw.setdefault("height", SIZE)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_ms", 10.0)
    return RenderServer(model, cfg, **kw)


class TestRenderServer:
    def test_results_match_direct_render(self):
        model = random_gaussians(jax.random.PRNGKey(0), 128, extent=1.5)
        cams = orbit_cameras(6, radius=5.0, width=SIZE, height=SIZE)
        with _server(model) as srv:
            futures = [srv.submit(c) for c in cams]
            results = [f.result(timeout=120) for f in futures]
        cfg = srv.config
        for cam, res in zip(cams, results):
            want = render(model, cam, cfg)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )
            assert res.latency_ms > 0.0
            assert 1 <= res.batch_size <= 4

    def test_padding_partial_batch(self):
        """3 requests into 4 slots: sentinel padding, results still exact."""
        model = random_gaussians(jax.random.PRNGKey(1), 64, extent=1.5)
        cams = orbit_cameras(3, radius=5.0, width=SIZE, height=SIZE)
        with _server(model) as srv:
            results = [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        stats = srv.stats()
        assert stats["requests"] == 3
        # all three landed in one (padded) batch or trickled into smaller
        # ones — occupancy must reflect real requests only
        assert 0.0 < stats["occupancy"] <= 1.0
        assert stats["mean_batch_size"] <= 3.0
        for cam, res in zip(cams, results):
            want = render(model, cam, srv.config)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )

    def test_stats_and_compile_time_reported(self):
        model = random_gaussians(jax.random.PRNGKey(2), 64, extent=1.5)
        srv = _server(model)
        assert srv.compile_ms is None
        with srv:
            assert srv.compile_ms is not None and srv.compile_ms > 0.0
            cams = orbit_cameras(5, radius=5.0, width=SIZE, height=SIZE)
            [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        stats = srv.stats()
        assert stats["requests"] == 5
        assert stats["batches"] >= 1
        assert stats["latency_ms_p50"] > 0.0
        assert stats["latency_ms_p95"] >= stats["latency_ms_p50"]
        assert stats["compile_ms"] == srv.compile_ms

    def test_rejects_mismatched_size(self):
        model = random_gaussians(jax.random.PRNGKey(3), 64, extent=1.5)
        with _server(model) as srv:
            bad = look_at_camera((0, 1, -5), (0, 0, 0), width=64, height=64)
            with pytest.raises(ValueError, match="static"):
                srv.submit(bad)

    def test_submit_requires_started_server(self):
        model = random_gaussians(jax.random.PRNGKey(4), 64, extent=1.5)
        srv = _server(model)
        cam = look_at_camera((0, 1, -5), (0, 0, 0), width=SIZE, height=SIZE)
        with pytest.raises(RuntimeError, match="not started"):
            srv.submit(cam)

    def test_blocking_render_helper(self):
        model = random_gaussians(jax.random.PRNGKey(5), 64, extent=1.5)
        cam = look_at_camera((0, 1, -5), (0, 0, 0), width=SIZE, height=SIZE)
        with _server(model, max_wait_ms=1.0) as srv:
            res = srv.render(cam)
        want = render(model, cam, srv.config)
        np.testing.assert_allclose(
            np.asarray(res.image), np.asarray(want), atol=1e-5
        )
        assert res.batch_size == 1  # nothing else in the window

    def test_many_requests_fill_batches(self):
        """A burst larger than the slot count produces full batches."""
        model = random_gaussians(jax.random.PRNGKey(6), 64, extent=1.5)
        cams = orbit_cameras(8, radius=5.0, width=SIZE, height=SIZE)
        with _server(model, max_batch=4, max_wait_ms=50.0) as srv:
            results = [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        sizes = {r.batch_size for r in results}
        assert max(sizes) >= 2  # the burst batched, not 8 singletons
        assert srv.stats()["requests"] == 8
