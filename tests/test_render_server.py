"""RenderServer: continuous-batching scheduler (slot refill, buckets,
generation routing, cancellation) + the micro-batching baseline."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RenderConfig, orbit_cameras, random_gaussians, render
from repro.core.camera import look_at_camera
from repro.serve import RenderServer


SIZE = 32


def _server(model, **kw):
    cfg = RenderConfig(raster_path="binned", tile_capacity=64, early_exit=False)
    kw.setdefault("width", SIZE)
    kw.setdefault("height", SIZE)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_ms", 10.0)
    return RenderServer(model, cfg, **kw)


class TestRenderServer:
    def test_results_match_direct_render(self):
        model = random_gaussians(jax.random.PRNGKey(0), 128, extent=1.5)
        cams = orbit_cameras(6, radius=5.0, width=SIZE, height=SIZE)
        with _server(model) as srv:
            futures = [srv.submit(c) for c in cams]
            results = [f.result(timeout=120) for f in futures]
        cfg = srv.config
        for cam, res in zip(cams, results):
            want = render(model, cam, cfg)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )
            assert res.latency_ms > 0.0
            assert 1 <= res.batch_size <= 4

    def test_padding_partial_batch(self):
        """3 requests into 4 slots: sentinel padding, results still exact."""
        model = random_gaussians(jax.random.PRNGKey(1), 64, extent=1.5)
        cams = orbit_cameras(3, radius=5.0, width=SIZE, height=SIZE)
        with _server(model) as srv:
            results = [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        stats = srv.stats()
        assert stats["requests"] == 3
        # all three landed in one (padded) batch or trickled into smaller
        # ones — occupancy must reflect real requests only
        assert 0.0 < stats["occupancy"] <= 1.0
        assert stats["mean_batch_size"] <= 3.0
        for cam, res in zip(cams, results):
            want = render(model, cam, srv.config)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )

    def test_stats_and_compile_time_reported(self):
        model = random_gaussians(jax.random.PRNGKey(2), 64, extent=1.5)
        srv = _server(model)
        assert srv.compile_ms is None
        with srv:
            assert srv.compile_ms is not None and srv.compile_ms > 0.0
            cams = orbit_cameras(5, radius=5.0, width=SIZE, height=SIZE)
            [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        stats = srv.stats()
        assert stats["requests"] == 5
        assert stats["batches"] >= 1
        assert stats["latency_ms_p50"] > 0.0
        assert stats["latency_ms_p95"] >= stats["latency_ms_p50"]
        assert stats["compile_ms"] == srv.compile_ms

    def test_rejects_mismatched_size(self):
        model = random_gaussians(jax.random.PRNGKey(3), 64, extent=1.5)
        with _server(model) as srv:
            bad = look_at_camera((0, 1, -5), (0, 0, 0), width=64, height=64)
            with pytest.raises(ValueError, match="static"):
                srv.submit(bad)

    def test_submit_requires_started_server(self):
        model = random_gaussians(jax.random.PRNGKey(4), 64, extent=1.5)
        srv = _server(model)
        cam = look_at_camera((0, 1, -5), (0, 0, 0), width=SIZE, height=SIZE)
        with pytest.raises(RuntimeError, match="not started"):
            srv.submit(cam)

    def test_blocking_render_helper(self):
        model = random_gaussians(jax.random.PRNGKey(5), 64, extent=1.5)
        cam = look_at_camera((0, 1, -5), (0, 0, 0), width=SIZE, height=SIZE)
        with _server(model, max_wait_ms=1.0) as srv:
            res = srv.render(cam)
        want = render(model, cam, srv.config)
        np.testing.assert_allclose(
            np.asarray(res.image), np.asarray(want), atol=1e-5
        )
        assert res.batch_size == 1  # nothing else in the window

    def test_many_requests_fill_batches(self):
        """A burst larger than the slot count produces full batches."""
        model = random_gaussians(jax.random.PRNGKey(6), 64, extent=1.5)
        cams = orbit_cameras(8, radius=5.0, width=SIZE, height=SIZE)
        with _server(model, max_batch=4, max_wait_ms=50.0) as srv:
            results = [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        sizes = {r.batch_size for r in results}
        assert max(sizes) >= 2  # the burst batched, not 8 singletons
        assert srv.stats()["requests"] == 8


class TestContinuousScheduler:
    """Slot refill, bucket routing, and stats of the continuous mode."""

    def test_invalid_mode_rejected(self):
        model = random_gaussians(jax.random.PRNGKey(0), 32, extent=1.5)
        with pytest.raises(ValueError, match="mode"):
            _server(model, mode="windowed")

    def test_bursty_poisson_arrivals_match_sequential_render(self):
        """Every admitted camera's result equals the sequential render,
        under a seeded bursty Poisson arrival stream."""
        model = random_gaussians(jax.random.PRNGKey(7), 96, extent=1.5)
        cams = orbit_cameras(10, radius=5.0, width=SIZE, height=SIZE)
        rng = np.random.default_rng(0)
        # Bursts of 1-3 requests at exponential gaps: slots free and refill
        # at staggered times, exercising mid-flight admission.
        gaps = rng.exponential(0.01, size=len(cams))
        burst = rng.integers(1, 4, size=len(cams))
        with _server(model, max_batch=2) as srv:
            futures = []
            i = 0
            while i < len(cams):
                for _ in range(int(burst[i % len(burst)])):
                    if i >= len(cams):
                        break
                    futures.append(srv.submit(cams[i]))
                    i += 1
                time.sleep(gaps[i % len(gaps)])
            results = [f.result(timeout=120) for f in futures]
        assert srv.stats()["requests"] == len(cams)
        for cam, res in zip(cams, results):
            want = render(model, cam, srv.config)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )

    def test_no_request_waits_a_window_behind_a_freed_slot(self):
        """The continuous scheduler serves a straggler immediately; the
        micro-batching baseline makes it wait out a whole window."""
        model = random_gaussians(jax.random.PRNGKey(8), 64, extent=1.5)
        cams = orbit_cameras(5, radius=5.0, width=SIZE, height=SIZE)
        window_ms = 250.0

        def run(mode):
            srv = _server(
                model, max_batch=4, max_wait_ms=window_ms, mode=mode
            )
            with srv:
                t0 = time.perf_counter()
                futures = [srv.submit(c) for c in cams]
                for f in futures:
                    f.result(timeout=120)
                return time.perf_counter() - t0

        # Burst of 5 into 4 slots: the baseline's second window holds only
        # the straggler and waits the full max_wait_ms for company; the
        # continuous scheduler admits it the moment a slot frees.
        micro_wall = run("microbatch")
        cont_wall = run("continuous")
        assert micro_wall >= window_ms / 1e3  # the straggler ate a window
        assert cont_wall < micro_wall

    def test_mixed_size_bucket_routing(self):
        """Requests route to their exact bucket executable; results match
        the per-camera render at each size; unknown sizes are rejected."""
        model = random_gaussians(jax.random.PRNGKey(9), 96, extent=1.5)
        cfg = RenderConfig(raster_path="binned", tile_capacity=64, early_exit=False)
        small = orbit_cameras(3, radius=5.0, width=32, height=32)
        large = orbit_cameras(3, radius=5.0, width=48, height=48)
        interleaved = [c for pair in zip(small, large) for c in pair]
        srv = RenderServer(
            model, cfg, sizes=[(32, 32), (48, 48)], max_batch=4
        )
        with srv:
            with pytest.raises(ValueError, match="bucket"):
                srv.submit(look_at_camera((0, 1, -5), (0, 0, 0), width=64, height=64))
            results = [
                f.result(timeout=120)
                for f in [srv.submit(c) for c in interleaved]
            ]
        assert set(srv.compile_ms_by_bucket) == {(32, 32), (48, 48)}
        for cam, res in zip(interleaved, results):
            assert res.image.shape == (cam.height, cam.width, 3)
            want = render(model, cam, cfg)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )

    def test_microbatch_mode_rejects_multiple_buckets(self):
        model = random_gaussians(jax.random.PRNGKey(10), 32, extent=1.5)
        cfg = RenderConfig(raster_path="binned", tile_capacity=64)
        with pytest.raises(ValueError, match="single-size"):
            RenderServer(
                model, cfg, sizes=[(32, 32), (48, 48)], mode="microbatch"
            )

    def test_stats_report_mode_and_occupancy(self):
        model = random_gaussians(jax.random.PRNGKey(11), 64, extent=1.5)
        cams = orbit_cameras(6, radius=5.0, width=SIZE, height=SIZE)
        with _server(model) as srv:
            [f.result(timeout=120) for f in [srv.submit(c) for c in cams]]
        stats = srv.stats()
        assert stats["mode"] == "continuous"
        assert stats["requests"] == 6
        assert stats["batches"] >= 2  # max_batch=4 < 6 requests
        assert 0.0 < stats["occupancy"] <= 1.0


class TestCancellation:
    """A cancelled client future must not poison its batch (the PR 3 bug:
    unguarded set_result raised InvalidStateError into the batcher's
    exception handler, which then failed every other request in the group)."""

    def test_cancelled_future_does_not_poison_microbatch(self):
        """Deterministic pin: cancel inside an open micro-batching window
        (the batch has not been claimed yet), the rest must still be served."""
        model = random_gaussians(jax.random.PRNGKey(12), 64, extent=1.5)
        cams = orbit_cameras(4, radius=5.0, width=SIZE, height=SIZE)
        # max_batch > len(cams): the window stays open for max_wait_ms, so
        # the cancel always lands before the batch is claimed.
        with _server(
            model, max_batch=8, max_wait_ms=400.0, mode="microbatch"
        ) as srv:
            futures = [srv.submit(c) for c in cams]
            assert futures[1].cancel()
            survivors = [f for i, f in enumerate(futures) if i != 1]
            results = [f.result(timeout=120) for f in survivors]
        assert futures[1].cancelled()
        kept = [c for i, c in enumerate(cams) if i != 1]
        for cam, res in zip(kept, results):
            want = render(model, cam, srv.config)
            np.testing.assert_allclose(
                np.asarray(res.image), np.asarray(want), atol=1e-5
            )
        # Only the three survivors were rendered and counted.
        assert srv.stats()["requests"] == 3

    def test_stop_still_serves_other_bucket_behind_cancelled_head(self):
        """Shutdown liveness: a cancelled request heading the oldest bucket
        must not strand a valid pre-stop request in another bucket (the
        scheduler must re-pick buckets until every pending deque drains)."""
        model = random_gaussians(jax.random.PRNGKey(14), 64, extent=1.5)
        cfg = RenderConfig(raster_path="binned", tile_capacity=64, early_exit=False)
        srv = RenderServer(model, cfg, sizes=[(32, 32), (48, 48)], max_batch=4)
        first = orbit_cameras(4, radius=5.0, width=32, height=32)
        cam_a = look_at_camera((0, 1, -5), (0, 0, 0), width=32, height=32)
        cam_b = look_at_camera((0, 1, -4), (0, 0, 0), width=48, height=48)
        with srv:
            # Occupy the scheduler with a full step so A and B queue behind
            # it; cancel A while it is (very likely) still unclaimed, then
            # stop() immediately — B must still be served, not failed.
            busy = [srv.submit(c) for c in first]
            fut_a = srv.submit(cam_a)
            fut_b = srv.submit(cam_b)
            a_cancelled = fut_a.cancel()
        [f.result(timeout=120) for f in busy]
        res_b = fut_b.result(timeout=120)
        np.testing.assert_allclose(
            np.asarray(res_b.image),
            np.asarray(render(model, cam_b, cfg)),
            atol=1e-5,
        )
        if not a_cancelled:  # scheduler claimed A first: it must be served
            assert fut_a.result(timeout=120).image.shape == (32, 32, 3)

    def test_cancel_one_of_n_inflight_continuous(self):
        """Cancelling one of N requests mid-flight never breaks the rest.
        (Whether the cancel wins depends on whether the scheduler claimed
        the future first — both outcomes must leave the others served.)"""
        model = random_gaussians(jax.random.PRNGKey(13), 64, extent=1.5)
        cams = orbit_cameras(8, radius=5.0, width=SIZE, height=SIZE)
        with _server(model, max_batch=2) as srv:
            futures = [srv.submit(c) for c in cams]
            won = futures[5].cancel()
            for i, f in enumerate(futures):
                if i == 5 and won:
                    assert f.cancelled()
                    continue
                res = f.result(timeout=120)
                want = render(model, cams[i], srv.config)
                np.testing.assert_allclose(
                    np.asarray(res.image), np.asarray(want), atol=1e-5
                )
        served = len(cams) - (1 if won else 0)
        assert srv.stats()["requests"] == served
