"""3DGS training substrate: densification invariants + end-to-end fit."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RenderConfig, look_at_camera, random_gaussians, render
from repro.core.train3dgs import (
    accumulate_grad_stats,
    densify_and_prune,
    init_densify_state,
    render_loss,
    reset_opacity,
)


class TestDensify:
    def _setup(self, capacity=64, initial=32):
        g = random_gaussians(jax.random.PRNGKey(0), capacity)
        st = init_densify_state(capacity, initial)
        return g, st

    def test_capacity_never_exceeded(self):
        g, st = self._setup()
        st = accumulate_grad_stats(st, jnp.ones((64, 2)), jnp.ones(64))
        g2, st2 = densify_and_prune(g, st, jax.random.PRNGKey(1))
        assert int(st2.active.sum()) <= 64
        assert g2.positions.shape == g.positions.shape  # fixed allocation

    def test_no_candidates_no_change_in_active(self):
        g, st = self._setup()
        # zero gradients -> nothing to clone/split; nothing pruned (opacity hi)
        g2, st2 = densify_and_prune(g, st, jax.random.PRNGKey(1))
        active_before = int(st.active.sum())
        # only low-opacity pruning can reduce; our random init has logit+1.5
        assert int(st2.active.sum()) <= active_before + 0  # no growth

    def test_prune_low_opacity(self):
        g, st = self._setup()
        g = dataclasses.replace(
            g, opacity_logit=jnp.full_like(g.opacity_logit, -10.0)
        )
        g2, st2 = densify_and_prune(g, st, jax.random.PRNGKey(2))
        assert int(st2.active.sum()) == 0

    def test_split_shrinks_scales(self):
        g, st = self._setup()
        g = dataclasses.replace(g, log_scales=jnp.zeros_like(g.log_scales))  # big
        st = accumulate_grad_stats(st, jnp.ones((64, 2)), jnp.ones(64))
        g2, st2 = densify_and_prune(g, st, jax.random.PRNGKey(3))
        # originals that split must have shrunk by log(1.6)
        shrunk = np.asarray(g2.log_scales[:32])
        assert (shrunk < 0).all()

    def test_grad_stats_reset_after_event(self):
        g, st = self._setup()
        st = accumulate_grad_stats(st, jnp.ones((64, 2)), jnp.ones(64))
        _, st2 = densify_and_prune(g, st, jax.random.PRNGKey(4))
        assert float(st2.grad_accum.max()) == 0.0
        assert float(st2.count.max()) == 0.0

    def test_opacity_reset_caps_active_only(self):
        g, st = self._setup()
        g2 = reset_opacity(g, st)
        active = np.asarray(st.active)
        op = np.asarray(g2.opacities())
        assert op[active].max() <= 0.011
        # inactive slots untouched
        np.testing.assert_array_equal(
            np.asarray(g2.opacity_logit)[~active],
            np.asarray(g.opacity_logit)[~active],
        )


@pytest.mark.slow
def test_end_to_end_fit_loss_drops():
    """Optimize a fresh cloud against rendered targets — loss must drop >30%."""
    key = jax.random.PRNGKey(0)
    gt = random_gaussians(key, 128, extent=1.0)
    cam = look_at_camera((0, 1.0, -5.0), (0, 0, 0), width=32, height=32)
    target = render(gt, cam)

    g = random_gaussians(jax.random.PRNGKey(1), 128, extent=1.0)

    from repro.optim import AdamWConfig, adamw_init, adamw_update

    ocfg = AdamWConfig(
        learning_rate=2e-2, weight_decay=0.0, warmup_steps=0, total_steps=1000,
        clip_norm=1e9,
    )
    opt = adamw_init(g)

    cfg = RenderConfig(pixel_chunk=None)

    @jax.jit
    def step(g, opt):
        loss, grads = jax.value_and_grad(
            lambda gg: render_loss(gg, cam, target, cfg)
        )(g)
        g, opt, _ = adamw_update(ocfg, g, grads, opt)
        return g, opt, loss

    losses = []
    for i in range(120):
        g, opt, loss = step(g, opt)
        losses.append(float(loss))
    assert losses[-1] < 0.7 * losses[0], (losses[0], losses[-1])
