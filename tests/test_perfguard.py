"""tools/perfguard: declarative perf-regression gating over BENCH files.

Unit-tests the decision core (dotted-path resolution with dots *inside*
keys, median/MAD noise margins, absolute vs relative checks, profile
gating) and then pins the CLI end-to-end the way CI runs it: a passing
fixture bench exits 0, a bench with serving req/s degraded 40%% exits 1
and emits the GitHub error annotation, and ``update-baseline`` writes a
provenance-stamped baseline. Fixture benches are built *from the shipped
pyproject budgets* so these tests keep pinning whatever budget set the
repo actually declares.
"""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))  # `tools` package lives at the repo root

from tools.perfguard.bench import (  # noqa: E402
    build_baseline,
    latest_bench,
    load_baseline,
    provenance_meta,
    write_baseline,
)
from tools.perfguard.budgets import (  # noqa: E402
    Budget,
    evaluate_budget,
    evaluate_budgets,
    mad,
    median,
    resolve_metric,
)
from tools.perfguard.config import load_config  # noqa: E402

# -- robust statistics -----------------------------------------------------


class TestStats:
    def test_median(self):
        assert median([3.0]) == 3.0
        assert median([1.0, 9.0, 2.0]) == 2.0
        assert median([1.0, 2.0, 3.0, 10.0]) == 2.5
        with pytest.raises(ValueError):
            median([])

    def test_mad_ignores_one_outlier(self):
        # One wild trial moves neither the median nor the MAD much — the
        # whole reason the noise margin uses the robust pair.
        clean = [10.0, 10.1, 9.9, 10.05, 9.95]
        dirty = clean[:-1] + [30.0]
        assert median(dirty) == pytest.approx(median(clean), rel=0.01)
        assert mad(dirty) < 0.2


# -- dotted-path resolution ------------------------------------------------


class TestResolveMetric:
    TREE = {
        "bench_serving": {
            "scheduler_sweep": {
                "1.5x_capacity": {"continuous_speedup": 1.2},
                "burst": {"continuous_speedup": 1.1},
            },
            "server": {"req_s": 13.0},
        }
    }

    def test_plain_path(self):
        assert resolve_metric(self.TREE, "bench_serving.server.req_s") == 13.0

    def test_dots_inside_keys(self):
        # "1.5x_capacity" contains dots: naive split(".") can't address it.
        assert (
            resolve_metric(
                self.TREE,
                "bench_serving.scheduler_sweep.1.5x_capacity.continuous_speedup",
            )
            == 1.2
        )

    def test_longest_key_wins(self):
        tree = {"a": {"b": {"c": 1.0}}, "a.b": {"c": 2.0}}
        assert resolve_metric(tree, "a.b.c") == 2.0

    def test_missing_returns_none(self):
        assert resolve_metric(self.TREE, "bench_serving.nope") is None
        assert resolve_metric(self.TREE, "bench_serving.server.req_s.deeper") is None


# -- budget evaluation -----------------------------------------------------


def _budget(**kw) -> Budget:
    kw.setdefault("name", "b")
    kw.setdefault("metric", "m")
    return Budget(**kw)


class TestEvaluateBudget:
    def test_absolute_floor_and_ceiling(self):
        b = _budget(min=1.5, relative=False)
        assert evaluate_budget(b, {"m": 2.0}, None, profile_match=True).status == "pass"
        r = evaluate_budget(b, {"m": 1.0}, None, profile_match=True)
        assert r.status == "regress" and "absolute floor" in r.message
        b = _budget(max=0.45, better="lower", relative=False)
        r = evaluate_budget(b, {"m": 0.5}, None, profile_match=True)
        assert r.status == "regress" and "absolute ceiling" in r.message

    def test_relative_band_and_improve(self):
        base = {"median": 10.0, "mad": 0.0}
        b = _budget(rel_tolerance=0.25, mad_k=3.0)
        ok = evaluate_budget(b, {"m": 8.0}, base, profile_match=True)
        assert ok.status == "pass"  # within 25%
        bad = evaluate_budget(b, {"m": 7.0}, base, profile_match=True)
        assert bad.status == "regress" and bad.failed
        up = evaluate_budget(b, {"m": 13.0}, base, profile_match=True)
        assert up.status == "improve" and not up.failed

    def test_better_lower_mirrors(self):
        base = {"median": 100.0, "mad": 0.0}
        b = _budget(better="lower", rel_tolerance=0.25)
        assert evaluate_budget(b, {"m": 120.0}, base, profile_match=True).status == "pass"
        assert (
            evaluate_budget(b, {"m": 130.0}, base, profile_match=True).status
            == "regress"
        )
        assert (
            evaluate_budget(b, {"m": 70.0}, base, profile_match=True).status
            == "improve"
        )

    def test_mad_widens_noisy_margin(self):
        # rel_tolerance alone would flag 25%: a noisy baseline (MAD 2.0,
        # mad_k 3) widens the band to +-6 around median 10 -> 5.0 passes.
        noisy = {"median": 10.0, "mad": 2.0}
        b = _budget(rel_tolerance=0.25, mad_k=3.0)
        assert evaluate_budget(b, {"m": 5.0}, noisy, profile_match=True).status == "pass"
        assert (
            evaluate_budget(b, {"m": 3.0}, noisy, profile_match=True).status
            == "regress"
        )

    def test_trial_list_reduces_to_median(self):
        b = _budget(min=1.0, relative=False)
        r = evaluate_budget(b, {"m": [0.5, 2.0, 3.0]}, None, profile_match=True)
        assert r.status == "pass" and r.value == 2.0 and r.n_samples == 3

    def test_missing_metric(self):
        r = evaluate_budget(_budget(), {}, None, profile_match=True)
        assert r.status == "missing" and r.failed
        r = evaluate_budget(_budget(required=False), {}, None, profile_match=True)
        assert r.status == "skipped" and not r.failed

    def test_profile_mismatch_downgrades_to_absolute(self):
        base = {"median": 10.0, "mad": 0.0}
        b = _budget(min=1.0)
        # 50% below baseline, but the baseline came from another profile:
        # only the absolute floor applies.
        r = evaluate_budget(b, {"m": 5.0}, base, profile_match=False)
        assert r.status == "pass" and "profile differs" in r.message

    def test_profiles_filter_in_evaluate_budgets(self):
        budgets = [
            _budget(name="any", min=0.0, relative=False),
            _budget(name="full-only", min=0.0, relative=False, profiles=("full",)),
        ]
        results = evaluate_budgets(budgets, {"m": 1.0}, None, profile="tiny")
        assert [r.budget.name for r in results] == ["any"]

    def test_github_annotation_format(self):
        r = evaluate_budget(_budget(min=5.0, relative=False), {"m": 1.0}, None,
                            profile_match=True)
        line = r.github()
        assert line.startswith("::error title=perfguard[b]::")
        assert "\n" not in line


class TestBudgetFromTable:
    def test_validation(self):
        with pytest.raises(ValueError, match="metric"):
            Budget.from_table("x", {}, default_mad_k=3, default_rel_tolerance=0.25)
        with pytest.raises(ValueError, match="better"):
            Budget.from_table(
                "x", {"metric": "m", "better": "sideways"},
                default_mad_k=3, default_rel_tolerance=0.25,
            )
        with pytest.raises(ValueError, match="unknown"):
            Budget.from_table(
                "x", {"metric": "m", "typo": 1},
                default_mad_k=3, default_rel_tolerance=0.25,
            )

    def test_defaults_flow_from_config(self):
        b = Budget.from_table(
            "x", {"metric": "m"}, default_mad_k=4.0, default_rel_tolerance=0.1
        )
        assert b.mad_k == 4.0 and b.rel_tolerance == 0.1


# -- config + bench IO against the real repo -------------------------------


class TestRepoConfig:
    def test_shipped_budgets_parse(self):
        # Pins the py3.10 mini-TOML path: floats, booleans, lists, and the
        # [tool.perfguard.budgets.NAME] sub-table shape all round-trip.
        cfg = load_config(REPO)
        names = {b.name for b in cfg["budgets"]}
        assert {"serving-req-s", "serving-p95-ms", "fused-speedup-500k",
                "quant-byte-ratio"} <= names
        by_name = {b.name: b for b in cfg["budgets"]}
        assert by_name["quant-byte-ratio"].better == "lower"
        assert by_name["quant-byte-ratio"].max == 0.45
        assert by_name["serving-req-s"].profiles == ("tiny",)
        assert by_name["serving-req-s"].rel_tolerance == 0.3
        assert by_name["serving-occupancy"].relative is False
        assert cfg["mad_k"] == 3.0

    def test_latest_bench_orders_by_pr_number(self, tmp_path):
        for name in ("BENCH_PR2.json", "BENCH_PR10.json", "BENCH_PR9.json"):
            (tmp_path / name).write_text("{}")
        assert latest_bench(tmp_path, "BENCH_PR*.json").name == "BENCH_PR10.json"
        assert latest_bench(tmp_path, "nope*.json") is None

    def test_provenance_meta_shape(self):
        meta = provenance_meta(trials=3, profile="tiny", root=REPO)
        assert meta["schema_version"] == 1
        assert meta["trials"] == 3 and meta["profile"] == "tiny"
        assert set(meta) >= {"git_sha", "date", "hostname"}

    def test_baseline_roundtrip(self, tmp_path):
        budgets = [_budget(name="x", metric="a.b")]
        bench = {"a": {"b": [1.0, 2.0, 3.0]},
                 "_meta": {"profile": "tiny", "trials": 3}}
        doc = build_baseline(budgets, bench, source="BENCH_X.json", root=REPO)
        assert doc["budgets"]["x"]["median"] == 2.0
        assert doc["budgets"]["x"]["n"] == 3
        assert doc["_meta"]["profile"] == "tiny"
        assert doc["_meta"]["source"] == "BENCH_X.json"
        path = tmp_path / "baseline.json"
        write_baseline(path, doc)
        assert load_baseline(path)["budgets"]["x"]["samples"] == [1.0, 2.0, 3.0]
        assert load_baseline(tmp_path / "absent.json") is None
        (tmp_path / "bad.json").write_text("[]")
        with pytest.raises(ValueError, match="update-baseline"):
            load_baseline(tmp_path / "bad.json")


# -- CLI end-to-end (subprocess, from the repo root, shipped budgets) ------


def _tiny_bench(req_s: float = 20.0, p95_ms: float = 900.0) -> dict:
    """A fixture bench covering every tiny-profile shipped budget, with
    samples jittered ~1%% so baseline MAD is realistic but small."""
    jitter = lambda x: [x, x * 1.01, x * 0.99]  # noqa: E731
    return {
        "_meta": {
            "schema_version": 1, "git_sha": "fixture", "date": "d",
            "hostname": "h", "trials": 3, "profile": "tiny",
        },
        "bench_serving": {
            "paths": {
                "binned": {"batched": {"8": {"speedup_vs_sequential": jitter(1.0)}}}
            },
            "scheduler_sweep": {"1.5x_capacity": {"continuous_speedup": jitter(1.05)}},
            "server": {
                "req_s": jitter(req_s),
                "occupancy": 1.0,
                "latency_ms_p95": jitter(p95_ms),
            },
        },
    }


def _run_cli(*argv: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-m", "tools.perfguard", *argv],
        cwd=REPO, capture_output=True, text=True, timeout=120,
    )


class TestCLI:
    def test_committed_state_passes_its_own_budgets(self):
        # The repo must always pass its own shipped gates: newest committed
        # BENCH file + committed baseline + shipped budgets -> exit 0.
        proc = _run_cli("check")
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "0 regressed" in proc.stderr

    def test_fresh_baseline_then_pass_then_regression(self, tmp_path):
        bench = tmp_path / "bench.json"
        baseline = tmp_path / "baseline.json"
        bench.write_text(json.dumps(_tiny_bench()))

        up = _run_cli(
            "update-baseline", "--bench", str(bench), "--baseline", str(baseline)
        )
        assert up.returncode == 0, up.stderr
        doc = json.loads(baseline.read_text())
        assert doc["_meta"]["profile"] == "tiny"
        assert doc["_meta"]["source"] == "bench.json"
        assert doc["_meta"]["git_sha"] != "unknown"  # stamped from this repo
        assert "serving-req-s" in doc["budgets"]

        ok = _run_cli("check", "--bench", str(bench), "--baseline", str(baseline))
        assert ok.returncode == 0, ok.stdout + ok.stderr

        # The acceptance fixture: fused req/s down 40% MUST flag, with a
        # GitHub error annotation naming the budget.
        degraded = tmp_path / "degraded.json"
        degraded.write_text(json.dumps(_tiny_bench(req_s=20.0 * 0.6)))
        bad = _run_cli(
            "check", "--bench", str(degraded), "--baseline", str(baseline),
            "--format", "github",
        )
        assert bad.returncode == 1
        assert "::error title=perfguard[serving-req-s]::" in bad.stdout
        assert "1 regressed" in bad.stderr

    def test_p95_regression_flags_too(self, tmp_path):
        bench = tmp_path / "bench.json"
        baseline = tmp_path / "baseline.json"
        bench.write_text(json.dumps(_tiny_bench()))
        _run_cli("update-baseline", "--bench", str(bench), "--baseline", str(baseline))
        slow = tmp_path / "slow.json"
        # p95 is better=lower with a 60% tolerance: a 2x blowup must flag.
        slow.write_text(json.dumps(_tiny_bench(p95_ms=900.0 * 2.0)))
        bad = _run_cli("check", "--bench", str(slow), "--baseline", str(baseline))
        assert bad.returncode == 1
        assert "serving-p95-ms" in bad.stdout

    def test_missing_required_metric_fails(self, tmp_path):
        bench = tmp_path / "bench.json"
        doc = _tiny_bench()
        del doc["bench_serving"]["server"]["occupancy"]
        bench.write_text(json.dumps(doc))
        proc = _run_cli("check", "--bench", str(bench))
        assert proc.returncode == 1
        assert "serving-occupancy" in proc.stdout

    def test_list_budgets(self):
        proc = _run_cli("list-budgets")
        assert proc.returncode == 0
        assert "serving-req-s" in proc.stdout
        assert "bench_serving.server.req_s" in proc.stdout


@pytest.mark.slow
def test_full_pipeline_tiny_bench_then_check(tmp_path):
    """The CI perfguard job end-to-end: a real --tiny bench run, then the
    gate — fresh measurements on this machine must pass the shipped
    absolute budgets (relative checks engage only against the committed
    tiny baseline when profiles match)."""
    out = tmp_path / "BENCH_tiny.json"
    bench = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--tiny", "--out", str(out)],
        cwd=REPO, capture_output=True, text=True, timeout=1800,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert bench.returncode == 0, bench.stdout[-2000:] + bench.stderr[-2000:]
    doc = json.loads(out.read_text())
    assert doc["_meta"]["profile"] == "tiny"
    proc = _run_cli("check", "--bench", str(out), "--format", "github")
    assert proc.returncode == 0, proc.stdout + proc.stderr
