"""repro.obs: metrics registry, tracing, in-kernel pipeline counters, and
the instrumented RenderServer.

The two load-bearing contracts pinned here:

* ``collect_stats=True`` never changes the image — bitwise-identical on
  every raster path (the diagnostics plane is a pure side output).
* the fused kernel's in-kernel counters equal the plain-jnp reference
  replay **exactly** (not approximately) on the same compacted operands —
  f32 and quantized, banded and unbanded.
"""

from __future__ import annotations

import json
import threading
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    build_scene_tree,
    clustered_gaussians,
    look_at_camera,
    orbit_cameras,
    random_gaussians,
    render,
)
from repro.core.render import render_with_stats
from repro.core.scene import resolve_scene_banded
from repro.obs.metrics import (
    Histogram,
    Registry,
    serve_metrics,
    validate_prometheus,
)
from repro.obs.pipeline import (
    fold_render_stats,
    replay_fused_stats,
    replay_fused_stats_q,
    summarize_kernel_stats,
)
from repro.obs.tracing import Tracer, span, validate_trace
from repro.serve import RenderServer

SIZE = 32
BG = jnp.zeros((3,), jnp.float32)


def _tiny_scene(n: int = 192, seed: int = 0):
    g = random_gaussians(jax.random.PRNGKey(seed), n, extent=1.5)
    cam = look_at_camera((0.0, 1.0, -5.0), (0.0, 0.0, 0.0),
                         width=SIZE, height=SIZE)
    return g, cam


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_counter_gauge_labels(self):
        reg = Registry()
        c = reg.counter("reqs_total", "requests")
        c.inc(mode="a")
        c.inc(2.0, mode="a")
        c.inc(mode="b")
        assert c.value(mode="a") == 3.0
        assert c.value(mode="b") == 1.0
        g = reg.gauge("occupancy")
        g.set(0.75, path="fused")
        assert g.value(path="fused") == 0.75
        # get-or-create: same object back, never a fresh series
        assert reg.counter("reqs_total") is c

    def test_kind_mismatch_raises(self):
        reg = Registry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_thread_safety_exact_counts(self):
        reg = Registry()
        c = reg.counter("n").labels()
        h = reg.histogram("lat").labels()
        threads, per = 8, 500

        def work():
            for i in range(per):
                c.inc()
                h.observe(float(i % 37))

        ts = [threading.Thread(target=work) for _ in range(threads)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert c.value == threads * per
        assert h.count == threads * per
        # cumulative buckets account for every observation
        assert sum(h.bucket_counts) == threads * per

    def test_histogram_percentiles_match_numpy(self):
        reg = Registry()
        h = reg.histogram("lat_ms").labels()
        rng = np.random.default_rng(0)
        vals = rng.exponential(25.0, size=997)
        for v in vals:
            h.observe(float(v))
        got = h.percentile([50.0, 95.0, 99.0])
        want = np.percentile(vals, [50.0, 95.0, 99.0])
        np.testing.assert_allclose(got, want, rtol=0, atol=0)
        s = h.summary()
        assert s["count"] == 997
        np.testing.assert_allclose(s["p50"], want[0])
        np.testing.assert_allclose(s["max"], vals.max())

    def test_histogram_ring_bounded(self):
        h = Histogram("lat", buckets=(10.0, 100.0), ring_size=64)
        child = h.labels()
        for v in range(1000):
            child.observe(float(v))
        # totals are exact over the lifetime...
        assert child.count == 1000
        assert child.sum == sum(range(1000))
        # ...but raw retention is bounded to the most recent ring_size
        recent = child._recent()
        assert len(recent) == 64
        assert sorted(recent) == [float(v) for v in range(936, 1000)]

    def test_snapshot_and_prometheus_roundtrip(self):
        reg = Registry()
        reg.counter("reqs_total", "served").inc(3.0, mode="continuous")
        reg.gauge("occ").set(0.5)
        h = reg.histogram("lat_ms", "latency", buckets=(1.0, 10.0))
        for v in (0.5, 5.0, 50.0):
            h.observe(v, mode="continuous")
        snap = reg.snapshot()
        assert snap["reqs_total"]["type"] == "counter"
        (series,) = snap["lat_ms"]["series"]
        assert series["summary"]["count"] == 3
        # cumulative buckets, +Inf == count
        assert series["buckets"] == {"1": 1, "10": 2, "+Inf": 3}
        # the snapshot is what benchmarks persist — must be JSON-clean
        json.dumps(snap)
        families = validate_prometheus(reg.render_prometheus())
        assert families["lat_ms"]["type"] == "histogram"
        assert families["reqs_total"]["type"] == "counter"

    def test_serve_metrics_endpoint(self):
        reg = Registry()
        reg.gauge("up").set(1.0)
        http = serve_metrics(reg, port=0)
        try:
            port = http.server_address[1]
            with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics"
            ) as resp:
                assert resp.status == 200
                text = resp.read().decode()
        finally:
            http.shutdown()
        assert "up 1" in text
        validate_prometheus(text)


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------


class TestTracing:
    def test_span_nesting_and_schema(self):
        tr = Tracer()
        with span("outer", tracer=tr, tier="test"):
            with span("inner", tracer=tr) as sp:
                sp.set(detail=1)
        trace = json.loads(json.dumps(tr.to_json()))
        assert validate_trace(trace) == 2
        by_name = {
            e["name"]: e for e in trace["traceEvents"] if e["ph"] == "X"
        }
        outer, inner = by_name["outer"], by_name["inner"]
        # proper nesting on the time axis
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
        assert outer["args"] == {"tier": "test"}
        assert inner["args"] == {"detail": 1}
        # thread rows carry names via "M" metadata events
        assert any(
            e["ph"] == "M" and e["name"] == "thread_name"
            for e in trace["traceEvents"]
        )

    def test_span_fence_blocks_on_device_values(self):
        tr = Tracer()
        x = jnp.ones((64, 64))
        with span("matmul", tracer=tr) as sp:
            sp.fence(x @ x)
        (ev,) = [e for e in tr.events() if e["ph"] == "X"]
        assert ev["dur"] >= 0.0

    def test_no_tracer_is_noop(self):
        with span("nothing", attr=1) as sp:
            sp.fence(jnp.ones(2))
            sp.set(extra=2)  # must not raise

    def test_max_events_bounded(self):
        tr = Tracer(max_events=3)
        for i in range(10):
            tr.emit(f"e{i}", float(i), 1.0, tid=7)
        assert len(tr.events()) <= 3
        assert tr.to_json()["droppedEvents"] == 7

    def test_lane_tid_logical_rows(self):
        tr = Tracer()
        assert tr.lane_tid(2, "slot 2") == 102
        names = [
            e["args"]["name"]
            for e in tr.events()
            if e["ph"] == "M" and e["name"] == "thread_name"
        ]
        assert "slot 2" in names


# ---------------------------------------------------------------------------
# collect_stats: image invariance on every raster path
# ---------------------------------------------------------------------------


class TestCollectStatsBitwise:
    @pytest.mark.parametrize(
        "path", ("dense", "binned", "pallas", "pallas_binned", "pallas_fused")
    )
    def test_image_bitwise_unchanged(self, path):
        g, cam = _tiny_scene()
        cfg = RenderConfig(raster_path=path, tile_capacity=64, sh_degree=1)
        plain = np.asarray(render(g, cam, cfg))
        img, stats = render_with_stats(
            g, cam, cfg.replace(collect_stats=True)
        )
        assert np.array_equal(np.asarray(img), plain), (
            f"collect_stats changed the {path} image"
        )
        assert stats is not None
        expected = "kernel" if path == "pallas_fused" else "occupancy"
        assert expected in stats

    def test_collect_stats_off_returns_none(self):
        g, cam = _tiny_scene()
        cfg = RenderConfig(raster_path="binned", tile_capacity=64, sh_degree=1)
        img, stats = render_with_stats(g, cam, cfg)
        assert stats is None
        assert np.array_equal(np.asarray(img), np.asarray(render(g, cam, cfg)))


# ---------------------------------------------------------------------------
# In-kernel counters == jnp reference replay (exact)
# ---------------------------------------------------------------------------


def _assert_counters_equal(kernel_stats: dict, ref: dict) -> None:
    for key in ("chunks_processed", "lanes_blended", "max_sh_band"):
        np.testing.assert_array_equal(
            np.asarray(kernel_stats[key]),
            np.asarray(ref[key]),
            err_msg=f"in-kernel {key} diverged from the reference replay",
        )


class TestKernelCountersReplay:
    def test_f32_counters_match_replay(self):
        from repro.kernels.fused_raster import ops as fops

        g, cam = _tiny_scene()
        kw = dict(tile_size=16, capacity=64, block_g=128, tile_chunk=None)
        _, stats = fops.fused_render_stats(
            g, cam, BG, sh_degree=1, early_exit=True, **kw
        )
        raw_compact, nsteps, chunk_band, bins, steps = (
            fops.build_fused_operands(g, cam, **kw)
        )
        pix = fops._tile_order_pixels(
            bins.tiles_y * 16, bins.tiles_x * 16, 16
        )
        ref = replay_fused_stats(
            raw_compact, fops.pack_camera(cam), pix, nsteps, chunk_band,
            steps=steps, block_g=128, sh_degree=1, banded=False,
            early_exit=True,
        )
        _assert_counters_equal(stats, ref)
        np.testing.assert_array_equal(
            np.asarray(stats["chunks_assigned"]), np.asarray(nsteps)
        )
        # processed never exceeds assigned (early exit only cuts work)
        assert np.all(
            np.asarray(stats["chunks_processed"])
            <= np.asarray(stats["chunks_assigned"])
        )

    def test_quantized_banded_counters_match_replay(self):
        from repro.kernels.fused_raster import ops as fops

        g = clustered_gaussians(jax.random.PRNGKey(3), 256, num_clusters=4)
        cam = look_at_camera((0.0, 1.0, -5.0), (0.0, 0.0, 0.0),
                             width=SIZE, height=SIZE)
        tree = build_scene_tree(g, leaf_size=64, compress="int8")
        cfg = RenderConfig(
            raster_path="pallas_fused", cull=True, compress="int8",
            tile_capacity=64, sh_degree=3, lod_thresholds=(0.5, 4.0),
        )
        qg, band = resolve_scene_banded(tree, cam, cfg)
        assert band is not None
        kw = dict(tile_size=16, capacity=64, block_g=128, tile_chunk=None)
        _, stats = fops.fused_render_q_stats(
            qg, cam, BG, band=band, sh_degree=3, early_exit=True, **kw
        )
        (qf_c, qi_c, qdc_c), nsteps, chunk_band, bins, steps = (
            fops.build_fused_operands_q(qg, cam, band=band, **kw)
        )
        pix = fops._tile_order_pixels(
            bins.tiles_y * 16, bins.tiles_x * 16, 16
        )
        ref = replay_fused_stats_q(
            qf_c, qi_c, qdc_c, fops.pack_camera(cam), pix, nsteps,
            chunk_band, steps=steps, block_g=128, sh_degree=3, banded=True,
            early_exit=True,
        )
        _assert_counters_equal(stats, ref)
        # LOD banding visible to the counters: max band bounded by degree
        assert float(np.max(np.asarray(stats["max_sh_band"]))) <= 3.0

    def test_fold_render_stats_into_registry(self):
        g, cam = _tiny_scene()
        cfg = RenderConfig(
            raster_path="pallas_fused", tile_capacity=64, sh_degree=1,
            collect_stats=True,
        )
        _, st = render_with_stats(g, cam, cfg)
        reg = Registry()
        agg = fold_render_stats(reg, st, config="test")
        assert agg is not None
        assert 0.0 <= agg["early_exit_savings"] <= 1.0
        assert 0.0 <= agg["chunk_occupancy_measured"] <= 1.0
        assert agg == summarize_kernel_stats(
            st["kernel"], block_g=st["block_g"]
        )
        snap = reg.snapshot()
        for name in (
            "render_chunks_assigned",
            "render_chunks_processed",
            "render_early_exit_savings",
            "render_early_exit_chunks",
            "render_chunk_occupancy_measured",
            "render_sh_band_max",
        ):
            assert name in snap, name
        # per-tile exit-depth histogram saw every tile
        (series,) = snap["render_early_exit_chunks"]["series"]
        assert series["summary"]["count"] == agg["num_tiles"]


# ---------------------------------------------------------------------------
# RenderServer observability
# ---------------------------------------------------------------------------


def _server(model, **kw):
    cfg = RenderConfig(raster_path="binned", tile_capacity=64, early_exit=False)
    kw.setdefault("width", SIZE)
    kw.setdefault("height", SIZE)
    kw.setdefault("max_batch", 4)
    return RenderServer(model, cfg, **kw)


class TestServerObservability:
    def test_stats_keys_pinned_and_memory_bounded(self):
        model = random_gaussians(jax.random.PRNGKey(0), 64, extent=1.5)
        cams = orbit_cameras(5, radius=5.0, width=SIZE, height=SIZE)
        srv = _server(model)
        idle_keys = set(srv.stats())
        with srv:
            [f.result(timeout=120) for f in map(srv.submit, cams)]
        stats = srv.stats()
        # the stats() schema, pinned (pre-registry keys + PR 10's "slo")
        assert set(stats) == {
            "mode", "requests", "batches", "compile_ms", "latency_ms_p50",
            "latency_ms_p95", "latency_ms_mean", "mean_batch_size",
            "occupancy", "memory", "slo",
        }
        assert stats["slo"] is None  # no monitor attached -> same schema
        assert idle_keys == set(stats)
        assert stats["requests"] == 5
        assert stats["latency_ms_p95"] >= stats["latency_ms_p50"] > 0.0
        # bounded: ring-buffer histograms, no unbounded per-request lists
        assert not hasattr(srv, "_latencies_ms")
        assert not hasattr(srv, "_batch_sizes")
        assert len(srv._lat._ring) == srv.registry.histogram(
            "render_server_latency_ms"
        ).ring_size

    def test_metrics_and_trace_export(self):
        model = random_gaussians(jax.random.PRNGKey(1), 64, extent=1.5)
        cams = orbit_cameras(6, radius=5.0, width=SIZE, height=SIZE)
        reg, tr = Registry(), Tracer()
        with _server(model, registry=reg, tracer=tr) as srv:
            [f.result(timeout=120) for f in map(srv.submit, cams)]
        families = validate_prometheus(reg.render_prometheus())
        for fam in (
            "render_server_latency_ms",
            "render_server_batch_size",
            "render_server_requests_total",
            "render_server_compile_ms",
        ):
            assert fam in families, fam
        assert reg.counter("render_server_requests_total").value(
            mode="continuous"
        ) == 6.0
        trace = json.loads(json.dumps(tr.to_json()))
        assert validate_trace(trace) > 0
        spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
        names = {e["name"] for e in spans}
        assert {"queue", "render", "harvest", "warmup_compile"} <= names
        # per-request spans are keyed by the slot's generation counter
        queue_spans = [e for e in spans if e["name"] == "queue"]
        assert len(queue_spans) == 6
        for ev in queue_spans:
            assert ev["args"]["gen"] >= 1
            assert ev["tid"] == 100 + ev["args"]["slot"]

    def test_microbatch_reports_same_series(self):
        model = random_gaussians(jax.random.PRNGKey(2), 64, extent=1.5)
        cams = orbit_cameras(3, radius=5.0, width=SIZE, height=SIZE)
        reg, tr = Registry(), Tracer()
        with _server(
            model, mode="microbatch", max_wait_ms=5.0, registry=reg, tracer=tr
        ) as srv:
            [f.result(timeout=120) for f in map(srv.submit, cams)]
        snap = reg.snapshot()
        (series,) = [
            s
            for s in snap["render_server_latency_ms"]["series"]
            if s["labels"].get("mode") == "microbatch"
        ]
        assert series["summary"]["count"] == 3
        names = {e["name"] for e in tr.events() if e.get("ph") == "X"}
        assert "microbatch_step" in names
