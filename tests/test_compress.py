"""Quantized resident scenes (``core.quant``) + decode-in-kernel raster.

The contract under test is *exactness where exactness is claimed*: decode is
``q.astype(f32) * scale`` everywhere, so the fused quantized render must be
bitwise-equal to the fused f32 render of the dequantized cloud (unbanded,
banded, early-exit on/off, culled tree), the straight-through estimator must
be bitwise the image a quantized-resident tree produces, and gradients must
flow to f32 masters unchanged. Accuracy (vs the *original* f32 scene) is a
tolerance claim and tested as PSNR.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    build_scene_tree,
    clustered_gaussians,
    dequantize_gaussians,
    look_at_camera,
    quantize_dequantize,
    quantize_gaussians,
    random_gaussians,
    render,
    visibility_stats,
)
from repro.core.quant import (
    SCALE_COLS,
    SH_BAND_SLICES,
    f32_memory_stats,
    quantized_memory_stats,
)
from repro.core.scene import SceneTree, apply_sh_lod
from repro.distributed.compression import (
    BLOCK,
    dequantize_int8,
    quantize_int8,
    symmetric_scale,
)
from repro.kernels.fused_raster import fused_render, fused_render_q
from repro.serve import RenderServer

BG = (0.1, 0.2, 0.3)
CHUNK = 128


def _cam(eye=(0, 1.0, -6.0), target=(0, 0, 0), width=48, height=48):
    return look_at_camera(eye, target, width=width, height=height)


def _psnr(a, b) -> float:
    mse = float(jnp.mean((jnp.asarray(a) - jnp.asarray(b)) ** 2))
    return float("inf") if mse == 0.0 else -10.0 * math.log10(mse)


def _bg():
    return jnp.asarray(BG, jnp.float32)


# -- satellite: zero-range / non-finite guards in the int8 compressor --------


class TestQuantizeInt8Guards:
    def test_all_zero_block_roundtrips_to_exact_zeros(self):
        x = jnp.zeros((BLOCK + 44,), jnp.float32)
        q, scale, n = quantize_int8(x)
        assert bool(jnp.all(jnp.isfinite(scale))) and bool(jnp.all(scale > 0))
        out = dequantize_int8(q, scale, n, x.shape)
        np.testing.assert_array_equal(np.asarray(out), 0.0)

    def test_constant_block_roundtrip(self):
        x = jnp.full((BLOCK,), 5.0, jnp.float32)
        q, scale, n = quantize_int8(x)
        out = dequantize_int8(q, scale, n, x.shape)
        assert bool(jnp.all(jnp.isfinite(out)))
        np.testing.assert_allclose(np.asarray(out), 5.0, rtol=1 / 127)

    def test_nonfinite_inputs_do_not_poison_the_block(self):
        x = jnp.arange(BLOCK, dtype=jnp.float32) / BLOCK
        x = x.at[3].set(jnp.nan).at[7].set(jnp.inf).at[11].set(-jnp.inf)
        q, scale, n = quantize_int8(x)
        out = np.asarray(dequantize_int8(q, scale, n, x.shape))
        assert np.all(np.isfinite(out))
        # Bad entries decode to 0; the rest round-trip within half a step.
        np.testing.assert_array_equal(out[[3, 7, 11]], 0.0)
        good = np.delete(np.arange(BLOCK), [3, 7, 11])
        err = np.abs(out[good] - np.asarray(x)[good])
        assert err.max() <= float(scale[0, 0]) / 2 + 1e-7

    def test_non_multiple_of_block_shape(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, BLOCK + 17))
        q, scale, n = quantize_int8(x)
        assert n == x.size
        out = dequantize_int8(q, scale, n, x.shape)
        assert out.shape == x.shape
        step = float(jnp.max(scale))
        assert float(jnp.abs(out - x).max()) <= step / 2 + 1e-7

    def test_symmetric_scale_fallbacks(self):
        s = symmetric_scale(jnp.asarray([0.0, jnp.inf, jnp.nan, 127.0]))
        np.testing.assert_allclose(
            np.asarray(s), [1 / 127, 1 / 127, 1 / 127, 1.0], rtol=1e-6
        )


# -- quantize/dequantize round trips -----------------------------------------


class TestQuantizeRoundTrip:
    def _cloud(self, n=1000, seed=0):
        return random_gaussians(jax.random.PRNGKey(seed), n, extent=1.5)

    def test_non_multiple_of_chunk_shapes(self):
        g = self._cloud(1000)
        qg = quantize_gaussians(g, CHUNK)
        assert qg.num_gaussians == 1024 and qg.num_real == 1000
        assert qg.num_chunks == 8 and qg.scales.shape == (8, len(SCALE_COLS))
        deq = dequantize_gaussians(qg)
        assert deq.num_gaussians == 1000
        for f in dataclasses.fields(deq):
            got = getattr(deq, f.name)
            want = getattr(g, f.name)
            assert got.shape == want.shape, f.name
            assert got.dtype == jnp.float32, f.name

    def test_hot_fields_exact_and_dc_is_fp16(self):
        g = self._cloud(512)
        qg = quantize_gaussians(g, CHUNK)
        deq = dequantize_gaussians(qg)
        # Positions/quats stay f32: bitwise.
        np.testing.assert_array_equal(
            np.asarray(deq.positions), np.asarray(g.positions)
        )
        np.testing.assert_array_equal(np.asarray(deq.quats), np.asarray(g.quats))
        # DC is exactly the fp16 cast (round-trip through fp16, nothing else).
        assert qg.sh_dc.dtype == jnp.float16
        np.testing.assert_array_equal(
            np.asarray(deq.sh[:, 0, :]),
            np.asarray(g.sh[:, 0, :].astype(jnp.float16).astype(jnp.float32)),
        )

    def test_per_band_scales_match_chunk_maxabs(self):
        g = self._cloud(512)
        qg = quantize_gaussians(g, CHUNK)
        sh = np.asarray(g.sh).reshape(512 // CHUNK, CHUNK, 16, 3)
        for b, (lo, hi) in enumerate(SH_BAND_SLICES):
            want = np.abs(sh[:, :, lo:hi, :]).max(axis=(1, 2, 3)) / 127.0
            np.testing.assert_allclose(
                np.asarray(qg.scales[:, 2 + b]), want, rtol=1e-6,
                err_msg=f"band {b + 1}",
            )

    def test_roundtrip_error_bounded_by_half_a_step(self):
        g = self._cloud(1024)
        qg = quantize_gaussians(g, CHUNK)
        deq = dequantize_gaussians(qg)
        m = qg.num_chunks

        def _chunk_max_err(got, want):
            return np.abs(
                np.asarray(got - want).reshape(m, -1)
            ).max(axis=1)

        step = np.asarray(qg.scales)
        assert (
            _chunk_max_err(deq.log_scales, g.log_scales)
            <= step[:, 0] / 2 + 1e-6
        ).all()
        assert (
            _chunk_max_err(deq.opacity_logit, g.opacity_logit)
            <= step[:, 1] / 2 + 1e-6
        ).all()
        for b, (lo, hi) in enumerate(SH_BAND_SLICES):
            assert (
                _chunk_max_err(deq.sh[:, lo:hi, :], g.sh[:, lo:hi, :])
                <= step[:, 2 + b] / 2 + 1e-6
            ).all(), f"band {b + 1}"

    def test_zero_sh_bands_decode_to_exact_zeros(self):
        """COLMAP-seeded clouds have all-zero SH bands 1-3 — the zero-range
        guard must give them a positive scale and exact-zero decode."""
        g = self._cloud(256)
        g = dataclasses.replace(g, sh=g.sh.at[:, 1:, :].set(0.0))
        qg = quantize_gaussians(g, CHUNK)
        assert bool(jnp.all(qg.scales > 0))
        np.testing.assert_array_equal(
            np.asarray(dequantize_gaussians(qg).sh[:, 1:, :]), 0.0
        )


# -- straight-through estimator ----------------------------------------------


class TestStraightThroughEstimator:
    def test_forward_is_the_quantized_cloud(self):
        g = random_gaussians(jax.random.PRNGKey(1), 777, extent=1.5)
        ste = quantize_dequantize(g, CHUNK)
        want = dequantize_gaussians(quantize_gaussians(g, CHUNK))
        for f in dataclasses.fields(g):
            np.testing.assert_array_equal(
                np.asarray(getattr(ste, f.name)),
                np.asarray(getattr(want, f.name)),
                err_msg=f.name,
            )

    def test_gradients_pass_through_to_f32_masters(self):
        g = random_gaussians(jax.random.PRNGKey(2), 256, extent=1.5)
        w_pos = jnp.arange(256 * 3, dtype=jnp.float32).reshape(256, 3)
        w_sh = jnp.sin(jnp.arange(256 * 16 * 3, dtype=jnp.float32)).reshape(
            256, 16, 3
        )

        def loss(gg):
            q = quantize_dequantize(gg, CHUNK)
            return jnp.sum(q.positions * w_pos) + jnp.sum(q.sh * w_sh)

        grads = jax.grad(loss)(g)
        # Identity VJP: cotangents land on the masters unchanged, even
        # through the int8 rounding of the forward.
        np.testing.assert_array_equal(np.asarray(grads.positions), w_pos)
        np.testing.assert_array_equal(np.asarray(grads.sh), w_sh)
        np.testing.assert_array_equal(np.asarray(grads.opacity_logit), 0.0)


# -- memory accounting -------------------------------------------------------


class TestMemoryStats:
    def test_quantized_ratio_and_sh_reduction(self):
        g = random_gaussians(jax.random.PRNGKey(0), 4096, extent=1.5)
        qs = quantized_memory_stats(quantize_gaussians(g, 256))
        fs = f32_memory_stats(g)
        assert qs["compressed"] and not fs["compressed"]
        assert qs["ratio_vs_f32"] <= 0.45  # issue acceptance floor
        assert qs["ratio_vs_f32"] <= 0.36  # 83/236 + chunk scales
        assert fs["sh_bytes"] / qs["sh_bytes"] >= 3.0
        assert fs["ratio_vs_f32"] == pytest.approx(1.0)
        # Per-field accounting sums to the total.
        assert sum(qs["fields"].values()) == qs["total_bytes"]

    def test_scene_tree_memory_stats_schema(self):
        g = random_gaussians(jax.random.PRNGKey(3), 1000, extent=1.5)
        for compress, flag in (("none", False), ("int8", True)):
            tree = build_scene_tree(g, leaf_size=CHUNK, compress=compress)
            assert tree.compressed is flag
            st = tree.memory_stats()
            for key in (
                "compressed", "fields", "sh_bands", "sh_bytes",
                "total_bytes", "ratio_vs_f32", "aabb_bytes", "num_chunks",
            ):
                assert key in st, key
            assert st["compressed"] is flag
            assert st["num_chunks"] == 8


# -- fused raster: decode-in-kernel exactness --------------------------------


class TestFusedQuantizedRender:
    def _scene(self, kind, n=2048, seed=0):
        key = jax.random.PRNGKey(seed)
        if kind == "uniform":
            return random_gaussians(key, n, extent=1.5)
        return clustered_gaussians(key, n)

    @pytest.mark.parametrize("kind", ["uniform", "clustered"])
    @pytest.mark.parametrize("early_exit", [False, True])
    def test_bitwise_equals_fused_f32_of_dequantized(self, kind, early_exit):
        g = self._scene(kind)
        qg = quantize_gaussians(g, CHUNK)
        cam = _cam()
        got = fused_render_q(qg, cam, _bg(), early_exit=early_exit)
        want = fused_render(
            dequantize_gaussians(qg), cam, _bg(), early_exit=early_exit
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_banded_bitwise_with_mixed_band_chunks(self):
        """Per-Gaussian SH bands that differ *within* a chunk: low-band
        lanes must not leak their (stored) above-band codes when the chunk
        decodes at its max band."""
        g = self._scene("clustered", n=2048, seed=4)
        qg = quantize_gaussians(g, CHUNK)
        band = jax.random.randint(jax.random.PRNGKey(5), (2048,), 0, 4)
        cam = _cam()
        got = fused_render_q(qg, cam, _bg(), band=band)
        deq = dequantize_gaussians(qg)
        deq = dataclasses.replace(deq, sh=apply_sh_lod(deq.sh, band))
        want = fused_render(deq, cam, _bg(), band=band)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_padding_chunks_render_invisible(self):
        """Non-multiple-of-chunk cloud: the quantized pad rows must not
        contribute — same image as the stripped dequantized cloud."""
        g = self._scene("uniform", n=1000, seed=6)
        qg = quantize_gaussians(g, CHUNK)  # pads 1000 -> 1024
        cam = _cam()
        got = fused_render_q(qg, cam, _bg())
        want = fused_render(dequantize_gaussians(qg), cam, _bg())
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("kind", ["uniform", "clustered"])
    def test_psnr_vs_f32_scene(self, kind):
        g = self._scene(kind, seed=7)
        qg = quantize_gaussians(g, CHUNK)
        cam = _cam(width=64, height=64)
        q_img = fused_render_q(qg, cam, _bg())
        f_img = fused_render(g, cam, _bg())
        assert _psnr(q_img, f_img) >= 35.0

    def test_gradients_match_f32_path(self):
        """Decode-then-VJP: grads w.r.t. the f32 fields of the quantized
        pytree equal the f32 fused path's grads at the dequantized point
        (int8 codes are constants; DC grads arrive in fp16)."""
        g = self._scene("uniform", n=512, seed=8)
        qg = quantize_gaussians(g, CHUNK)
        deq = dequantize_gaussians(qg)
        cam = _cam(width=32, height=32)

        def loss_q(pos):
            qg2 = dataclasses.replace(qg, positions=pos)
            return jnp.sum(fused_render_q(qg2, cam, _bg()))

        def loss_f(pos):
            g2 = dataclasses.replace(deq, positions=pos)
            return jnp.sum(fused_render(g2, cam, _bg()))

        dq = jax.grad(loss_q)(qg.positions)
        df = jax.grad(loss_f)(deq.positions)
        np.testing.assert_array_equal(np.asarray(dq), np.asarray(df))

        ddc = jax.grad(
            lambda dc: jnp.sum(
                fused_render_q(
                    dataclasses.replace(qg, sh_dc=dc), cam, _bg()
                )
            )
        )(qg.sh_dc)
        assert ddc.dtype == jnp.float16
        assert bool(jnp.all(jnp.isfinite(ddc)))
        assert float(jnp.abs(ddc).max()) > 0.0


# -- compressed SceneTree through the public render() ------------------------


class TestCompressedTreeRender:
    def _setup(self, **cfg_kw):
        g = clustered_gaussians(jax.random.PRNGKey(9), 4096, num_clusters=8)
        tree_f = build_scene_tree(g, leaf_size=CHUNK)
        tree_q = build_scene_tree(g, leaf_size=CHUNK, compress="int8")
        cam = _cam(eye=(0.3, 0.2, -0.4), target=(2.0, 0.2, 0.5))
        cfg = RenderConfig(
            raster_path="pallas_fused", background=BG, cull=True, **cfg_kw
        )
        stats = visibility_stats(tree_f, cam, cfg)
        assert 0 < stats["num_visible"] < tree_f.num_chunks
        cfg = cfg.replace(visible_capacity=stats["num_visible"])
        return tree_f, tree_q, cam, cfg

    def test_culled_quantized_matches_ste_bitwise(self):
        """A compressed resident tree and the straight-through estimator on
        the f32 tree must produce the *same image bitwise* — gathered slots
        are whole leaves, so the chunk statistics (and hence scales and
        codes) are identical."""
        tree_f, tree_q, cam, cfg = self._setup()
        got = render(tree_q, cam, cfg)
        ste = render(tree_f, cam, cfg.replace(compress="int8"))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ste))

    def test_culled_banded_quantized_matches_ste_bitwise(self):
        tree_f, tree_q, cam, cfg = self._setup(lod_thresholds=(0.4, 1.2))
        got = render(tree_q, cam, cfg)
        ste = render(tree_f, cam, cfg.replace(compress="int8"))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ste))

    def test_culled_quantized_psnr_vs_f32(self):
        tree_f, tree_q, cam, cfg = self._setup()
        q_img = render(tree_q, cam, cfg)
        f_img = render(tree_f, cam, cfg)
        assert _psnr(q_img, f_img) >= 35.0

    def test_nonfused_path_decodes_resident_tree(self):
        """raster_path != pallas_fused dequantizes the resolve — the
        compressed tree stays renderable on every path."""
        tree_f, tree_q, cam, cfg = self._setup()
        cfg = cfg.replace(raster_path="binned", early_exit=False)
        q_img = render(tree_q, cam, cfg)
        f_img = render(tree_f, cam, cfg)
        assert _psnr(q_img, f_img) >= 35.0


# -- serving -----------------------------------------------------------------


class TestRenderServerCompress:
    def test_server_promotes_and_reports_memory(self):
        g = random_gaussians(jax.random.PRNGKey(10), 512, extent=1.5)
        cfg = RenderConfig(
            raster_path="binned",
            tile_capacity=64,
            early_exit=False,
            compress="int8",
            leaf_size=64,
        )
        cam = look_at_camera((0, 1.0, -5.0), (0, 0, 0), width=32, height=32)
        server = RenderServer(g, cfg, width=32, height=32, max_batch=2)
        assert isinstance(server.model, SceneTree) and server.model.compressed
        mem = server.stats()["memory"]
        assert mem is not None and mem["compressed"]
        assert mem["ratio_vs_f32"] <= 0.45
        with server:
            got = server.render(cam).image
        want = np.asarray(render(server.model, cam, cfg))
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_uncompressed_raw_cloud_reports_no_memory(self):
        g = random_gaussians(jax.random.PRNGKey(11), 64, extent=1.5)
        cfg = RenderConfig(raster_path="binned", tile_capacity=64)
        server = RenderServer(g, cfg, width=32, height=32)
        assert server.stats()["memory"] is None


# -- sharded: all-gather quantized records, decode per device ----------------


@pytest.mark.slow
class TestShardedQuantizedRender:
    def test_sharded_fused_quantized_tree(self, run_multidevice):
        run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.compat import make_mesh
            from repro.core import (RenderConfig, build_scene_tree,
                                    random_gaussians, render)
            from repro.core.camera import orbit_cameras
            from repro.core.pipeline import sharded_render_batch

            mesh = make_mesh((2, 2, 2), ("gs", "cam", "px"))
            g = random_gaussians(jax.random.PRNGKey(0), 512, extent=1.5)
            tree = build_scene_tree(g, leaf_size=64, compress="int8")
            cfg = RenderConfig(raster_path="pallas_fused", early_exit=False,
                               cull=True, visible_capacity=4)
            cams = orbit_cameras(2, radius=5.0, width=32, height=32,
                                 stacked=True)
            fn = sharded_render_batch(mesh, ("gs",), ("cam",), ("px",),
                                      config=cfg)
            out = fn(tree, cams, jnp.zeros(3))
            for i in range(2):
                want = render(tree, cams.camera(i), cfg.replace(cull=False))
                err = float(jnp.abs(out[i] - want).max())
                assert err < 1e-5, err
            print("ok")
            """,
            devices=8,
        )
