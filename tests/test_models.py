"""Per-architecture smoke tests (required by the brief) + serving consistency.

Each assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU, asserting output shapes and absence of NaNs. The
consistency tests check prefill/decode against the teacher-forced forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import params as P
from repro.models.api import SHAPES, family_module, supports_shape

B, T = 2, 64


def _batch(cfg, key, seq=T):
    batch = {
        "tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.family == "vlm":
        from repro.models.vlm import VIT_DIM

        batch["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_patches, VIT_DIM)
        )
        batch["tokens"] = batch["tokens"][:, : seq - cfg.num_patches]
        batch["labels"] = batch["labels"][:, : seq - cfg.num_patches]
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        logits = mod.forward(cfg, params, batch)
        t_expect = batch["tokens"].shape[1]
        assert logits.shape == (B, t_expect, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())

    def test_train_step_no_nan(self, arch):
        cfg = get_smoke_config(arch)
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        loss, grads = jax.jit(
            jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, batch))
        )(params)
        assert bool(jnp.isfinite(loss))
        for leaf in jax.tree.leaves(grads):
            assert bool(jnp.isfinite(leaf).all())

    def test_decode_step_shapes(self, arch):
        cfg = get_smoke_config(arch)
        mod = family_module(cfg)
        params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
        state = mod.init_decode_state(cfg, B, 128)
        state2, logits = jax.jit(
            lambda s, t: mod.decode_step(cfg, params, s, t)
        )(state, jnp.zeros((B,), jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        assert int(state2["pos"]) == 1

    def test_full_config_matches_assignment(self, arch):
        """The full config records the assigned architecture exactly."""
        cfg = get_config(arch)
        expected = {
            "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
            "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
            "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
            "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
            "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
            "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
            "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 0, 151936),
            "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
            "whisper-small": (12, 768, 12, 12, 3072, 51865),
            "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        }[arch]
        got = (
            cfg.n_layers,
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.d_ff,
            cfg.vocab_size,
        )
        assert got == expected, (got, expected)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """prefill(prompt) + decode_step must equal the teacher-forced forward."""
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":  # disable capacity dropping for exactness
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    mod = family_module(cfg)
    params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1), seq=32)
    full = mod.forward(cfg, params, batch)
    state, last = mod.prefill(cfg, params, batch, max_seq=64)
    np.testing.assert_allclose(full[:, -1], last, rtol=1e-3, atol=2e-3)

    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    state2, dec_logits = mod.decode_step(cfg, params, state, nxt)
    batch2 = dict(
        batch, tokens=jnp.concatenate([batch["tokens"], nxt[:, None]], axis=1)
    )
    full2 = mod.forward(cfg, params, batch2)
    np.testing.assert_allclose(full2[:, -1], dec_logits, rtol=1e-3, atol=2e-3)


def test_sliding_window_ring_buffer_long_decode():
    """SWA decode past the window: ring buffer matches windowed forward."""
    cfg = get_smoke_config("h2o-danube-1.8b")  # window 16
    mod = family_module(cfg)
    params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 40), 0, cfg.vocab_size)
    # teacher-forced reference over the full sequence
    full = mod.forward(cfg, params, {"tokens": toks})
    # decode token-by-token from scratch
    state = mod.init_decode_state(cfg, 1, 64)
    step = jax.jit(lambda s, t: mod.decode_step(cfg, params, s, t))
    for i in range(40):
        state, logits = step(state, toks[:, i])
    np.testing.assert_allclose(full[:, -1], logits, rtol=2e-3, atol=2e-3)


def test_shape_skip_rules():
    long = SHAPES["long_500k"]
    ok_cases = {"mamba2-1.3b": True, "zamba2-2.7b": True, "h2o-danube-1.8b": True,
                "qwen2-7b": False, "tinyllama-1.1b": False, "starcoder2-7b": False,
                "whisper-small": False, "internvl2-2b": False}
    for arch, expect in ok_cases.items():
        ok, why = supports_shape(get_config(arch), long)
        assert ok == expect, (arch, why)
