"""Distributed machinery: sharding rules, multi-device pipeline/trainer
(subprocess with fake host devices), compression, dry-run on a small mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import abstract_mesh
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.distributed.sharding import RULE_SETS, ShardingContext


class TestShardingRules:
    def _ctx(self, shape=(4, 2), axes=("data", "model"), mode="fsdp_sp"):
        # AbstractMesh: rule logic only needs axis sizes, not real devices.
        mesh = abstract_mesh(shape, axes)
        return ShardingContext(mesh=mesh, rules=RULE_SETS[mode])

    def test_divisible_dims_shard(self):
        ctx = self._ctx()
        spec = ctx.spec_for((8, 16), ("act_batch", "act_seq"))
        assert spec == jax.sharding.PartitionSpec("data", "model")

    def test_nondivisible_falls_back_to_replication(self):
        ctx = self._ctx()
        # 7 % 4 != 0 -> batch axis dropped; 16 % 2 == 0 -> seq stays sharded
        spec = ctx.spec_for((7, 16), ("act_batch", "act_seq"))
        assert spec == jax.sharding.PartitionSpec(None, "model")

    def test_axis_used_only_once(self):
        ctx = self._ctx()
        spec = ctx.spec_for((8, 8), ("act_seq", "act_kv_seq"))  # both -> model
        parts = [p for p in spec if p is not None]
        assert parts.count("model") <= 1

    def test_multi_axis_group(self):
        mesh = abstract_mesh((1, 2, 2), ("pod", "data", "model"))
        ctx = ShardingContext(mesh=mesh, rules=RULE_SETS["fsdp_sp"])
        spec = ctx.spec_for((8, 4), ("act_batch", None))
        assert spec[0] in (("pod", "data"), "data", ("data",))

    def test_no_mesh_is_noop(self):
        ctx = ShardingContext(mesh=None, rules=RULE_SETS["fsdp_sp"])
        assert ctx.spec_for((8,), ("act_batch",)) == jax.sharding.PartitionSpec()


class TestCompression:
    def test_quant_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
        q, scale, n = quantize_int8(x)
        back = dequantize_int8(q, scale, n, x.shape)
        # blockwise max-scaled int8: error <= scale/2 per element
        err = jnp.abs(back - x)
        max_allowed = jnp.repeat(scale[:, 0], 256)[:n] * 0.5 + 1e-7
        assert bool(jnp.all(err <= max_allowed))

    def test_zero_block_stable(self):
        x = jnp.zeros((512,))
        q, scale, n = quantize_int8(x)
        back = dequantize_int8(q, scale, n, x.shape)
        np.testing.assert_array_equal(np.asarray(back), 0.0)


@pytest.mark.slow  # subprocess-per-test with 8 fake devices: ~2 min total
class TestMultiDevice:
    def test_compressed_psum_matches_exact_with_error_feedback(
        self, run_multidevice
    ):
        out = run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np, functools
            from jax.sharding import PartitionSpec as P
            from repro.compat import shard_map
            from repro.distributed.compression import compressed_psum
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((4,), ("dp",))

            @functools.partial(shard_map, mesh=mesh, in_specs=(P("dp"), P("dp")),
                               out_specs=(P("dp"), P("dp")))
            def sync(g, err):
                s, new_err = compressed_psum(g, "dp", err)
                return s, new_err

            key = jax.random.PRNGKey(0)
            # accumulate over steps: error feedback keeps the BIAS bounded
            g = jax.random.normal(key, (4, 1024))
            err = jnp.zeros((4, 1024))
            exact_total = jnp.zeros((1024,))
            approx_total = jnp.zeros((4, 1024))
            for i in range(10):
                g_i = jax.random.normal(jax.random.fold_in(key, i), (4, 1024))
                s, err = sync(g_i, err)
                exact_total = exact_total + g_i.sum(0)
                approx_total = approx_total + s
            # every shard sees the same sum; compare against exact
            rel = float(jnp.linalg.norm(approx_total[0] - exact_total) /
                        jnp.linalg.norm(exact_total))
            assert rel < 0.02, rel
            print("REL", rel)
            """,
            devices=4,
        )
        assert "REL" in out

    def test_sharded_3dgs_pipeline_matches_single_device(self, run_multidevice):
        out = run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import random_gaussians, look_at_camera, render
            from repro.core.pipeline import sharded_render
            g = random_gaussians(jax.random.PRNGKey(0), 256)
            cam = look_at_camera((0, 1.0, -6.0), (0,0,0), width=32, height=32)
            want = render(g, cam)
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((4,), ("gs",))
            rr = sharded_render(mesh, ("gs",), ("gs",))
            got = jax.jit(rr)(g, cam, jnp.zeros(3))
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
            print("MATCH")
            """,
            devices=4,
        )
        assert "MATCH" in out

    def test_sharded_pallas_binned_matches_single_device(self, run_multidevice):
        """Per-device gather-to-compact + compact Pallas kernel inside
        shard_map reproduces the single-device pallas_binned render."""
        out = run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import RenderConfig, random_gaussians, look_at_camera, render
            from repro.core.pipeline import sharded_render
            from repro.launch.mesh import make_mesh
            g = random_gaussians(jax.random.PRNGKey(0), 256)
            cam = look_at_camera((0, 1.0, -6.0), (0,0,0), width=32, height=32)
            cfg = RenderConfig(raster_path="pallas_binned", tile_capacity=256)
            want = render(g, cam, cfg)
            mesh = make_mesh((4,), ("gs",))
            rr = sharded_render(mesh, ("gs",), ("gs",), config=cfg)
            got = jax.jit(rr)(g, cam, jnp.zeros(3))
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
            print("COMPACT MATCH")
            """,
            devices=4,
        )
        assert "COMPACT MATCH" in out

    def test_sharded_render_batch_matches_single_device(self, run_multidevice):
        """Camera x pixel-row sharded batch render reproduces render_batch
        on a (cam=2, gs=2) mesh, binned and pallas_binned."""
        out = run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.core import (RenderConfig, random_gaussians,
                                    orbit_cameras, render_batch)
            from repro.core.pipeline import sharded_render_batch
            from repro.launch.mesh import make_mesh
            g = random_gaussians(jax.random.PRNGKey(0), 256)
            cams = orbit_cameras(4, radius=5.0, width=32, height=32, stacked=True)
            mesh = make_mesh((2, 2), ("cam", "gs"))
            for path in ("binned", "pallas_binned"):
                cfg = RenderConfig(raster_path=path, tile_capacity=256,
                                   early_exit=False)
                want = render_batch(g, cams, cfg)
                rr = sharded_render_batch(mesh, ("gs",), ("cam",), ("gs",),
                                          config=cfg)
                got = jax.jit(rr)(g, cams, jnp.zeros(3))
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=1e-4, atol=1e-4)
            print("BATCH MATCH")
            """,
            devices=4,
        )
        assert "BATCH MATCH" in out

    def test_trainer_restart_and_elastic_reshard(self, run_multidevice):
        out = run_multidevice(
            """
            import shutil, jax
            from repro.configs import get_smoke_config
            from repro.launch.mesh import make_mesh
            from repro.data import SyntheticLMData
            from repro.optim import AdamWConfig
            from repro.train.trainer import Trainer, TrainerConfig
            shutil.rmtree("/tmp/ckpt_sub", ignore_errors=True)
            cfg = get_smoke_config("tinyllama-1.1b")
            data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8)
            ocfg = AdamWConfig(learning_rate=3e-3, warmup_steps=2, total_steps=40)

            # phase 1: train on a 4x2 mesh, inject a crash mid-run
            tr = Trainer(cfg, ocfg, TrainerConfig(steps=12, checkpoint_every=5,
                checkpoint_dir="/tmp/ckpt_sub", log_every=12), data, make_mesh((4,2),("data","model")))
            tr.inject_failure_at(8)
            res = tr.run()
            assert res["restarts"] == 1, res
            assert res["final_step"] == 12

            # phase 2: elastic resume on a DIFFERENT mesh (2x2 = shrink)
            tr2 = Trainer(cfg, ocfg, TrainerConfig(steps=16, checkpoint_every=8,
                checkpoint_dir="/tmp/ckpt_sub", log_every=16), data, make_mesh((2,2),("data","model")))
            res2 = tr2.run()
            assert res2["final_step"] == 16
            assert res2["restarts"] == 0
            print("FT OK", res["restarts"], res2["final_step"])
            """,
            devices=8,
        )
        assert "FT OK" in out

    def test_dryrun_cell_small_mesh(self, run_multidevice):
        """lower+compile a real cell on an 8-device mesh + roofline sanity."""
        out = run_multidevice(
            """
            import jax, sys
            from repro.configs import get_config
            from repro.models.api import SHAPES
            from repro.launch.mesh import make_mesh
            from repro.launch.dryrun import lower_cell, analyze_cell
            import dataclasses
            cfg = get_config("tinyllama-1.1b")
            shape = dataclasses.replace(SHAPES["train_4k"], global_batch=8, seq_len=512)
            mesh = make_mesh((4, 2), ("data", "model"))
            compiled = lower_cell(cfg, shape, mesh)
            res = analyze_cell(cfg, shape, mesh, compiled)
            r = res["roofline"]
            assert r["flops"] > 0 and r["hbm_bytes"] > 0
            assert r["bottleneck"] in ("compute", "memory", "collective")
            # useful-flops ratio sane: between 5% and 120%
            assert 0.05 < r["useful_ratio"] < 1.2, r["useful_ratio"]
            print("DRYRUN OK", r["bottleneck"], round(r["useful_ratio"], 3))
            """,
            devices=8,
            timeout=900,
        )
        assert "DRYRUN OK" in out


@pytest.mark.slow  # subprocess with 8 fake devices, ~35s
class TestExpertParallelMoE:
    def test_ep_shard_map_matches_plain_path(self, run_multidevice):
        """The EP (shard_map) MoE must be numerically identical to the
        single-device dispatch path, gradients included."""
        out = run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_smoke_config
            from repro.models.api import family_module
            from repro.models import params as P
            from repro.distributed import sharding as shd
            from repro.launch.mesh import make_mesh

            cfg = get_smoke_config("qwen3-moe-30b-a3b")
            mod = family_module(cfg)
            params = P.init_tree(jax.random.PRNGKey(0), mod.param_defs(cfg))
            batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab_size),
                     "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, cfg.vocab_size)}

            # plain path (no mesh context)
            loss_plain, g_plain = jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, batch))(params)

            # EP path on a (2 data x 4 model) mesh; 8 experts / 4 = 2 per shard
            mesh = make_mesh((2, 4), ("data", "model"))
            with mesh, shd.axis_rules(mesh, "fsdp_sp"):
                loss_ep, g_ep = jax.jit(jax.value_and_grad(
                    lambda p: mod.loss_fn(cfg, p, batch)))(params, )
            # EP reduces in a different order (psum_scatter tree); tolerances
            # cover f32 reassociation, not a semantic gap.
            np.testing.assert_allclose(float(loss_plain), float(loss_ep), rtol=1e-4)
            for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_ep)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=3e-4)
            print("EP MATCH", float(loss_plain), float(loss_ep))
            """,
            devices=8,
        )
        assert "EP MATCH" in out
