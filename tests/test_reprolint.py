"""reprolint test suite: golden fixtures per rule + repo self-lint.

Each fixture file under ``tests/data/reprolint/`` is linted under a
*synthetic* repo-relative path chosen to land inside the rule's
configured scope (e.g. the kernel-purity fixture pretends to live at
``src/repro/kernels/fx/kernel.py``). The project's real pyproject
config is used throughout, so these tests also pin the shipped scoping
and allowlists.
"""

from __future__ import annotations

import ast
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "data" / "reprolint"

sys.path.insert(0, str(REPO))  # `tools` package lives at the repo root

from tools.reprolint.config import load_config  # noqa: E402
from tools.reprolint.engine import (  # noqa: E402
    SourceFile,
    apply_baseline,
    lint_sources,
    load_baseline,
)
from tools.reprolint.findings import Finding  # noqa: E402


def _sf(fixture: str, as_path: str) -> SourceFile:
    text = (FIXTURES / fixture).read_text()
    return SourceFile(as_path, text, ast.parse(text))


def _lint(files: list[SourceFile], rule: str) -> list[Finding]:
    return lint_sources(files, REPO, load_config(REPO), select={rule})


def _lines(findings: list[Finding]) -> set[int]:
    return {f.line for f in findings}


def _marked_lines(fixture: str) -> set[int]:
    """Lines carrying a ``# LINE:`` marker in the fixture."""
    out = set()
    for i, line in enumerate((FIXTURES / fixture).read_text().splitlines(), 1):
        if "# LINE" in line:
            out.add(i)
    return out


# -- per-rule golden fixtures ---------------------------------------------

CASES = [
    ("tracer-leak", "tracer_leak_pos.py", "tracer_leak_neg.py", "src/repro/core/fx.py"),
    ("retrace-hazard", "retrace_pos.py", "retrace_neg.py", "src/repro/core/fx.py"),
    (
        "kernel-purity",
        "kernel_purity_pos.py",
        "kernel_purity_neg.py",
        "src/repro/kernels/fx/kernel.py",
    ),
    ("dtype-discipline", "dtype_pos.py", "dtype_neg.py", "src/repro/core/fx.py"),
    ("host-sync", "host_sync_pos.py", "host_sync_neg.py", "benchmarks/fx.py"),
    ("lock-discipline", "lock_pos.py", "lock_neg.py", "src/repro/serve/fx.py"),
]


@pytest.mark.parametrize("rule,pos,neg,path", CASES, ids=[c[0] for c in CASES])
def test_rule_fires_on_positive(rule, pos, neg, path):
    findings = _lint([_sf(pos, path)], rule)
    assert findings, f"{rule} reported nothing on its positive fixture"
    assert all(f.rule == rule for f in findings)
    marked = _marked_lines(pos)
    if marked:  # every deliberately-seeded violation line is caught
        assert marked <= _lines(findings), (
            f"{rule} missed marked lines "
            f"{sorted(marked - _lines(findings))}: "
            + "\n".join(f.text() for f in findings)
        )


@pytest.mark.parametrize("rule,pos,neg,path", CASES, ids=[c[0] for c in CASES])
def test_rule_silent_on_negative(rule, pos, neg, path):
    findings = _lint([_sf(neg, path)], rule)
    assert not findings, "\n".join(f.text() for f in findings)


def test_dead_module_reachability():
    files = [
        _sf("dead_module_entry.py", "examples/entry.py"),
        _sf("dead_module_used.py", "src/repro/deadfix/used.py"),
        _sf("dead_module_transitive.py", "src/repro/deadfix/transitive.py"),
        _sf("dead_module_unused.py", "src/repro/deadfix/unused.py"),
    ]
    findings = _lint(files, "dead-module")
    assert [f.path for f in findings] == ["src/repro/deadfix/unused.py"]
    assert "repro.deadfix.unused" in findings[0].message


def test_dead_module_allowlist():
    # The shipped allowlist keeps the dynamically-imported zoo alive.
    files = [_sf("dead_module_unused.py", "src/repro/configs/ghost.py")]
    assert not _lint(files, "dead-module")


# -- suppression mechanics -------------------------------------------------


def test_inline_and_standalone_suppressions():
    findings = _lint([_sf("suppression.py", "src/repro/core/fx.py")], "retrace-hazard")
    assert _lines(findings) == _marked_lines("suppression.py"), "\n".join(
        f.text() for f in findings
    )


def test_disable_file_suppression():
    files = [_sf("suppression_file.py", "src/repro/core/fx.py")]
    assert not _lint(files, "retrace-hazard")


# -- baseline --------------------------------------------------------------


def test_baseline_roundtrip(tmp_path):
    f = Finding("a.py", 3, 1, "retrace-hazard", "msg")
    g = Finding("a.py", 9, 1, "retrace-hazard", "other msg")
    base = tmp_path / "baseline.txt"
    base.write_text("# comment\n\n" + f.baseline_key() + "\n")
    kept = apply_baseline([f, g], load_baseline(base))
    assert kept == [g]
    # Line-number-free identity: a shifted duplicate still matches.
    shifted = Finding("a.py", 300, 7, "retrace-hazard", "msg")
    assert not apply_baseline([shifted], load_baseline(base))


def test_shipped_baseline_is_empty():
    assert not load_baseline(REPO / "tools" / "reprolint" / "baseline.txt")


# -- config loading (mini-TOML fallback must match the shipped file) -------


def test_config_loads_shipped_pyproject():
    cfg = load_config(REPO)
    assert cfg["paths"] == ["src", "tests", "benchmarks", "examples"]
    assert "tests/data" in cfg["exclude"]
    assert cfg["rules"]["lock-discipline"]["safe-attrs"] == ["_queue"]
    allow = cfg["rules"]["dead-module"]["allow"]
    assert "repro.configs.*" in allow and "repro.kernels.*.ref" in allow


# -- end-to-end: the repo lints clean (tier-1 acceptance gate) -------------


def test_reprolint_self_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "tools.reprolint", "--format", "text"],
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert proc.returncode == 0, (
        "reprolint found violations in the repo:\n"
        f"{proc.stdout}\n{proc.stderr}"
    )


def test_github_format_annotation():
    f = Finding("src/a.py", 3, 2, "tracer-leak", "bad % thing")
    out = f.github()
    assert out.startswith("::error file=src/a.py,line=3,col=2,")
    assert "%25" in out and "\n" not in out
