"""Scene hierarchy: tree construction, frustum culling, LOD, sentinels.

The load-bearing contracts:

* **conservative culling** — a chunk is culled only when no member Gaussian
  can touch the screen under the rasterizer's support contract (3-sigma box
  + alpha floor), so at conservative capacity the culled tile lists equal
  the uncull ones and the images match exactly on every raster path;
* **sentinel neutrality** — visible-set gather sentinels (and
  ``pad_to_multiple`` padding generally) carry sub-alpha-floor opacity and
  are mask-culled by the feature pipeline, so they contribute exactly zero
  color/alpha in every blend path and never crowd tile-list capacity;
* **SH LOD exactness** — zeroing coefficients above degree k reproduces the
  degree-k evaluation bit-for-bit, so the distance-banded LOD (and the
  static ``sh_degree`` knob) need no second executable.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RenderConfig,
    SceneTree,
    apply_sh_lod,
    build_scene_tree,
    clustered_gaussians,
    cull_chunks,
    gather_visible,
    random_gaussians,
    render,
    render_batch,
    render_batch_masked,
    select_visible_chunks,
    visibility_stats,
)
from repro.core.camera import look_at_camera, orbit_cameras
from repro.core.features import compute_features_fused
from repro.core.gaussians import pad_to_multiple
from repro.core.sh import eval_sh_color


def _scene(n=300, seed=0, extent=1.5):
    return random_gaussians(jax.random.PRNGKey(seed), n, extent=extent)


def _cam(size=32, eye=(0.0, 1.0, -5.0)):
    return look_at_camera(eye, (0.0, 0.0, 0.0), width=size, height=size)


# The O(P*G) dense oracle is compile-heavy at these scene sizes; its params
# run in the slow CI suite (dense==binned is pinned separately in
# test_binning), the production + Pallas paths stay in tier-1.
ALL_PATHS = [
    pytest.param("dense", marks=pytest.mark.slow),
    "binned",
    "pallas",
    "pallas_binned",
]


class TestBuildTree:
    def test_shapes_and_padding(self):
        g = _scene(n=300)
        tree = build_scene_tree(g, leaf_size=64)
        assert tree.num_chunks == 5  # 300 -> 320 padded
        assert tree.num_gaussians == 5 * 64
        assert tree.num_real == 300
        assert tree.chunk_lo.shape == tree.chunk_hi.shape == (5, 3)

    def test_permutation_preserves_cloud(self):
        g = _scene(n=128)
        tree = build_scene_tree(g, leaf_size=32)
        # Same multiset of positions in the first num_real rows.
        a = np.sort(np.asarray(g.positions), axis=0)
        b_all = np.asarray(tree.gaussians.positions)
        # Padding rows are invisible (opacity below the alpha floor).
        opa = jax.nn.sigmoid(np.asarray(tree.gaussians.opacity_logit))
        real = opa >= 1.0 / 255.0
        assert real.sum() == 128
        np.testing.assert_allclose(np.sort(b_all[real], axis=0), a)

    def test_chunks_are_spatially_coherent(self):
        """Morton ordering: chunk AABB volumes are far below the scene
        AABB volume (random order would give every chunk ~the full box)."""
        g = _scene(n=4096, extent=2.0)
        tree = build_scene_tree(g, leaf_size=256)
        ext = np.asarray(tree.chunk_hi - tree.chunk_lo)
        scene_vol = np.prod(
            np.asarray(g.positions).max(0) - np.asarray(g.positions).min(0)
        )
        assert np.median(np.prod(ext, axis=1)) < 0.25 * scene_vol

    def test_aabbs_contain_members_with_sigma_pad(self):
        g = _scene(n=200)
        tree = build_scene_tree(g, leaf_size=64)
        pos = np.asarray(tree.gaussians.positions)
        rad = 3.0 * np.exp(np.asarray(tree.gaussians.log_scales)).max(-1)
        valid = np.arange(pos.shape[0]) < 200
        for c in range(tree.num_chunks):
            sl = slice(c * 64, (c + 1) * 64)
            v = valid[sl]
            if not v.any():
                continue
            lo = np.asarray(tree.chunk_lo[c])
            hi = np.asarray(tree.chunk_hi[c])
            assert (pos[sl][v] - rad[sl][v, None] >= lo - 1e-5).all()
            assert (pos[sl][v] + rad[sl][v, None] <= hi + 1e-5).all()

    def test_rejects_empty_and_bad_leaf(self):
        g = _scene(n=8)
        with pytest.raises(ValueError, match="leaf_size"):
            build_scene_tree(g, leaf_size=0)


class TestCullChunks:
    def test_all_visible_from_far_camera(self):
        tree = build_scene_tree(_scene(), leaf_size=64)
        vis = cull_chunks(tree, _cam(eye=(0, 1, -8)))
        assert bool(np.asarray(vis.visible).all())

    def test_behind_camera_culled(self):
        """Two separated clusters; the one behind the camera is culled."""
        front = _scene(n=128, seed=0, extent=0.4)
        back = dataclasses.replace(
            _scene(n=128, seed=1, extent=0.4),
            positions=_scene(n=128, seed=1, extent=0.4).positions
            + jnp.asarray([0.0, 0.0, -20.0]),
        )
        g = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b]), front, back
        )
        tree = build_scene_tree(g, leaf_size=32)
        cam = _cam(eye=(0.0, 0.0, -5.0))  # looking at the front cluster
        vis = np.asarray(cull_chunks(tree, cam).visible)
        assert vis.any() and not vis.all()
        # The culled chunks are exactly the far-cluster ones.
        centers = np.asarray(0.5 * (tree.chunk_lo + tree.chunk_hi))
        assert (centers[~vis][:, 2] < -5.0).all()

    def test_off_center_principal_point_stays_conservative(self):
        """An off-center cx widens one side of the frustum beyond the
        symmetric tan_fov; culling must still keep every chunk that can
        reach the screen (COLMAP captures are routinely asymmetric)."""
        g = _scene(n=512, extent=2.0)
        tree = build_scene_tree(g, leaf_size=64)
        cam = look_at_camera(
            (0.0, 0.0, 0.0), (0.0, 0.0, 3.0), width=64, height=64
        )
        # Shift the principal point hard toward one edge: content near the
        # wide edge sits outside the symmetric half-angle.
        cam = dataclasses.replace(
            cam, cx=jnp.asarray(8.0, jnp.float32)
        )
        cfg = RenderConfig(
            raster_path="binned", early_exit=False, cull=True
        )
        base = render(tree, cam, cfg.replace(cull=False))
        culled = render(tree, cam, cfg)
        np.testing.assert_allclose(
            np.asarray(culled), np.asarray(base), atol=1e-6
        )

    def test_lod_bands_by_distance(self):
        tree = build_scene_tree(_scene(extent=0.3), leaf_size=64)
        cam_near = _cam(eye=(0, 0, -1.5))
        cam_far = _cam(eye=(0, 0, -30.0))
        near = cull_chunks(tree, cam_near, lod_thresholds=(5.0, 20.0))
        far = cull_chunks(tree, cam_far, lod_thresholds=(5.0, 20.0))
        assert (np.asarray(near.sh_degree) == 3).all()
        assert (np.asarray(far.sh_degree) == 0).all()

    def test_select_nearest_first_on_overflow(self):
        tree = build_scene_tree(_scene(n=512), leaf_size=64)
        vis = cull_chunks(tree, _cam(eye=(0, 1, -8)))
        idx, nvis = select_visible_chunks(vis, capacity=3)
        assert int(nvis) == tree.num_chunks  # all visible, overflowed
        dist = np.asarray(vis.distance)
        kept = np.asarray(idx)
        assert (kept < tree.num_chunks).all()
        # Kept chunks are the 3 nearest.
        assert set(kept) == set(np.argsort(dist)[:3])

    def test_sentinel_padding_in_select(self):
        tree = build_scene_tree(_scene(n=256), leaf_size=64)
        vis = cull_chunks(tree, _cam())
        # Force one chunk invisible to exercise sentinel padding.
        vis = dataclasses.replace(
            vis, visible=vis.visible.at[0].set(False)
        )
        idx, nvis = select_visible_chunks(vis, capacity=tree.num_chunks)
        assert int(nvis) == tree.num_chunks - 1
        assert int(np.asarray(idx[-1])) == tree.num_chunks  # sentinel


class TestCulledRenderEquivalence:
    @pytest.mark.parametrize("path", ALL_PATHS)
    def test_all_visible_matches_uncull(self, path):
        g = _scene(n=256)
        tree = build_scene_tree(g, leaf_size=64)
        cam = _cam()
        cfg = RenderConfig(
            raster_path=path,
            tile_capacity=128,
            early_exit=False,
            pixel_chunk=None,
        )
        base = render(g, cam, cfg)
        culled = render(tree, cam, cfg.replace(cull=True))
        np.testing.assert_allclose(
            np.asarray(culled), np.asarray(base), atol=1e-5, rtol=1e-5
        )

    @pytest.mark.parametrize(
        "path",
        [
            # The O(P*G) oracle at 600 G is compile-heavy; the binned
            # production path keeps the pixel-exactness pin in tier-1.
            pytest.param("dense", marks=pytest.mark.slow),
            "binned",
        ],
    )
    def test_conservative_drop_is_pixel_exact(self, path):
        """Camera inside the scene: far/behind chunks culled, image equal
        on the in-frustum content (conservative culling only removes
        Gaussians the support contract already excludes)."""
        g = _scene(n=600, extent=2.0)
        tree = build_scene_tree(g, leaf_size=64)
        # Camera inside the cloud looking outward: one frustum's worth of
        # the scene is visible, the rest is conservatively culled.
        cam = look_at_camera(
            (0.0, 0.0, 0.0), (0.0, 0.0, 3.0), width=32, height=32
        )
        cfg = RenderConfig(
            raster_path=path, early_exit=False, pixel_chunk=None
        )
        stats = visibility_stats(tree, cam, cfg.replace(cull=True))
        assert 0 < stats["num_visible"] < stats["num_chunks"]
        base = render(g, cam, cfg)
        culled = render(tree, cam, cfg.replace(cull=True))
        np.testing.assert_allclose(
            np.asarray(culled), np.asarray(base), atol=1e-6
        )

    def test_capacity_overflow_drops_far_content_only(self):
        g = _scene(n=512)
        tree = build_scene_tree(g, leaf_size=64)
        cam = _cam()
        cfg = RenderConfig(
            raster_path="binned",
            early_exit=False,
            cull=True,
            visible_capacity=2,
        )
        out = render(tree, cam, cfg)
        assert np.isfinite(np.asarray(out)).all()

    def test_one_executable_many_cameras(self):
        """Culling is traced: different cameras hit one compiled fn."""
        from repro.core import render_jit

        tree = build_scene_tree(_scene(n=128), leaf_size=32)
        cfg = RenderConfig(raster_path="binned", cull=True, visible_capacity=4)
        cams = orbit_cameras(3, radius=5.0, width=16, height=16)
        render_jit(tree, cams[0], cfg)
        before = render_jit._cache_size()
        render_jit(tree, cams[1], cfg)
        render_jit(tree, cams[2], cfg)
        assert render_jit._cache_size() == before

    @pytest.mark.slow  # value_and_grad through cull+gather: compile-heavy
    def test_gradients_flow_through_culled_render(self):
        g = _scene(n=128)
        tree = build_scene_tree(g, leaf_size=32)
        cam = _cam(size=16)
        cfg = RenderConfig(
            raster_path="binned", cull=True, tile_capacity=64
        )

        def loss(cloud):
            t = dataclasses.replace(tree, gaussians=cloud)
            return jnp.mean(render(t, cam, cfg) ** 2)

        grads = jax.grad(loss)(tree.gaussians)
        for name in ["positions", "sh", "opacity_logit"]:
            gn = float(jnp.linalg.norm(getattr(grads, name)))
            assert np.isfinite(gn) and gn > 0.0, name


class TestBatchedCulledRender:
    def test_render_batch_matches_per_camera(self):
        tree = build_scene_tree(_scene(n=256), leaf_size=64)
        cb = orbit_cameras(3, radius=5.0, width=32, height=32, stacked=True)
        cfg = RenderConfig(
            raster_path="binned",
            early_exit=False,
            cull=True,
            visible_capacity=4,
        )
        out = render_batch(tree, cb, cfg)
        for i in range(3):
            want = render(tree, cb.camera(i), cfg)
            np.testing.assert_allclose(
                np.asarray(out[i]), np.asarray(want), atol=1e-5
            )

    def test_masked_inactive_slots_render_background(self):
        tree = build_scene_tree(_scene(n=128), leaf_size=32)
        cb = orbit_cameras(3, radius=5.0, width=16, height=16, stacked=True)
        cfg = RenderConfig(
            raster_path="binned",
            cull=True,
            visible_capacity=4,
            background=(0.2, 0.4, 0.6),
        )
        out = render_batch_masked(
            tree, cb, jnp.asarray([True, False, True]), cfg
        )
        bg = np.broadcast_to(np.asarray(cfg.background), (16, 16, 3))
        np.testing.assert_allclose(np.asarray(out[1]), bg, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(out[0]),
            np.asarray(render(tree, cb.camera(0), cfg)),
            atol=1e-5,
        )


class TestSentinelNeutrality:
    """Satellite: gather sentinels contribute exactly zero everywhere."""

    def _tree_with_sentinels(self):
        g = _scene(n=96)
        tree = build_scene_tree(g, leaf_size=32)  # 3 chunks
        # Capacity above the chunk count guarantees sentinel slots in the
        # gathered compact set.
        vis = cull_chunks(tree, _cam())
        idx, _ = select_visible_chunks(
            dataclasses.replace(vis, visible=vis.visible.at[2].set(False)),
            capacity=tree.num_chunks,
        )
        return tree, idx

    def test_gather_pads_with_invisible_records(self):
        tree, idx = self._tree_with_sentinels()
        params, valid = gather_visible(tree, idx)
        assert params.num_gaussians == tree.num_chunks * 32
        sentinels = ~np.repeat(np.asarray(valid), 32)
        assert sentinels.any()
        opa = jax.nn.sigmoid(np.asarray(params.opacity_logit))
        assert (opa[sentinels] < 1.0 / 255.0).all()

    def test_sentinel_features_are_mask_culled(self):
        tree, idx = self._tree_with_sentinels()
        params, valid = gather_visible(tree, idx)
        feats = compute_features_fused(params, _cam())
        sentinels = ~np.repeat(np.asarray(valid), 32)
        assert (np.asarray(feats.mask)[sentinels] == 0.0).all()

    @pytest.mark.parametrize("path", ALL_PATHS)
    def test_sentinels_contribute_zero_in_every_blend_path(self, path):
        """Rendering the sentinel-padded compact set == rendering the same
        real records without sentinels, on every raster path."""
        tree, idx = self._tree_with_sentinels()
        params, valid = gather_visible(tree, idx)
        mask = np.repeat(np.asarray(valid), 32)
        real = jax.tree.map(lambda x: x[np.where(mask)[0]], params)
        cam = _cam()
        cfg = RenderConfig(
            raster_path=path,
            tile_capacity=96,
            early_exit=False,
            pixel_chunk=None,
        )
        with_sentinels = render(params, cam, cfg)
        without = render(real, cam, cfg)
        np.testing.assert_allclose(
            np.asarray(with_sentinels), np.asarray(without), atol=1e-6
        )

    def test_pad_to_multiple_padding_never_crowds_tile_lists(self):
        """The mask now culls sub-alpha-floor opacities, so padded records
        cannot occupy tile-list capacity (they used to pass the mask)."""
        from repro.core.binning import bin_gaussians
        from repro.core.rasterize import sort_by_depth

        g = _scene(n=64)
        padded, _ = pad_to_multiple(g, 128)
        feats = sort_by_depth(compute_features_fused(padded, _cam()))
        bins = bin_gaussians(feats, 32, 32, tile_size=16, capacity=128)
        # No list may contain more live entries than there are real
        # Gaussians: padding must never appear.
        assert int(np.asarray(bins.count).max()) <= 64


class TestShDegreeLOD:
    """Satellite: sh_degree threading + LOD-banding exactness."""

    def test_degree_k_equals_degree3_with_zeroed_tail(self):
        g = _scene(n=64)
        cam = _cam()
        for k in (0, 1, 2):
            nb = (k + 1) ** 2
            zeroed = dataclasses.replace(
                g, sh=g.sh.at[:, nb:, :].set(0.0)
            )
            a = compute_features_fused(g, cam, sh_degree=k).color
            b = compute_features_fused(zeroed, cam, sh_degree=3).color
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-6
            )

    def test_config_sh_degree_threads_to_render(self):
        g = _scene(n=64)
        cam = _cam()
        nb = 4  # degree 1
        zeroed = dataclasses.replace(g, sh=g.sh.at[:, nb:, :].set(0.0))
        a = render(g, cam, RenderConfig(sh_degree=1, early_exit=False))
        b = render(zeroed, cam, RenderConfig(sh_degree=3, early_exit=False))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_apply_sh_lod_matches_low_degree_eval(self):
        key = jax.random.PRNGKey(0)
        sh = jax.random.normal(key, (32, 16, 3))
        dirs = jax.random.normal(jax.random.PRNGKey(1), (32, 3))
        dirs = dirs / jnp.linalg.norm(dirs, axis=-1, keepdims=True)
        for k in (0, 1, 3):
            deg = jnp.full((32,), k, dtype=jnp.int32)
            banded = eval_sh_color(apply_sh_lod(sh, deg), dirs, degree=3)
            direct = eval_sh_color(sh, dirs, degree=k)
            np.testing.assert_allclose(
                np.asarray(banded), np.asarray(direct), atol=1e-6
            )

    def test_lod_render_drops_view_dependence_only(self):
        """Degree-0 LOD on every chunk == rendering with sh_degree=0."""
        g = _scene(n=128)
        tree = build_scene_tree(g, leaf_size=32)
        cam = _cam()
        # Thresholds of 0 put every chunk in the far band (degree 0).
        lod = render(
            tree,
            cam,
            RenderConfig(
                cull=True, lod_thresholds=(0.0, 0.0), early_exit=False
            ),
        )
        flat = render(
            tree,
            cam,
            RenderConfig(cull=True, sh_degree=0, early_exit=False),
        )
        np.testing.assert_allclose(
            np.asarray(lod), np.asarray(flat), atol=1e-6
        )


class TestServerWithTree:
    def test_server_builds_tree_and_matches_uncull(self):
        from repro.serve import RenderServer

        g = _scene(n=256)
        cfg = RenderConfig(
            raster_path="binned", cull=True, leaf_size=64, visible_capacity=8
        )
        cam = _cam()
        server = RenderServer(g, cfg, width=32, height=32, max_batch=2)
        assert isinstance(server.model, SceneTree)
        with server:
            got = server.render(cam).image
        want = np.asarray(render(g, cam, RenderConfig(raster_path="binned")))
        np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.slow
class TestBigSceneSmoke:
    def test_200k_culled_render_cpu(self):
        """200k-Gaussian clustered scene: culled render matches uncull and
        visible fraction is partial (the million-Gaussian path in little)."""
        g = clustered_gaussians(
            jax.random.PRNGKey(0), 200_000, num_clusters=12, extent=2.0
        )
        tree = build_scene_tree(g, leaf_size=256)
        cam = look_at_camera(
            (0.7, 0.2, 0.0), (2.1, 0.2, 0.0), width=128, height=128
        )
        cfg = RenderConfig(raster_path="binned")
        stats = visibility_stats(tree, cam, cfg.replace(cull=True))
        assert stats["visible_fraction"] < 0.5
        cfgc = cfg.replace(
            cull=True, visible_capacity=stats["num_visible"]
        )
        base = render(g, cam, cfg)
        culled = render(tree, cam, cfgc)
        np.testing.assert_allclose(
            np.asarray(culled), np.asarray(base), atol=1e-5
        )


@pytest.mark.slow
class TestShardedCulledRender:
    def test_sharded_batch_with_tree(self, run_multidevice):
        run_multidevice(
            """
            import jax, jax.numpy as jnp, numpy as np
            from repro.compat import make_mesh
            from repro.core import (RenderConfig, build_scene_tree,
                                    random_gaussians, render)
            from repro.core.camera import orbit_cameras
            from repro.core.pipeline import sharded_render_batch

            mesh = make_mesh((2, 2, 2), ("gs", "cam", "px"))
            g = random_gaussians(jax.random.PRNGKey(0), 512, extent=1.5)
            tree = build_scene_tree(g, leaf_size=64)
            cfg = RenderConfig(raster_path="binned", early_exit=False,
                               cull=True, visible_capacity=4)
            cams = orbit_cameras(2, radius=5.0, width=32, height=32,
                                 stacked=True)
            fn = sharded_render_batch(mesh, ("gs",), ("cam",), ("px",),
                                      config=cfg)
            out = fn(tree, cams, jnp.zeros(3))
            for i in range(2):
                want = render(g, cams.camera(i), cfg.replace(cull=False))
                err = float(jnp.abs(out[i] - want).max())
                assert err < 1e-5, err
            print("ok")
            """,
            devices=8,
        )
