"""Roofline HLO-parser unit tests (known workloads, subprocess meshes)."""

import sys

import pytest


class TestParserOnKnownWorkloads:
    def test_scan_matmul_exact_flops(self, run_multidevice):
        out = run_multidevice(
            """
            import jax, jax.numpy as jnp, sys
            sys.path.insert(0, "/root/repo")
            from jax.sharding import PartitionSpec as P, NamedSharding
            from benchmarks import roofline as R
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            L = 7
            def step(w, x):
                def body(c, _):
                    return jnp.tanh(c @ w), None
                h, _ = jax.lax.scan(body, x, None, length=L)
                return h.sum()
            ws = jax.ShapeDtypeStruct((512, 512), jnp.float32)
            xs = jax.ShapeDtypeStruct((64, 512), jnp.float32)
            with mesh:
                comp = jax.jit(step, in_shardings=(
                    NamedSharding(mesh, P(None, "model")),
                    NamedSharding(mesh, P("data", None)))).lower(ws, xs).compile()
            rep = R.analyze(comp.as_text(), num_partitions=8)
            expected = 2 * 64 * 512 * 512 * L / 8  # per-device
            ratio = rep.flops / expected
            assert 0.99 < ratio < 1.01, ratio
            # the scan body all-gathers x (32,512) f32 per iteration
            per_iter_ag = 32 * 512 * 4 * (4 - 1) / 4
            assert rep.collective_bytes >= per_iter_ag * L * 0.9
            print("PARSER OK", ratio)
            """,
            devices=8,
        )
        assert "PARSER OK" in out

    def test_collective_formulas(self):
        sys.path.insert(0, "/root/repo")
        from benchmarks import roofline as R

        # all-reduce of f32[1024] over 4 devices: 2 * 4096 B * 3/4
        line = "  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[2,4]<=[8], to_apply=%sum"
        got = R._collective_bytes(line, "all-reduce", 8)
        assert abs(got - 2 * 4096 * 3 / 4) < 1e-6

        line2 = "  %ag = f32[64,512]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}"
        got2 = R._collective_bytes(line2, "all-gather", 8)
        assert abs(got2 - 64 * 512 * 4 * 3 / 4) < 1e-6

    def test_model_flops_dense_vs_moe(self):
        sys.path.insert(0, "/root/repo")
        from benchmarks import roofline as R
        from repro.configs import get_config
        from repro.models.api import SHAPES

        dense = get_config("tinyllama-1.1b")
        n = R.active_param_count(dense)
        assert 1.0e9 < n < 1.3e9, n  # ~1.1B

        moe = get_config("qwen3-moe-30b-a3b")
        n_active = R.active_param_count(moe)
        assert 2e9 < n_active < 4.5e9, n_active  # "a3b" = ~3B active

        full_moe = get_config("qwen3-moe-235b-a22b")
        n_active2 = R.active_param_count(full_moe)
        assert 1.5e10 < n_active2 < 3e10, n_active2  # ~22B active

        mf = R.model_flops_global(dense, SHAPES["train_4k"])
        assert abs(mf - 6 * n * 256 * 4096) / mf < 1e-6
