"""Paper Table I analogue: per-kernel cost of computing one Gaussian's features.

The paper reports cycles per Gaussian for each of the 7 (post-partitioning)
kernels under Naive vs in-tile-optimized (Stream/Window) execution. We report
microseconds per 100-Gaussian batch (the paper's simulator input size) for:

  naive      — per-Gaussian scalar loops (paper Listing 1 semantics)
  staged     — SoA-vectorized stage (paper Listing 2 / in-tile optimized)

``derived`` column: ns/Gaussian and the naive/staged speedup per stage.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import look_at_camera, random_gaussians
from repro.core import features as F

N = 100  # paper: "100 Gaussian samples were randomly generated"


def naive_stage_fns(cam, sh_degree=3):
    """Per-stage naive (vmap-of-scalar-loops) implementations."""
    return {
        "cov3D": lambda g: jax.vmap(F._naive_cov3d_single)(g.quats, g.scales()),
        "projection": lambda g: F.stage_projection(g.positions, cam),
        "Jacobian": lambda g: F.stage_jacobian(
            F.stage_projection(g.positions, cam)[0], cam
        ),
        "cov2D": lambda g: jax.vmap(F._naive_cov2d_single, in_axes=(0, 0, None))(
            jax.vmap(F._naive_cov3d_single)(g.quats, g.scales()),
            F.stage_jacobian(F.stage_projection(g.positions, cam)[0], cam),
            cam.r_cw,
        ),
        "cov2D_inv": lambda g: F.stage_cov2d_inv(
            jax.vmap(F._naive_cov2d_single, in_axes=(0, 0, None))(
                jax.vmap(F._naive_cov3d_single)(g.quats, g.scales()),
                F.stage_jacobian(F.stage_projection(g.positions, cam)[0], cam),
                cam.r_cw,
            )
        ),
        "dir_vec": lambda g: F.stage_ray_dir(g.positions, cam),
        "color": lambda g: jax.vmap(
            lambda sh_n, d_n: jnp.maximum(_naive_color(sh_n, d_n, sh_degree), 0.0)
        )(g.sh, F.stage_ray_dir(g.positions, cam)),
    }


def _naive_color(sh_n, d_n, sh_degree):
    from repro.core.sh import sh_basis

    basis = sh_basis(d_n)
    acc = jnp.zeros((3,), dtype=sh_n.dtype)
    for k in range((sh_degree + 1) ** 2):
        acc = acc + sh_n[k] * basis[k]
    return acc + 0.5


def main() -> None:
    g = random_gaussians(jax.random.PRNGKey(0), N)
    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=128, height=128)

    staged = F.staged_stage_fns(cam)
    naive = naive_stage_fns(cam)

    for stage in ["color", "dir_vec", "cov2D", "Jacobian", "cov2D_inv", "projection", "cov3D"]:
        # reprolint: disable=retrace-hazard -- one compile per swept stage;
        # time_fn warms up past it.
        t_naive = time_fn(jax.jit(naive[stage]), g)
        t_staged = time_fn(jax.jit(staged[stage]), g)  # reprolint: disable=retrace-hazard
        speedup = t_naive / max(t_staged, 1e-9)
        emit(
            f"table1/{stage}/naive",
            t_naive,
            f"{t_naive * 1000 / N:.0f}ns_per_gaussian",
        )
        emit(
            f"table1/{stage}/staged",
            t_staged,
            f"{t_staged * 1000 / N:.0f}ns_per_gaussian;speedup={speedup:.1f}x",
        )


if __name__ == "__main__":
    main()
