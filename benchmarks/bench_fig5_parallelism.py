"""Paper Fig. 5 analogue: throughput vs spatial-parallelism degree.

The paper replicates feature-computation units across AIE columns
(1/4/8/25/50 units) and measures simulator throughput, observing near-linear
scaling to 25 units. Our spatial axis is TPU chips; since this container has
one CPU device, the scaling numbers come from the same source as the paper's:
a model (roofline over compiled HLO) rather than wall-clock. A subprocess
lowers the sharded feature pipeline over 1..64 fake devices, parses the
compiled module per device count, and reports model throughput:

    tput(P) = stream_bytes / max(compute_s, memory_s, collective_s)

We also emit single-device wall-clock scaling over the stream length
(linearity in N — what the AIE simulator's steady-state assumption implies).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax

from benchmarks.common import emit, time_fn

_SUBPROC = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import json, sys
sys.path.insert(0, {repo!r})
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp
from repro.core import random_gaussians, look_at_camera
from repro.core.pipeline import sharded_features
from repro.core.gaussians import GAUSSIAN_RECORD_BYTES
from benchmarks import roofline as R

N = 1_048_576  # 1M-Gaussian stream (paper's scene: 389,434)
g = jax.eval_shape(lambda k: random_gaussians(k, N), jax.random.PRNGKey(0))
cam = look_at_camera((0, 1.0, -6.0), (0,0,0), width=1024, height=1024)
out = {{}}
for p in [1, 4, 8, 16, 32, 64]:
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((p,), ("gs",))
    fn = sharded_features(mesh, ("gs",))
    with mesh:
        compiled = jax.jit(fn).lower(g, cam).compile()
    rep = R.analyze(compiled.as_text(), num_partitions=p)
    bound = max(rep.compute_s, rep.memory_s, rep.collective_s)
    tput = N * GAUSSIAN_RECORD_BYTES / bound / 1e9  # GB/s of gaussian records
    out[p] = dict(compute_s=rep.compute_s, memory_s=rep.memory_s,
                  collective_s=rep.collective_s, tput_gbps=tput)
print("JSON" + json.dumps(out))
"""


def main() -> None:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")

    # 1) model-based scaling over device count (paper Fig. 5 axis)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROC.format(repo=repo, src=src)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON")]
    if not line:
        raise RuntimeError(f"fig5 subprocess failed: {proc.stderr[-2000:]}")
    data = json.loads(line[0][4:])
    base = data["1"]["tput_gbps"]
    for p, d in data.items():
        emit(
            f"fig5/roofline_tput/p{p}",
            d["memory_s"] * 1e6,
            f"{d['tput_gbps']:.1f}GBps;scaling={d['tput_gbps'] / base:.1f}x",
        )

    # 2) single-device wall-clock linearity in stream length
    import jax.numpy as jnp

    from repro.core import features as F
    from repro.core import look_at_camera, random_gaussians
    from repro.core.gaussians import GAUSSIAN_RECORD_BYTES

    cam = look_at_camera((0, 1.0, -6.0), (0, 0, 0), width=512, height=512)
    f = jax.jit(lambda g: F.compute_features_fused(g, cam))
    t_base = None
    for n in [16_384, 65_536, 262_144]:
        g = random_gaussians(jax.random.PRNGKey(n), n)
        t = time_fn(f, g, warmup=1, iters=3)
        if t_base is None:
            t_base = t / n
        emit(
            f"fig5/stream_scaling/n{n}",
            t,
            f"{n * GAUSSIAN_RECORD_BYTES / t:.0f}MBps;per_gaussian_ns={t * 1000 / n:.1f}",
        )


if __name__ == "__main__":
    main()
